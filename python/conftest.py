"""pytest bootstrap: make the `compile` package importable whether pytest is
invoked from the repo root (`pytest python/tests/`) or from `python/`."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
