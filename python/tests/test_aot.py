"""AOT lowering tests: every entry point lowers to parseable HLO text and the
manifest agrees with the model's declared shapes."""

import json
import pathlib
import sys
import tempfile

import jax
import pytest

from compile import aot, model


def test_entry_points_cover_all_kernels():
    from compile.kernels import stencil

    eps = model.entry_points(2, 8)
    assert set(eps) == set(stencil.ENTRY_KERNELS)


@pytest.mark.parametrize("name", list(model.entry_points(1, 4)))
def test_lower_single_entry(name):
    fn, specs, n_out = model.entry_points(2, 8)[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # return_tuple=True ⇒ root is a tuple of n_out elements
    assert text.count("parameter(") >= len(specs)


def test_lower_all_writes_manifest(tmp_path):
    aot.lower_all(tmp_path, batch=2, n=4, extra_batches=(1,))
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["n"] == 4
    assert manifest["default_batch"] == 2
    names = {(e["name"], e["batch"]) for e in manifest["entries"]}
    assert len(names) == 2 * len(model.entry_points(1, 4))
    for e in manifest["entries"]:
        f = tmp_path / e["file"]
        assert f.exists() and f.stat().st_size > 0
        for spec in e["inputs"]:
            assert spec["dtype"] == "float32"


def test_hlo_is_batch_shape_specialised(tmp_path):
    fn, specs, _ = model.entry_points(3, 4)["jacobi"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "f32[3,6,6,6]" in text  # halo-padded input embedded in module
