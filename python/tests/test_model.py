"""L2 model-composition tests: the reference projection step has the right
physics on a periodic box (divergence reduction, momentum/energy sanity)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def params(n, dt=0.005, nu=0.01, alpha=0.01, beta_g=0.0):
    return jnp.asarray(
        [dt, 1.0 / n, nu, alpha, beta_g, 300.0, 0.0, 1.0, 0.857, 0.0, 0.0, 0.0],
        jnp.float32)


def taylor_green(n):
    """Taylor–Green-like periodic initial velocity on an n³ box."""
    x = (np.arange(n) + 0.5) / n * 2 * np.pi
    X, Y, Z = np.meshgrid(x, x, x, indexing="ij")
    u = np.sin(X) * np.cos(Y) * np.cos(Z)
    v = -np.cos(X) * np.sin(Y) * np.cos(Z)
    w = np.zeros_like(u)
    return (jnp.asarray(a[None], jnp.float32) for a in (u, v, w))


def test_reference_step_shapes():
    n = 8
    u, v, w = taylor_green(n)
    t = 300.0 * jnp.ones((1, n, n, n), jnp.float32)
    un, vn, wn, tn, p = model.reference_step(u, v, w, t, params(n), n_jacobi=30)
    for a in (un, vn, wn, tn, p):
        assert a.shape == (1, n, n, n)
        assert bool(jnp.all(jnp.isfinite(a)))


def test_projection_reduces_divergence_taylor_green():
    n = 16
    u, v, w = taylor_green(n)
    t = 300.0 * jnp.ones((1, n, n, n), jnp.float32)
    par = params(n)
    un, vn, wn, _, _ = model.reference_step(u, v, w, t, par, n_jacobi=300)
    pre = ref.divergence(model._wrap(u), model._wrap(v), model._wrap(w), par)
    post = ref.divergence(model._wrap(un), model._wrap(vn), model._wrap(wn), par)
    assert float(jnp.sqrt(jnp.mean(post**2))) < float(jnp.sqrt(jnp.mean(pre**2)))


def test_energy_conserved_without_sources():
    """With q_int=0 and periodic BCs, mean temperature is invariant."""
    n = 8
    rng = np.random.default_rng(7)
    u = jnp.zeros((1, n, n, n), jnp.float32)
    t = jnp.asarray(rng.uniform(295, 305, (1, n, n, n)), jnp.float32)
    par = params(n, nu=0.0, alpha=0.02)
    _, _, _, tn, _ = model.reference_step(u, u, u, t, par, n_jacobi=5)
    assert abs(float(jnp.mean(tn)) - float(jnp.mean(t))) < 1e-3


def test_buoyancy_accelerates_hot_fluid_upward():
    n = 8
    u = jnp.zeros((1, n, n, n), jnp.float32)
    t = 300.0 * jnp.ones((1, n, n, n), jnp.float32)
    t = t.at[0, 4, 4, 4].set(310.0)
    par = params(n, beta_g=1.0)  # b_w = β g (T − T∞), T∞ = 300
    _, _, wn, _, _ = model.reference_step(u, u, u, t, par, n_jacobi=100)
    assert float(wn[0, 4, 4, 4]) > 0.0  # hot cell pushed along +z


def test_viscosity_decays_kinetic_energy():
    n = 16
    u, v, w = taylor_green(n)
    t = 300.0 * jnp.ones((1, n, n, n), jnp.float32)
    par = params(n, nu=0.05)
    ke0 = float(jnp.mean(u**2 + v**2 + w**2))
    un, vn, wn = u, v, w
    for _ in range(3):
        un, vn, wn, _, _ = model.reference_step(un, vn, wn, t, par, n_jacobi=60)
    ke1 = float(jnp.mean(un**2 + vn**2 + wn**2))
    assert ke1 < ke0
