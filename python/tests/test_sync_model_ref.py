"""Reference port of the rust/src/sync bounded-interleaving model checker.

A line-for-line port of ``sync::model`` (the CHESS-style bounded-DFS
explorer) and the three protocol models in ``sync::protocols`` —
commit/flush barrier ordering with fault injection, epoch-pin
retire/park/release, and publisher subscriber-seeding. This is the
container-side validation of the Rust subsystem (the established
port-trick used for the h5lite codecs): the algorithm, the three
invariants, and the buggy-variant catches are exercised here with the
exact state machines the Rust tests compile, and the interleaving counts
printed by ``-s`` calibrate the exhaustiveness floors asserted in
``protocols.rs``.

Stdlib only — no numpy/jax — so it runs anywhere pytest does.
"""

import copy
from dataclasses import dataclass, field

import pytest

PROGRESS, BLOCKED, DONE = "progress", "blocked", "done"


@dataclass
class Stats:
    executions: int = 0
    states_visited: int = 0
    preemption_pruned: int = 0
    max_interleaving_len: int = 0


@dataclass
class Violation:
    message: str
    schedule: list


class Checker:
    """Port of sync::model::Checker: bounded-DFS over all interleavings."""

    def __init__(self, max_preemptions=3, max_executions=2_000_000):
        self.max_preemptions = max_preemptions
        self.max_executions = max_executions

    def explore(self, model, invariant):
        stats, violation = self._search(model, invariant, stop_on_violation=False)
        assert stats.executions > 0, "explored zero complete interleavings"
        return stats

    def explore_collect(self, model, invariant):
        return self._search(model, invariant, stop_on_violation=True)

    def _search(self, model, invariant, stop_on_violation):
        stats = Stats()
        schedule = []
        first_violation = []

        init = model.init()
        msg = invariant(init)
        if msg is not None:
            v = Violation("initial state: " + msg, [])
            if stop_on_violation:
                return stats, v
            raise AssertionError(v.message)

        n = model.threads()

        def dfs(state, done, last, preemptions):
            if first_violation and stop_on_violation:
                return
            # probe runnability on clones (Blocked steps must not mutate,
            # so a runnable probe's clone doubles as the branch state)
            runnable = []
            for tid in range(n):
                if done[tid]:
                    continue
                branch = copy.deepcopy(state)
                step = model.step(tid, branch)
                if step != BLOCKED:
                    runnable.append((tid, branch, step))

            if not runnable:
                if all(done):
                    stats.executions += 1
                    assert stats.executions <= self.max_executions
                    stats.max_interleaving_len = max(
                        stats.max_interleaving_len, len(schedule)
                    )
                else:
                    stuck = [t for t in range(n) if not done[t]]
                    v = Violation(
                        f"deadlock: threads {stuck} blocked with no runnable peer "
                        f"after schedule {schedule}",
                        list(schedule),
                    )
                    if stop_on_violation:
                        if not first_violation:
                            first_violation.append(v)
                    else:
                        raise AssertionError(v.message)
                return

            last_still_runnable = last is not None and any(
                t == last for t, _, _ in runnable
            )
            for tid, branch, step in runnable:
                preempt = last_still_runnable and last != tid
                budget = preemptions + 1 if preempt else preemptions
                if budget > self.max_preemptions:
                    stats.preemption_pruned += 1
                    continue
                stats.states_visited += 1
                schedule.append(tid)
                msg = invariant(branch)
                if msg is not None:
                    v = Violation(
                        f"invariant violated: {msg} (schedule {schedule})",
                        list(schedule),
                    )
                    if stop_on_violation:
                        if not first_violation:
                            first_violation.append(v)
                        schedule.pop()
                        return
                    raise AssertionError(v.message)
                next_done = list(done)
                if step == DONE:
                    next_done[tid] = True
                dfs(branch, next_done, tid, budget)
                schedule.pop()

        dfs(init, [False] * n, None, 0)
        return stats, (first_violation[0] if first_violation else None)


# ---------------------------------------------------------------------------
# checker self-tests (ports of sync::model::tests)
# ---------------------------------------------------------------------------


class Counter:
    def __init__(self, per_thread):
        self.per_thread = per_thread

    def init(self):
        return {"value": 0, "pc": [0, 0]}

    def threads(self):
        return 2

    def step(self, tid, s):
        s["value"] += 1
        s["pc"][tid] += 1
        return DONE if s["pc"][tid] == self.per_thread else PROGRESS


def counter_invariant(s):
    if s["value"] != s["pc"][0] + s["pc"][1]:
        return f"value {s['value']} != pc sum"
    return None


def test_counter_explores_all_interleavings():
    stats = Checker(max_preemptions=10**9).explore(Counter(2), counter_invariant)
    assert stats.executions == 6  # C(4,2) interleavings of AABB
    assert stats.max_interleaving_len == 4


def test_preemption_bound_prunes():
    full = Checker(max_preemptions=10**9).explore(Counter(3), lambda s: None)
    bounded = Checker(max_preemptions=1).explore(Counter(3), lambda s: None)
    assert bounded.executions < full.executions
    assert bounded.preemption_pruned > 0
    assert bounded.executions >= 2


class AbBa:
    """Classic AB/BA lock-order deadlock."""

    def init(self):
        return {"a": None, "b": None, "pc": [0, 0]}

    def threads(self):
        return 2

    def step(self, tid, s):
        first, second = ("a", "b") if tid == 0 else ("b", "a")
        pc = s["pc"][tid]
        if pc == 0:
            if s[first] is not None:
                return BLOCKED
            s[first] = tid
        elif pc == 1:
            if s[second] is not None:
                return BLOCKED
            s[second] = tid
        else:
            s[first] = None
            s[second] = None
            s["pc"][tid] += 1
            return DONE
        s["pc"][tid] += 1
        return PROGRESS


def test_ab_ba_deadlock_detected():
    _, violation = Checker(max_preemptions=10**9).explore_collect(
        AbBa(), lambda s: None
    )
    assert violation is not None and "deadlock" in violation.message


# ---------------------------------------------------------------------------
# protocol (a): commit barriers vs. draining flusher + fault injection
# ---------------------------------------------------------------------------

FOOTER_PARTS = 2
COMMIT_EPOCHS = 2
W_PHASES = 5


class CommitFlush:
    def __init__(self, buggy):
        self.buggy = buggy

    def init(self):
        return {
            "queue": [],
            "footer_parts": [0] * (COMMIT_EPOCHS + 1),
            "flip": 0,
            "writer_pc": 0,
            "writer_done": False,
            "flusher_dead": False,
            "fault_fired": False,
        }

    def threads(self):
        return 3

    def step(self, tid, s):
        if tid == 0:  # writer
            if s["writer_done"]:
                return DONE
            if s["flusher_dead"]:
                s["writer_done"] = True
                return DONE
            epoch = s["writer_pc"] // W_PHASES + 1
            phase = s["writer_pc"] % W_PHASES
            if self.buggy:
                op = (
                    ("flip", epoch)
                    if phase == 0
                    else (("part", epoch) if phase in (1, 2) else None)
                )
            else:
                op = (
                    ("part", epoch)
                    if phase in (0, 1)
                    else (("flip", epoch) if phase == 3 else None)
                )
            if op is not None:
                s["queue"].append(op)
            elif s["queue"]:
                return BLOCKED  # durability barrier
            s["writer_pc"] += 1
            if s["writer_pc"] == COMMIT_EPOCHS * W_PHASES:
                s["writer_done"] = True
                return DONE
            return PROGRESS
        if tid == 1:  # flusher
            if s["flusher_dead"]:
                return DONE
            if not s["queue"]:
                return DONE if s["writer_done"] else BLOCKED
            kind, e = s["queue"].pop(0)
            if kind == "part":
                s["footer_parts"][e] += 1
            else:
                s["flip"] = e
            return PROGRESS
        # fault injector
        if not s["fault_fired"]:
            s["fault_fired"] = True
            s["flusher_dead"] = True
        return DONE


def commit_flush_invariant(s):
    if s["flip"] != 0 and s["footer_parts"][s["flip"]] != FOOTER_PARTS:
        return (
            f"superblock points at epoch {s['flip']} but only "
            f"{s['footer_parts'][s['flip']]}/{FOOTER_PARTS} footer parts are "
            f"durable — recovery would read a torn footer"
        )
    return None


def test_commit_flush_fixed_holds_on_every_interleaving(capsys):
    stats = Checker().explore(CommitFlush(buggy=False), commit_flush_invariant)
    print(f"\ncommit_flush fixed: {stats}")
    assert stats.executions >= 50
    assert stats.max_interleaving_len >= 10


def test_commit_flush_buggy_flip_caught():
    _, violation = Checker().explore_collect(
        CommitFlush(buggy=True), commit_flush_invariant
    )
    assert violation is not None and "torn footer" in violation.message


# ---------------------------------------------------------------------------
# protocol (b): epoch-pin retire/park/release vs. concurrent commit
# ---------------------------------------------------------------------------

PIN_COMMITS = 2
LIVE, PARKED, FREED = "live", "parked", "freed"


def _min_pin(pins):
    return min(pins) if pins else None


def _release_parked(s):
    floor = _min_pin(s["pins"])
    for ext in s["extents"]:
        if ext[1] == PARKED and (floor is None or ext[0] < floor):
            ext[1] = FREED


class PinRetire:
    def __init__(self, buggy):
        self.buggy = buggy

    def init(self):
        return {
            "epoch": 0,
            "pins": [],
            "extents": [],  # [tag, status] pairs
            "commits_done": 0,
            "reader_pc": 0,
            "reader_loaded": None,
        }

    def threads(self):
        return 2

    def step(self, tid, s):
        if tid == 0:  # committing writer
            if s["commits_done"] == PIN_COMMITS:
                return DONE
            tag = s["epoch"]
            s["epoch"] += 1
            mp = _min_pin(s["pins"])
            status = PARKED if (mp is not None and mp <= tag) else FREED
            s["extents"].append([tag, status])
            _release_parked(s)
            s["commits_done"] += 1
            return PROGRESS
        # reader: pin → read → unpin
        pc = s["reader_pc"]
        if pc == 0 and not self.buggy:
            s["pins"].append(s["epoch"])
            s["reader_pc"] = 2
            return PROGRESS
        if pc == 0:  # buggy: epoch load only
            s["reader_loaded"] = s["epoch"]
            s["reader_pc"] = 1
            return PROGRESS
        if pc == 1:  # buggy: pins insert as a second step
            s["pins"].append(s["reader_loaded"])
            s["reader_loaded"] = None
            s["reader_pc"] = 2
            return PROGRESS
        if pc == 2:  # the read
            s["reader_pc"] = 3
            return PROGRESS
        if pc == 3:  # unpin + release_parked
            s["pins"].pop()
            _release_parked(s)
            s["reader_pc"] = 4
            return DONE
        return DONE


def pin_retire_invariant(s):
    for tag, status in s["extents"]:
        if status == FREED:
            mp = _min_pin(s["pins"])
            if mp is not None and mp <= tag:
                return (
                    f"extent retired at epoch {tag} is freed while a pin at epoch "
                    f"{mp} <= {tag} is outstanding"
                )
    return None


def test_pin_retire_fixed_holds_on_every_interleaving(capsys):
    stats = Checker().explore(PinRetire(buggy=False), pin_retire_invariant)
    print(f"\npin_retire fixed: {stats}")
    assert stats.executions >= 10


def test_pin_retire_buggy_split_pin_caught():
    _, violation = Checker().explore_collect(
        PinRetire(buggy=True), pin_retire_invariant
    )
    assert violation is not None and "freed while a pin" in violation.message


# ---------------------------------------------------------------------------
# protocol (c): subscriber seeding vs. durable-watermark advance
# ---------------------------------------------------------------------------

PUB_SEQS = 3


class PubSeed:
    def __init__(self, buggy):
        self.buggy = buggy

    def init(self):
        return {
            "published": 0,
            "retained": [],
            "durable": 0,
            "delivered": [],
            "seed_from": 0,
            "registered": False,
            "pending_seed": None,
            "registrar_pc": 0,
        }

    def threads(self):
        return 3

    def step(self, tid, s):
        if tid == 0:  # publishing writer (on_batch under PubInner)
            if s["published"] == PUB_SEQS:
                return DONE
            s["published"] += 1
            s["retained"].append(s["published"])
            if s["registered"]:
                s["delivered"].append(s["published"])
            return DONE if s["published"] == PUB_SEQS else PROGRESS
        if tid == 1:  # flusher (on_durable: advance watermark, prune)
            if s["durable"] == s["published"]:
                return DONE if s["published"] == PUB_SEQS else BLOCKED
            s["durable"] += 1
            d = s["durable"]
            s["retained"] = [q for q in s["retained"] if q > d]
            return PROGRESS
        # registrar
        if not self.buggy:
            if s["registrar_pc"] == 0:
                s["delivered"] = list(s["retained"])
                s["seed_from"] = s["durable"]
                s["registered"] = True
                s["registrar_pc"] = 1
            return DONE
        if s["registrar_pc"] == 0:  # buggy: snapshot…
            s["pending_seed"] = (list(s["retained"]), s["durable"])
            s["registrar_pc"] = 1
            return PROGRESS
        seed, from_ = s["pending_seed"]  # …register later
        s["pending_seed"] = None
        s["delivered"] = seed
        s["seed_from"] = from_
        s["registered"] = True
        return DONE


def pub_seed_invariant(s):
    if not s["registered"]:
        return None
    for seq in range(s["seed_from"] + 1, s["published"] + 1):
        if seq not in s["delivered"]:
            return (
                f"subscriber seeded from watermark {s['seed_from']} is missing "
                f"seq {seq} (published through {s['published']}): gapped seed"
            )
    return None


def test_pub_seed_fixed_holds_on_every_interleaving(capsys):
    stats = Checker().explore(PubSeed(buggy=False), pub_seed_invariant)
    print(f"\npub_seed fixed: {stats}")
    assert stats.executions >= 20


def test_pub_seed_buggy_split_registration_caught():
    _, violation = Checker().explore_collect(PubSeed(buggy=True), pub_seed_invariant)
    assert violation is not None and "gapped seed" in violation.message


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-v", "-s"]))
