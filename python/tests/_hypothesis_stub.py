"""Offline fallback for the `hypothesis` API subset used by test_kernels.py.

The CI image installs real hypothesis; the hermetic build image has no
registry access, so `test_kernels.py` falls back to this deterministic
mini-driver: `@given(...)` draws `max_examples` cases from strategies with
a per-test seeded RNG (reproducible across runs) and reports the failing
case's drawn arguments.
"""

import functools
import random


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


class _Strategies:
    integers = staticmethod(_integers)
    sampled_from = staticmethod(_sampled_from)
    booleans = staticmethod(_booleans)
    floats = staticmethod(_floats)


strategies = _Strategies()

_DEFAULT_MAX_EXAMPLES = 20


def settings(**kwargs):
    """Decorator: attach run settings (only max_examples is honoured)."""

    def deco(fn):
        fn._hyp_settings = dict(kwargs)
        return fn

    return deco


def given(**strategy_kwargs):
    """Decorator: run the test once per drawn example."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_hyp_settings", {})
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for case in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (case {case}): {drawn!r}"
                    ) from e

        # pytest must not mistake the strategy parameters for fixtures: hide
        # the wrapped function's signature
        del wrapper.__wrapped__
        return wrapper

    return deco
