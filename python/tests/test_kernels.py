"""Pallas kernels (interpret=True) vs the pure-jnp oracle in kernels/ref.py.

This is the core L1 correctness signal: every kernel, over randomised batch
sizes, d-grid edges, parameter vectors and field contents (hypothesis), must
match the reference to float32 tolerance.
"""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # hermetic image: fall back to the offline mini-driver
    import _hypothesis_stub as hypothesis
    st = hypothesis.strategies
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, stencil

SETTINGS = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def make_params(rng, dt=None):
    dt_v = dt if dt is not None else rng.uniform(1e-4, 1e-2)
    return jnp.asarray(
        [
            dt_v,
            rng.uniform(0.05, 1.0),    # h
            rng.uniform(1e-4, 1e-1),   # nu
            rng.uniform(1e-4, 1e-1),   # alpha
            rng.uniform(-1.0, 1.0),    # beta_g
            rng.uniform(280.0, 300.0), # t_inf
            rng.uniform(-1.0, 1.0),    # q_int
            rng.uniform(0.5, 2.0),     # rho
            rng.uniform(0.5, 1.0),     # omega (jacobi damping)
            0.0, 0.0, 0.0,             # reserved
        ],
        dtype=jnp.float32,
    )


def halo_field(rng, b, n, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, (b, n + 2, n + 2, n + 2)),
                       dtype=jnp.float32)


def int_field(rng, b, n, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, (b, n, n, n)), dtype=jnp.float32)


def assert_close(a, b, rtol=2e-5, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  n=st.sampled_from([4, 8, 16]),
                  mode=st.sampled_from(["fused", "block"]))
def test_jacobi_matches_ref(seed, b, n, mode):
    rng = np.random.default_rng(seed)
    p, rhs, par = halo_field(rng, b, n), int_field(rng, b, n), make_params(rng)
    assert_close(stencil.jacobi(p, rhs, par, mode=mode), ref.jacobi(p, rhs, par))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  n=st.sampled_from([4, 8, 16]))
def test_residual_matches_ref(seed, b, n):
    rng = np.random.default_rng(seed)
    p, rhs, par = halo_field(rng, b, n), int_field(rng, b, n), make_params(rng)
    r_b, s_b = stencil.residual(p, rhs, par, mode="block")
    r_k, s_k = stencil.residual(p, rhs, par)
    assert_close(r_b, r_k)
    r_r, s_r = ref.residual(p, rhs, par)
    assert_close(r_k, r_r)
    assert_close(s_k, s_r, rtol=1e-3, atol=1e-3)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  n=st.sampled_from([4, 8, 16]))
def test_divergence_matches_ref(seed, b, n):
    rng = np.random.default_rng(seed)
    u, v, w = (halo_field(rng, b, n) for _ in range(3))
    par = make_params(rng)
    assert_close(stencil.divergence(u, v, w, par),
                 ref.divergence(u, v, w, par))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  n=st.sampled_from([4, 8, 16]))
def test_correct_matches_ref(seed, b, n):
    rng = np.random.default_rng(seed)
    u, v, w = (int_field(rng, b, n) for _ in range(3))
    p, par = halo_field(rng, b, n), make_params(rng)
    for got, want in zip(stencil.correct(u, v, w, p, par),
                         ref.correct(u, v, w, p, par)):
        assert_close(got, want)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 4),
                  n=st.sampled_from([4, 8, 16]))
def test_predictor_matches_ref(seed, b, n):
    rng = np.random.default_rng(seed)
    u, v, w = (halo_field(rng, b, n) for _ in range(3))
    t = halo_field(rng, b, n, 280.0, 320.0)
    par = make_params(rng)
    for mode in ("fused", "block"):
        for got, want in zip(stencil.predictor(u, v, w, t, par, mode=mode),
                             ref.predictor(u, v, w, t, par)):
            assert_close(got, want, rtol=1e-4, atol=1e-3)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  n=st.sampled_from([4, 8, 16]))
def test_restrict_matches_ref(seed, b, n):
    rng = np.random.default_rng(seed)
    f, par = int_field(rng, b, n), make_params(rng)
    assert_close(stencil.restrict_blocks(f, par), ref.restrict_blocks(f, par))


# ---------------------------------------------------------------------------
# analytic sanity checks — the oracles themselves must be right
# ---------------------------------------------------------------------------

def test_jacobi_fixed_point_is_solution():
    """If p solves the 7-point system exactly, a Jacobi sweep is identity."""
    rng = np.random.default_rng(0)
    p = halo_field(rng, 2, 8)
    par = make_params(rng)
    h = float(par[ref.P_H])
    rhs = np.asarray(ref.laplacian(p, h))  # rhs := ∇²p  ⇒ p is the solution
    out = ref.jacobi(p, jnp.asarray(rhs), par)
    assert_close(out, ref.interior(p), rtol=1e-4, atol=1e-4)


def test_residual_zero_for_exact_solution():
    rng = np.random.default_rng(1)
    p = halo_field(rng, 2, 8)
    par = make_params(rng)
    rhs = ref.laplacian(p, float(par[ref.P_H]))
    r, ssq = ref.residual(p, rhs, par)
    assert float(jnp.max(jnp.abs(r))) < 1e-3
    assert float(jnp.max(ssq)) < 1e-4


def test_divergence_of_constant_field_is_zero():
    par = make_params(np.random.default_rng(2))
    c = jnp.ones((1, 10, 10, 10), jnp.float32)
    assert float(jnp.max(jnp.abs(ref.divergence(c, 2 * c, -c, par)))) == 0.0


def test_divergence_linear_field_exact():
    """∇·(x, 2y, 3z) = 6, exactly representable by central differences."""
    n = 8
    par = make_params(np.random.default_rng(3), dt=1.0)
    par = par.at[ref.P_RHO].set(1.0)
    h = float(par[ref.P_H])
    idx = (np.arange(n + 2) - 0.5) * h
    x = np.broadcast_to(idx[:, None, None], (n + 2,) * 3)
    u = jnp.asarray(x[None], jnp.float32)
    v = jnp.asarray(2 * np.transpose(x, (1, 0, 2))[None], jnp.float32)
    w = jnp.asarray(3 * np.transpose(x, (2, 1, 0))[None], jnp.float32)
    assert_close(ref.divergence(u, v, w, par),
                 6.0 * jnp.ones((1, n, n, n)), rtol=1e-3, atol=1e-3)


def test_correct_then_divergence_reduces():
    """Projection with a converged p must reduce ‖∇·u‖ (periodic box)."""
    import compile.model as model

    rng = np.random.default_rng(4)
    n = 16
    par = jnp.asarray(
        [0.01, 1.0 / n, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.857, 0.0, 0.0, 0.0],
        jnp.float32)
    u, v, w = (int_field(rng, 1, n, -0.1, 0.1) for _ in range(3))
    t = int_field(rng, 1, n, 299.0, 301.0)
    un, vn, wn, _, _ = model.reference_step(u, v, w, t, par, n_jacobi=400)
    div0 = ref.divergence(model._wrap(u), model._wrap(v), model._wrap(w), par)
    div1 = ref.divergence(model._wrap(un), model._wrap(vn), model._wrap(wn), par)
    n0 = float(jnp.sqrt(jnp.mean(div0 ** 2)))
    n1 = float(jnp.sqrt(jnp.mean(div1 ** 2)))
    assert n1 < 0.35 * n0, (n0, n1)


def test_restrict_preserves_constant():
    c = 3.5 * jnp.ones((2, 8, 8, 8), jnp.float32)
    out = ref.restrict_blocks(c, None)
    assert_close(out, 3.5 * jnp.ones((2, 4, 4, 4)))


def test_predictor_diffusion_decays_peak():
    """Pure diffusion must strictly reduce an interior hot spot."""
    n = 8
    par = jnp.asarray(
        [1e-3, 0.1, 0.05, 0.05, 0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0],
        jnp.float32)
    z = jnp.zeros((1, n + 2, n + 2, n + 2), jnp.float32)
    t = z.at[0, 5, 5, 5].set(1.0)
    _, _, _, tn = ref.predictor(z, z, z, t, par)
    assert float(tn[0, 4, 4, 4]) < 1.0
    assert float(tn[0, 3, 4, 4]) > 0.0  # heat spread to a neighbour
