"""AOT bridge: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (`make artifacts`); Python never runs on the Rust
request path. The interchange format is HLO text, NOT a serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
image's xla_extension 0.5.1 (behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowering goes through stablehlo → XlaComputation with
``return_tuple=True`` — the Rust side unwraps the tuple positionally.

Outputs:
    artifacts/<entry>_b<B>_n<N>.hlo.txt   one module per entry point
    artifacts/manifest.json               what Rust loads: shapes, arity

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [-b 32] [-n 16]
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: pathlib.Path, batch: int, n: int, extra_batches=()):
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"n": n, "default_batch": batch, "entries": []}
    for b in sorted({batch, *extra_batches}):
        for name, (fn, specs, n_out) in model.entry_points(b, n).items():
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}_b{b}_n{n}.hlo.txt"
            (out_dir / fname).write_text(text)
            manifest["entries"].append(
                {
                    "name": name,
                    "file": fname,
                    "batch": b,
                    "n": n,
                    "inputs": [
                        {"shape": list(s.shape), "dtype": str(s.dtype)}
                        for s in specs
                    ],
                    "outputs": n_out,
                }
            )
            print(f"  {fname}: {len(text)} chars")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['entries'])} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("-b", "--batch", type=int, default=32,
                    help="primary d-grid batch size (runtime pads to this)")
    ap.add_argument("--extra-batches", type=int, nargs="*", default=[1],
                    help="additional batch sizes to lower (perf sweeps)")
    ap.add_argument("-n", "--n", type=int, default=16,
                    help="d-grid edge length (paper: 16)")
    args = ap.parse_args()
    lower_all(pathlib.Path(args.out_dir), args.batch, args.n,
              tuple(args.extra_batches))


if __name__ == "__main__":
    main()
