"""L2 — the JAX compute graph executed (after AOT lowering) by the Rust runtime.

The paper's CFD kernel advances the incompressible Navier–Stokes equations
with Boussinesq thermal coupling via Chorin's projection method (paper §2.1):

    1. predictor      u* = u + dt(ν∇²u − (u·∇)u + b),  T' likewise (energy eq.)
    2. divergence     rhs = (ρ/dt) ∇·u*
    3. Poisson solve  ∇²p = rhs        — multigrid-like V-cycle, orchestrated
                                          by Rust; the smoothing sweeps and
                                          residuals are the entry points here
    4. correct        u = u* − (dt/ρ)∇p

Steps 1, 2 and 4 are single fused artifacts; step 3's inner operations
(jacobi / residual / restrict) are separate artifacts invoked repeatedly by
the Rust V-cycle driver with per-level `h` passed in the params vector (the
d-grid shape is 16³ at *every* tree depth, so one artifact serves all
multigrid levels — this mirrors how the paper reuses the communication
schema as restriction/prolongation).

Each entry point delegates its stencil work to the L1 Pallas kernels in
`kernels/stencil.py`, so the Pallas body lowers into the same HLO module the
Rust runtime loads. Everything here is shape-specialised at AOT time to a
fixed batch size B and d-grid edge N (see `aot.py`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import stencil

F32 = jnp.float32


# Every entry point takes/returns plain arrays; Rust builds the input
# Literals and unpacks the (always-tuple) outputs positionally.

def jacobi(p, rhs, params):
    """One Jacobi smoothing sweep (multigrid smoother). → (p_new,)"""
    return (stencil.jacobi(p, rhs, params),)


def residual(p, rhs, params):
    """PPE residual field and per-grid Σr². → (r, ssq)"""
    r, ssq = stencil.residual(p, rhs, params)
    return (r, ssq)


def divergence(u, v, w, params):
    """PPE right-hand side (ρ/dt)∇·u*. → (rhs,)"""
    return (stencil.divergence(u, v, w, params),)


def correct(u, v, w, p, params):
    """Projection step. → (u, v, w)"""
    return stencil.correct(u, v, w, p, params)


def predictor(u, v, w, t, params):
    """Fused tentative-velocity + energy update. → (u*, v*, w*, T')"""
    return stencil.predictor(u, v, w, t, params)


def restrict(fine, params):
    """Full-weighting 2× restriction (bottom-up averaging). → (coarse,)"""
    return (stencil.restrict_blocks(fine, params),)


def _halo(b, n):
    return jax.ShapeDtypeStruct((b, n + 2, n + 2, n + 2), F32)


def _int(b, n):
    return jax.ShapeDtypeStruct((b, n, n, n), F32)


def _par():
    from .kernels import ref

    return jax.ShapeDtypeStruct((ref.PARAMS_LEN,), F32)


def entry_points(b: int, n: int):
    """The AOT manifest: name → (fn, input ShapeDtypeStructs, #outputs)."""
    return {
        "jacobi": (jacobi, [_halo(b, n), _int(b, n), _par()], 1),
        "residual": (residual, [_halo(b, n), _int(b, n), _par()], 2),
        "divergence": (divergence, [_halo(b, n)] * 3 + [_par()], 1),
        "correct": (correct, [_int(b, n)] * 3 + [_halo(b, n), _par()], 3),
        "predictor": (predictor, [_halo(b, n)] * 4 + [_par()], 4),
        "restrict": (restrict, [_int(b, n), _par()], 1),
    }


# ---------------------------------------------------------------------------
# pure-jnp composition used by tests: one full projection time step on a
# single periodic super-block (no tree, no halo exchange) — the physics
# oracle for the end-to-end integration tests.
# ---------------------------------------------------------------------------

def _wrap(x):
    """Periodic halo pad of an interior batch (B, N, N, N)."""
    return jnp.pad(x, ((0, 0), (1, 1), (1, 1), (1, 1)), mode="wrap")


def reference_step(u, v, w, t, params, n_jacobi: int = 50):
    """One complete Chorin step on periodic interiors — test oracle only."""
    from .kernels import ref

    us, vs, ws, tn = ref.predictor(_wrap(u), _wrap(v), _wrap(w), _wrap(t), params)
    rhs = ref.divergence(_wrap(us), _wrap(vs), _wrap(ws), params)
    rhs = rhs - jnp.mean(rhs, axis=(1, 2, 3), keepdims=True)  # solvability
    p = jnp.zeros_like(rhs)
    for _ in range(n_jacobi):
        p = ref.jacobi(_wrap(p), rhs, params)
    un, vn, wn = ref.correct(us, vs, ws, _wrap(p), params)
    return un, vn, wn, tn, p
