"""Pure-jnp reference oracle for the mpfluid compute kernels.

Every Pallas kernel in this package has its semantics defined HERE, by a
straightforward jax.numpy implementation. pytest (python/tests) asserts
allclose between each Pallas kernel (interpret=True) and these functions over
randomised shapes and seeds; the Rust integration test `runtime_golden`
additionally checks the AOT-compiled artifacts against a pure-Rust port of
the same formulas.

Conventions
-----------
All fields live on a *batch of d-grids*: arrays of shape ``(B, N+2, N+2, N+2)``
("halo-padded": one ghost cell per side, filled by the Rust exchange layer)
or ``(B, N, N, N)`` ("interior"). ``N`` is the d-grid edge length (16 in
production, per the paper). dtype is float32 throughout.

Scalar parameters are packed into a single ``(12,)`` float32 vector so the
AOT artifacts take a fixed input arity (slots 9-11 reserved):

    params = [dt, h, nu, alpha, beta_g, t_inf, q_int, rho, omega, _, _, _]

``omega`` is the damping factor of the Jacobi sweep: undamped Jacobi is not
a smoother for the 3-D 7-point Laplacian (the highest-frequency mode has
amplification −1), so the multigrid solver runs ω = 6/7.

The spatial discretisation is the paper's finite-volume scheme on regular
Cartesian blocks, which "locally degenerates into finite differences"
(paper §2.1): 7-point Laplacian, donor-cell upwind advection, central
pressure gradient/divergence, explicit Euler in time (Chorin projection).
"""

from __future__ import annotations

import jax.numpy as jnp

# Indices into the packed scalar-parameter vector.
P_DT, P_H, P_NU, P_ALPHA, P_BETA_G, P_TINF, P_QINT, P_RHO, P_OMEGA = range(9)
PARAMS_LEN = 12


# ---------------------------------------------------------------------------
# stencil helpers on halo-padded fields (B, N+2, N+2, N+2)
# ---------------------------------------------------------------------------

def interior(x):
    """Centre view: strip one halo cell from each face."""
    return x[:, 1:-1, 1:-1, 1:-1]


def shifts(x):
    """The six face-neighbour views of the interior (xm, xp, ym, yp, zm, zp)."""
    return (
        x[:, :-2, 1:-1, 1:-1],
        x[:, 2:, 1:-1, 1:-1],
        x[:, 1:-1, :-2, 1:-1],
        x[:, 1:-1, 2:, 1:-1],
        x[:, 1:-1, 1:-1, :-2],
        x[:, 1:-1, 1:-1, 2:],
    )


def laplacian(x, h):
    """7-point Laplacian of a halo-padded field, on the interior."""
    xm, xp, ym, yp, zm, zp = shifts(x)
    return (xm + xp + ym + yp + zm + zp - 6.0 * interior(x)) / (h * h)


def upwind_advect(q, u, v, w, h):
    """Donor-cell upwind advection term  (u·∇)q  on the interior.

    ``q, u, v, w`` are halo-padded; the advecting velocity is evaluated at
    the cell centre.
    """
    qc = interior(q)
    qxm, qxp, qym, qyp, qzm, qzp = shifts(q)
    uc, vc, wc = interior(u), interior(v), interior(w)
    ddx = jnp.where(uc > 0.0, (qc - qxm) / h, (qxp - qc) / h)
    ddy = jnp.where(vc > 0.0, (qc - qym) / h, (qyp - qc) / h)
    ddz = jnp.where(wc > 0.0, (qc - qzm) / h, (qzp - qc) / h)
    return uc * ddx + vc * ddy + wc * ddz


# ---------------------------------------------------------------------------
# kernel oracles — one per AOT entry point
# ---------------------------------------------------------------------------

def jacobi(p, rhs, params):
    """One damped Jacobi sweep for the pressure Poisson equation.

    Solves ∇²p = rhs:  p' = (1−ω)·p + ω·(Σ neighbours − h²·rhs) / 6.
    p: (B, N+2, N+2, N+2) halo-padded, rhs: (B, N, N, N) interior.
    Returns the updated interior (B, N, N, N).
    """
    h, omega = params[P_H], params[P_OMEGA]
    xm, xp, ym, yp, zm, zp = shifts(p)
    sweep = (xm + xp + ym + yp + zm + zp - h * h * rhs) / 6.0
    return (1.0 - omega) * interior(p) + omega * sweep


def residual(p, rhs, params):
    """PPE residual r = rhs − ∇²p on the interior, plus per-grid Σ r²."""
    h = params[P_H]
    r = rhs - laplacian(p, h)
    return r, jnp.sum(r * r, axis=(1, 2, 3))


def divergence(u, v, w, params):
    """PPE right-hand side:  (ρ/dt) ∇·u  in MAC (Harlow–Welch) form.

    Velocities are interpreted as face values u_{i+½} stored at cell index i
    (staggered scheme, the paper's reference [10]): backward differences here
    pair with the forward-difference gradient in :func:`correct` so that
    div∘grad is *exactly* the compact 7-point Laplacian used by
    :func:`jacobi` — making the discrete projection exact.

    u, v, w halo-padded; returns interior (B, N, N, N).
    """
    dt, h, rho = params[P_DT], params[P_H], params[P_RHO]
    du = u[:, 1:-1, 1:-1, 1:-1] - u[:, :-2, 1:-1, 1:-1]
    dv = v[:, 1:-1, 1:-1, 1:-1] - v[:, 1:-1, :-2, 1:-1]
    dw = w[:, 1:-1, 1:-1, 1:-1] - w[:, 1:-1, 1:-1, :-2]
    return (rho / dt) * (du + dv + dw) / h


def correct(u, v, w, p, params):
    """Chorin projection: subtract (dt/ρ) ∇p (forward differences, MAC).

    u, v, w: interior (B, N, N, N); p halo-padded. Returns corrected (u,v,w).
    """
    dt, h, rho = params[P_DT], params[P_H], params[P_RHO]
    c = dt / (rho * h)
    pc = interior(p)
    gx = p[:, 2:, 1:-1, 1:-1] - pc
    gy = p[:, 1:-1, 2:, 1:-1] - pc
    gz = p[:, 1:-1, 1:-1, 2:] - pc
    return u - c * gx, v - c * gy, w - c * gz


def predictor(u, v, w, t, params):
    """Fused explicit-Euler predictor: tentative velocity + energy equation.

    u* = u + dt( ν∇²u − (u·∇)u + b )        (momentum, eq. 2)
    T' = T + dt( α∇²T − (u·∇)T + q_int )    (energy,   eq. 3)

    Buoyancy (Boussinesq) acts on the w component: b_w = β·g·(T − T∞).
    All inputs halo-padded (B, N+2, N+2, N+2); returns interior
    (u*, v*, w*, T').
    """
    dt, h, nu = params[P_DT], params[P_H], params[P_NU]
    alpha, beta_g = params[P_ALPHA], params[P_BETA_G]
    t_inf, q_int = params[P_TINF], params[P_QINT]

    un = interior(u) + dt * (nu * laplacian(u, h) - upwind_advect(u, u, v, w, h))
    vn = interior(v) + dt * (nu * laplacian(v, h) - upwind_advect(v, u, v, w, h))
    wn = interior(w) + dt * (
        nu * laplacian(w, h)
        - upwind_advect(w, u, v, w, h)
        + beta_g * (interior(t) - t_inf)
    )
    tn = interior(t) + dt * (
        alpha * laplacian(t, h) - upwind_advect(t, u, v, w, h) + q_int
    )
    return un, vn, wn, tn


def restrict_blocks(fine, params):
    """Full-weighting restriction: average 2×2×2 fine cells to one coarse cell.

    fine: (B, N, N, N) interior with even N → (B, N/2, N/2, N/2).
    Mirrors the bottom-up averaging step of the paper's communication phase
    (used as the multigrid restriction operator, §2.2).
    """
    del params
    b, n, _, _ = fine.shape
    m = n // 2
    f = fine.reshape(b, m, 2, m, 2, m, 2)
    return f.mean(axis=(2, 4, 6))


ENTRY_ORACLES = {
    "jacobi": jacobi,
    "residual": residual,
    "divergence": divergence,
    "correct": correct,
    "predictor": predictor,
    "restrict": restrict_blocks,
}
