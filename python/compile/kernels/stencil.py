"""L1 — Pallas kernels for the mpfluid compute hot-spot.

The paper's compute phase spends >90 % of its time in 7-point stencil sweeps
over 16³ d-grids (pressure Poisson smoothing, §2.2); the remaining stencils
(predictor, divergence, projection) share the same access pattern. Each
kernel processes a *batch* of d-grids.

Two lowering modes (``MODE``, env ``MPFLUID_PALLAS_MODE``):

* ``"block"`` — the TPU-shaped schedule: the Pallas grid is the batch
  dimension and each program instance owns one halo-padded d-grid
  (18³·4 B ≈ 23 KiB; a full working set of ≤ 5 fields ≈ 115 KiB sits
  comfortably in VMEM). The BlockSpec expresses the HBM↔VMEM pipeline the
  paper expressed with per-process block decomposition. On a real TPU this
  is the mode to compile.
* ``"fused"`` (default) — one program instance covering the whole batch.
  In ``interpret=True`` mode (mandatory here: the CPU PJRT plugin cannot
  execute Mosaic custom-calls) the ``block`` grid lowers to a *serial* XLA
  while-loop over blocks, ~57× slower than the equivalent fused form; the
  fused kernel lowers to straight vectorised HLO. Since the CPU path is the
  production path in this reproduction, the AOT artifacts use ``fused``
  (perf pass, EXPERIMENTS.md §Perf). Numerics are identical — pytest checks
  both modes against the oracle.

The sweeps are elementwise/VPU work — there is deliberately no MXU use,
matching the paper's stencil (not matmul) hot-spot.

Semantics are defined by `ref.py`; the fused bodies literally apply the
reference formulas inside the kernel.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

F32 = jnp.float32

#: lowering mode: "fused" (CPU production) or "block" (TPU-shaped schedule)
MODE = os.environ.get("MPFLUID_PALLAS_MODE", "fused")


def _halo_spec(n):
    """BlockSpec for one halo-padded d-grid per program instance."""
    return pl.BlockSpec((1, n + 2, n + 2, n + 2), lambda b: (b, 0, 0, 0))


def _int_spec(n):
    """BlockSpec for one interior d-grid per program instance."""
    return pl.BlockSpec((1, n, n, n), lambda b: (b, 0, 0, 0))


def _par_spec():
    """BlockSpec for the shared scalar-parameter vector."""
    return pl.BlockSpec((ref.PARAMS_LEN,), lambda b: (0,))


def _sum_spec():
    """BlockSpec for a per-grid scalar output (shape (B,))."""
    return pl.BlockSpec((1,), lambda b: (b,))


def _field(n, b):
    return jax.ShapeDtypeStruct((b, n, n, n), F32)


# ---------------------------------------------------------------------------
# fused bodies: one program, whole batch — delegate to the ref formulas
# ---------------------------------------------------------------------------

def _jacobi_fused(p_ref, rhs_ref, par_ref, o_ref):
    o_ref[...] = ref.jacobi(p_ref[...], rhs_ref[...], par_ref[...])


def _residual_fused(p_ref, rhs_ref, par_ref, r_ref, ssq_ref):
    r, ssq = ref.residual(p_ref[...], rhs_ref[...], par_ref[...])
    r_ref[...] = r
    ssq_ref[...] = ssq


def _divergence_fused(u_ref, v_ref, w_ref, par_ref, o_ref):
    o_ref[...] = ref.divergence(u_ref[...], v_ref[...], w_ref[...], par_ref[...])


def _correct_fused(u_ref, v_ref, w_ref, p_ref, par_ref, uo_ref, vo_ref, wo_ref):
    u, v, w = ref.correct(u_ref[...], v_ref[...], w_ref[...], p_ref[...], par_ref[...])
    uo_ref[...] = u
    vo_ref[...] = v
    wo_ref[...] = w


def _predictor_fused(u_ref, v_ref, w_ref, t_ref, par_ref,
                     uo_ref, vo_ref, wo_ref, to_ref):
    u, v, w, t = ref.predictor(
        u_ref[...], v_ref[...], w_ref[...], t_ref[...], par_ref[...]
    )
    uo_ref[...] = u
    vo_ref[...] = v
    wo_ref[...] = w
    to_ref[...] = t


def _restrict_fused(f_ref, par_ref, o_ref):
    o_ref[...] = ref.restrict_blocks(f_ref[...], par_ref[...])


# ---------------------------------------------------------------------------
# block bodies: one program per d-grid (leading dim of every ref is 1)
# ---------------------------------------------------------------------------

def _jacobi_block(p_ref, rhs_ref, par_ref, o_ref):
    o_ref[...] = ref.jacobi(p_ref[...], rhs_ref[...], par_ref[...])


def _residual_block(p_ref, rhs_ref, par_ref, r_ref, ssq_ref):
    r, ssq = ref.residual(p_ref[...], rhs_ref[...], par_ref[...])
    r_ref[...] = r
    ssq_ref[...] = ssq


def _divergence_block(u_ref, v_ref, w_ref, par_ref, o_ref):
    o_ref[...] = ref.divergence(u_ref[...], v_ref[...], w_ref[...], par_ref[...])


def _correct_block(u_ref, v_ref, w_ref, p_ref, par_ref, uo_ref, vo_ref, wo_ref):
    u, v, w = ref.correct(u_ref[...], v_ref[...], w_ref[...], p_ref[...], par_ref[...])
    uo_ref[...] = u
    vo_ref[...] = v
    wo_ref[...] = w


def _predictor_block(u_ref, v_ref, w_ref, t_ref, par_ref,
                     uo_ref, vo_ref, wo_ref, to_ref):
    u, v, w, t = ref.predictor(
        u_ref[...], v_ref[...], w_ref[...], t_ref[...], par_ref[...]
    )
    uo_ref[...] = u
    vo_ref[...] = v
    wo_ref[...] = w
    to_ref[...] = t


def _restrict_block(f_ref, par_ref, o_ref):
    o_ref[...] = ref.restrict_blocks(f_ref[...], par_ref[...])


# ---------------------------------------------------------------------------
# pallas_call wrappers — public API, shape (B, ...) in / out
# ---------------------------------------------------------------------------

def _call(body_fused, body_block, ins, out_specs, out_shapes, in_specs, b, mode):
    """Dispatch between the fused single-program and per-block forms."""
    if (mode or MODE) == "fused":
        return pl.pallas_call(
            body_fused,
            out_shape=out_shapes,
            interpret=True,
        )(*ins)
    return pl.pallas_call(
        body_block,
        grid=(b,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=True,
    )(*ins)


@functools.partial(jax.jit, static_argnames=("mode",))
def jacobi(p, rhs, params, mode=None):
    b, npad = p.shape[0], p.shape[1]
    n = npad - 2
    return _call(
        _jacobi_fused,
        _jacobi_block,
        (p, rhs, params),
        _int_spec(n),
        _field(n, b),
        [_halo_spec(n), _int_spec(n), _par_spec()],
        b,
        mode,
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def residual(p, rhs, params, mode=None):
    b, npad = p.shape[0], p.shape[1]
    n = npad - 2
    return _call(
        _residual_fused,
        _residual_block,
        (p, rhs, params),
        [_int_spec(n), _sum_spec()],
        [_field(n, b), jax.ShapeDtypeStruct((b,), F32)],
        [_halo_spec(n), _int_spec(n), _par_spec()],
        b,
        mode,
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def divergence(u, v, w, params, mode=None):
    b, npad = u.shape[0], u.shape[1]
    n = npad - 2
    return _call(
        _divergence_fused,
        _divergence_block,
        (u, v, w, params),
        _int_spec(n),
        _field(n, b),
        [_halo_spec(n)] * 3 + [_par_spec()],
        b,
        mode,
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def correct(u, v, w, p, params, mode=None):
    b, n = u.shape[0], u.shape[1]
    return _call(
        _correct_fused,
        _correct_block,
        (u, v, w, p, params),
        [_int_spec(n)] * 3,
        [_field(n, b)] * 3,
        [_int_spec(n)] * 3 + [_halo_spec(n), _par_spec()],
        b,
        mode,
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def predictor(u, v, w, t, params, mode=None):
    b, npad = u.shape[0], u.shape[1]
    n = npad - 2
    return _call(
        _predictor_fused,
        _predictor_block,
        (u, v, w, t, params),
        [_int_spec(n)] * 4,
        [_field(n, b)] * 4,
        [_halo_spec(n)] * 4 + [_par_spec()],
        b,
        mode,
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def restrict_blocks(fine, params, mode=None):
    b, n = fine.shape[0], fine.shape[1]
    m = n // 2
    return _call(
        _restrict_fused,
        _restrict_block,
        (fine, params),
        _int_spec(m),
        _field(m, b),
        [_int_spec(n), _par_spec()],
        b,
        mode,
    )


ENTRY_KERNELS = {
    "jacobi": jacobi,
    "residual": residual,
    "divergence": divergence,
    "correct": correct,
    "predictor": predictor,
    "restrict": restrict_blocks,
}
