//! The compute-backend abstraction.
//!
//! The solver and coordinator are written against [`ComputeBackend`], with
//! two implementations:
//!
//! * [`RustBackend`] — the pure-Rust kernels from [`super`] applied per
//!   block (thread-parallel across the batch via
//!   [`crate::util::parallel_for`]). Always available; the test oracle.
//! * [`crate::runtime::PjrtBackend`] — executes the AOT-lowered Pallas/JAX
//!   artifacts through the PJRT CPU client. The production path.
//!
//! All methods operate on *batches* of d-grids flattened into contiguous
//! `f32` slices: halo-padded inputs are `b · (N+2)³` long, interiors
//! `b · N³`, with `N =` [`crate::DGRID_N`] fixed by the artifacts.

use super::{
    correct_block, divergence_block, int_len, jacobi_block, pad_len, predictor_block,
    residual_block, restrict_block, Params,
};
use crate::util::{parallel_for, SendPtr};
use crate::DGRID_N;

/// Convenience bundle of batch geometry (sizes in `f32` elements).
#[derive(Clone, Copy, Debug)]
pub struct BatchViews {
    pub b: usize,
    pub padded: usize,
    pub interior: usize,
}

impl BatchViews {
    pub fn new(b: usize) -> BatchViews {
        BatchViews {
            b,
            padded: pad_len(DGRID_N),
            interior: int_len(DGRID_N),
        }
    }
}

/// Backend-neutral interface to the six AOT entry points.
pub trait ComputeBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// The batch size this backend prefers (callers pad to a multiple).
    fn preferred_batch(&self) -> usize;

    /// One Jacobi sweep over `b` blocks.
    fn jacobi(&self, b: usize, p: &[f32], rhs: &[f32], par: &Params, out: &mut [f32]);

    /// Residual field + per-block Σr².
    fn residual(
        &self,
        b: usize,
        p: &[f32],
        rhs: &[f32],
        par: &Params,
        r: &mut [f32],
        ssq: &mut [f32],
    );

    /// PPE right-hand side from the tentative velocity.
    fn divergence(&self, b: usize, u: &[f32], v: &[f32], w: &[f32], par: &Params, out: &mut [f32]);

    /// Projection: corrected velocity = tentative − (dt/ρ)∇p.
    #[allow(clippy::too_many_arguments)]
    fn correct(
        &self,
        b: usize,
        u: &[f32],
        v: &[f32],
        w: &[f32],
        p: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
    );

    /// Fused tentative-velocity + energy update.
    #[allow(clippy::too_many_arguments)]
    fn predictor(
        &self,
        b: usize,
        u: &[f32],
        v: &[f32],
        w: &[f32],
        t: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
        to: &mut [f32],
    );

    /// Full-weighting 2× restriction of `b` interiors (N³ → (N/2)³ each).
    fn restrict(&self, b: usize, fine: &[f32], out: &mut [f32]);
}

/// Pure-Rust backend; thread-parallel across blocks in a batch.
#[derive(Debug, Default, Clone)]
pub struct RustBackend;

impl ComputeBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn preferred_batch(&self) -> usize {
        32
    }

    fn jacobi(&self, b: usize, p: &[f32], rhs: &[f32], par: &Params, out: &mut [f32]) {
        let v = BatchViews::new(b);
        let optr = SendPtr::new(out);
        parallel_for(b, |i| {
            // SAFETY: block i owns rows [i*interior, (i+1)*interior) —
            // one task per block, ranges pairwise disjoint.
            let o = unsafe { optr.slice(i * v.interior, v.interior) };
            jacobi_block(
                DGRID_N,
                &p[i * v.padded..(i + 1) * v.padded],
                &rhs[i * v.interior..(i + 1) * v.interior],
                par,
                o,
            );
        });
    }

    fn residual(
        &self,
        b: usize,
        p: &[f32],
        rhs: &[f32],
        par: &Params,
        r: &mut [f32],
        ssq: &mut [f32],
    ) {
        let v = BatchViews::new(b);
        let rptr = SendPtr::new(r);
        let sptr = SendPtr::new(ssq);
        parallel_for(b, |i| {
            // SAFETY: block i owns residual rows [i*interior, ...) and
            // the single ssq cell i — disjoint per task.
            let ro = unsafe { rptr.slice(i * v.interior, v.interior) };
            let so = unsafe { sptr.slice(i, 1) };
            so[0] = residual_block(
                DGRID_N,
                &p[i * v.padded..(i + 1) * v.padded],
                &rhs[i * v.interior..(i + 1) * v.interior],
                par,
                ro,
            );
        });
    }

    fn divergence(
        &self,
        b: usize,
        u: &[f32],
        v_: &[f32],
        w: &[f32],
        par: &Params,
        out: &mut [f32],
    ) {
        let v = BatchViews::new(b);
        let optr = SendPtr::new(out);
        parallel_for(b, |i| {
            // SAFETY: block i owns [i*interior, (i+1)*interior).
            let o = unsafe { optr.slice(i * v.interior, v.interior) };
            divergence_block(
                DGRID_N,
                &u[i * v.padded..(i + 1) * v.padded],
                &v_[i * v.padded..(i + 1) * v.padded],
                &w[i * v.padded..(i + 1) * v.padded],
                par,
                o,
            );
        });
    }

    fn correct(
        &self,
        b: usize,
        u: &[f32],
        v_: &[f32],
        w: &[f32],
        p: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
    ) {
        let v = BatchViews::new(b);
        uo.copy_from_slice(u);
        vo.copy_from_slice(v_);
        wo.copy_from_slice(w);
        let uptr = SendPtr::new(uo);
        let vptr = SendPtr::new(vo);
        let wptr = SendPtr::new(wo);
        parallel_for(b, |i| {
            // SAFETY: block i owns its interior range of each of the
            // three velocity buffers — disjoint per task per buffer.
            let a = unsafe { uptr.slice(i * v.interior, v.interior) };
            let bq = unsafe { vptr.slice(i * v.interior, v.interior) };
            let c = unsafe { wptr.slice(i * v.interior, v.interior) };
            correct_block(DGRID_N, a, bq, c, &p[i * v.padded..(i + 1) * v.padded], par);
        });
    }

    fn predictor(
        &self,
        b: usize,
        u: &[f32],
        v_: &[f32],
        w: &[f32],
        t: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
        to: &mut [f32],
    ) {
        let v = BatchViews::new(b);
        let uptr = SendPtr::new(uo);
        let vptr = SendPtr::new(vo);
        let wptr = SendPtr::new(wo);
        let tptr = SendPtr::new(to);
        parallel_for(b, |i| {
            // SAFETY: block i owns its interior range of each output
            // buffer (u, v, w, T) — disjoint per task per buffer.
            let a = unsafe { uptr.slice(i * v.interior, v.interior) };
            let bq = unsafe { vptr.slice(i * v.interior, v.interior) };
            let c = unsafe { wptr.slice(i * v.interior, v.interior) };
            let d = unsafe { tptr.slice(i * v.interior, v.interior) };
            predictor_block(
                DGRID_N,
                &u[i * v.padded..(i + 1) * v.padded],
                &v_[i * v.padded..(i + 1) * v.padded],
                &w[i * v.padded..(i + 1) * v.padded],
                &t[i * v.padded..(i + 1) * v.padded],
                par,
                a,
                bq,
                c,
                d,
            );
        });
    }

    fn restrict(&self, b: usize, fine: &[f32], out: &mut [f32]) {
        let v = BatchViews::new(b);
        let half = int_len(DGRID_N / 2);
        let optr = SendPtr::new(out);
        parallel_for(b, |i| {
            // SAFETY: block i owns the coarse rows [i*half, (i+1)*half).
            let o = unsafe { optr.slice(i * half, half) };
            restrict_block(DGRID_N, &fine[i * v.interior..(i + 1) * v.interior], o);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::physics::{int_len, pad_len};

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut v = vec![0.0f32; len];
        rng.fill_f32(&mut v, -0.5, 0.5);
        v
    }

    #[test]
    fn batched_jacobi_matches_per_block() {
        let b = 3;
        let par = Params::isothermal(0.01, 0.1, 0.0);
        let p = rand_vec(b * pad_len(DGRID_N), 1);
        let rhs = rand_vec(b * int_len(DGRID_N), 2);
        let be = RustBackend;
        let mut out = vec![0.0; b * int_len(DGRID_N)];
        be.jacobi(b, &p, &rhs, &par, &mut out);
        for i in 0..b {
            let mut single = vec![0.0; int_len(DGRID_N)];
            crate::physics::jacobi_block(
                DGRID_N,
                &p[i * pad_len(DGRID_N)..(i + 1) * pad_len(DGRID_N)],
                &rhs[i * int_len(DGRID_N)..(i + 1) * int_len(DGRID_N)],
                &par,
                &mut single,
            );
            assert_eq!(
                &out[i * int_len(DGRID_N)..(i + 1) * int_len(DGRID_N)],
                &single[..]
            );
        }
    }

    #[test]
    fn batched_residual_ssq_positive() {
        let b = 2;
        let par = Params::isothermal(0.01, 0.1, 0.0);
        let p = rand_vec(b * pad_len(DGRID_N), 5);
        let rhs = rand_vec(b * int_len(DGRID_N), 6);
        let be = RustBackend;
        let mut r = vec![0.0; b * int_len(DGRID_N)];
        let mut ssq = vec![0.0; b];
        be.residual(b, &p, &rhs, &par, &mut r, &mut ssq);
        assert!(ssq.iter().all(|&s| s > 0.0));
        let manual: f32 = r[..int_len(DGRID_N)].iter().map(|x| x * x).sum();
        assert!((manual - ssq[0]).abs() / manual < 1e-4);
    }

    #[test]
    fn batched_predictor_matches_single() {
        let b = 2;
        let par = Params {
            dt: 0.01,
            h: 0.1,
            nu: 0.02,
            alpha: 0.01,
            beta_g: 0.3,
            t_inf: 300.0,
            q_int: 0.1,
            rho: 1.0,
            omega: 1.0,
        };
        let u = rand_vec(b * pad_len(DGRID_N), 10);
        let v = rand_vec(b * pad_len(DGRID_N), 11);
        let w = rand_vec(b * pad_len(DGRID_N), 12);
        let t = rand_vec(b * pad_len(DGRID_N), 13);
        let be = RustBackend;
        let mut uo = vec![0.0; b * int_len(DGRID_N)];
        let mut vo = vec![0.0; b * int_len(DGRID_N)];
        let mut wo = vec![0.0; b * int_len(DGRID_N)];
        let mut to = vec![0.0; b * int_len(DGRID_N)];
        be.predictor(b, &u, &v, &w, &t, &par, &mut uo, &mut vo, &mut wo, &mut to);
        // second block independently
        let (mut u1, mut v1, mut w1, mut t1) = (
            vec![0.0; int_len(DGRID_N)],
            vec![0.0; int_len(DGRID_N)],
            vec![0.0; int_len(DGRID_N)],
            vec![0.0; int_len(DGRID_N)],
        );
        predictor_block(
            DGRID_N,
            &u[pad_len(DGRID_N)..],
            &v[pad_len(DGRID_N)..],
            &w[pad_len(DGRID_N)..],
            &t[pad_len(DGRID_N)..],
            &par,
            &mut u1,
            &mut v1,
            &mut w1,
            &mut t1,
        );
        assert_eq!(&uo[int_len(DGRID_N)..], &u1[..]);
        assert_eq!(&to[int_len(DGRID_N)..], &t1[..]);
    }

    #[test]
    fn batched_restrict_shape() {
        let b = 4;
        let be = RustBackend;
        let fine = vec![2.0f32; b * int_len(DGRID_N)];
        let mut out = vec![0.0f32; b * int_len(DGRID_N / 2)];
        be.restrict(b, &fine, &mut out);
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
    }
}
