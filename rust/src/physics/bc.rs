//! Boundary conditions.
//!
//! Two mechanisms, both Rust-side (the kernels never see boundaries):
//!
//! * **Domain-face halo fills** — d-grids whose face lies on the physical
//!   domain boundary get their ghost layer filled from a per-face,
//!   per-variable boundary specification (Dirichlet / zero-gradient
//!   Neumann). This is how channel inflow/outflow and wall conditions are
//!   realised.
//! * **Cell-type masks** — obstacle geometry (the Schäfer–Turek cylinder,
//!   the operation theatre's lamps and bodies) is voxelised into
//!   [`CellType`](crate::tree::dgrid::CellType) entries; after every update
//!   solid cells are reset (no-slip velocity, frozen temperature), which is
//!   the steering hook for "moving geometry" commands.


use crate::nbs::Face;
use crate::tree::dgrid::{iidx, pidx, CellType, DGrid, FieldSet, NPAD};
use crate::{var, DGRID_N, NVAR};

/// Boundary condition for one variable on one domain face.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum VarBc {
    /// Ghost set so the face value equals the given constant
    /// (`ghost = 2·value − interior`).
    Dirichlet(f32),
    /// Zero gradient: `ghost = interior`.
    Neumann,
}

/// Boundary conditions for all [`NVAR`] variables on one face.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaceBc {
    pub per_var: [VarBc; NVAR],
}

impl FaceBc {
    /// No-slip adiabatic wall: velocities 0, pressure & temperature Neumann.
    pub fn wall() -> FaceBc {
        let mut per_var = [VarBc::Neumann; NVAR];
        per_var[var::U] = VarBc::Dirichlet(0.0);
        per_var[var::V] = VarBc::Dirichlet(0.0);
        per_var[var::W] = VarBc::Dirichlet(0.0);
        FaceBc { per_var }
    }

    /// Velocity inflow along +x with speed `u_in` at temperature `t_in`.
    pub fn inflow(u_in: f32, t_in: f32) -> FaceBc {
        let mut per_var = [VarBc::Neumann; NVAR];
        per_var[var::U] = VarBc::Dirichlet(u_in);
        per_var[var::V] = VarBc::Dirichlet(0.0);
        per_var[var::W] = VarBc::Dirichlet(0.0);
        per_var[var::T] = VarBc::Dirichlet(t_in);
        FaceBc { per_var }
    }

    /// Zero-gradient outflow with fixed reference pressure.
    pub fn outflow() -> FaceBc {
        let mut per_var = [VarBc::Neumann; NVAR];
        per_var[var::P] = VarBc::Dirichlet(0.0);
        FaceBc { per_var }
    }

    /// Isothermal no-slip wall at temperature `t`.
    pub fn wall_at(t: f32) -> FaceBc {
        let mut f = FaceBc::wall();
        f.per_var[var::T] = VarBc::Dirichlet(t);
        f
    }
}

/// Per-face boundary specification for the whole domain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DomainBc {
    /// Indexed by `Face as usize` in [XM, XP, YM, YP, ZM, ZP] order.
    pub faces: [FaceBc; 6],
}

impl DomainBc {
    pub fn all_walls() -> DomainBc {
        DomainBc {
            faces: [FaceBc::wall(); 6],
        }
    }

    /// Channel along x: inflow at x⁻, outflow at x⁺, walls elsewhere.
    pub fn channel(u_in: f32, t_in: f32) -> DomainBc {
        let mut faces = [FaceBc::wall(); 6];
        faces[Face::XM as usize] = FaceBc::inflow(u_in, t_in);
        faces[Face::XP as usize] = FaceBc::outflow();
        DomainBc { faces }
    }

    pub fn face(&self, f: Face) -> &FaceBc {
        &self.faces[f as usize]
    }

    pub fn face_mut(&mut self, f: Face) -> &mut FaceBc {
        &mut self.faces[f as usize]
    }
}

/// Iterate the halo cells of `face` together with their adjacent interior
/// cells, calling `f(ghost_idx, interior_idx)`.
fn for_face_pairs(face: Face, mut f: impl FnMut(usize, usize)) {
    let n = DGRID_N;
    let (g, i1) = match face.dir() {
        -1 => (0usize, 1usize),
        _ => (NPAD - 1, NPAD - 2),
    };
    for a in 0..NPAD {
        for b in 0..NPAD {
            let (gi, ii_) = match face.axis() {
                0 => (pidx(g, a, b), pidx(i1, a, b)),
                1 => (pidx(a, g, b), pidx(a, i1, b)),
                _ => (pidx(a, b, g), pidx(a, b, i1)),
            };
            f(gi, ii_);
        }
    }
    let _ = n;
}

/// Fill the ghost layer of `face` on every variable of `fs` according to
/// the face's boundary specification.
pub fn apply_face_bc(fs: &mut FieldSet, face: Face, bc: &FaceBc) {
    for (v, spec) in bc.per_var.iter().enumerate() {
        let field = fs.var_mut(v);
        match spec {
            VarBc::Dirichlet(val) => {
                for_face_pairs(face, |g, i| field[g] = 2.0 * val - field[i]);
            }
            VarBc::Neumann => {
                for_face_pairs(face, |g, i| field[g] = field[i]);
            }
        }
    }
}

/// Enforce solid-cell constraints on the *current* generation: no-slip
/// velocity, temperature frozen at the previous value (heated solids were
/// initialised to their fixed temperature and therefore stay there).
pub fn apply_solid_mask(g: &mut DGrid) {
    for i in 0..DGRID_N {
        for j in 0..DGRID_N {
            for k in 0..DGRID_N {
                if g.cell_type(i, j, k).is_solid() {
                    let p = pidx(i + 1, j + 1, k + 1);
                    g.cur.var_mut(var::U)[p] = 0.0;
                    g.cur.var_mut(var::V)[p] = 0.0;
                    g.cur.var_mut(var::W)[p] = 0.0;
                    let t_prev = g.prev.var(var::T)[p];
                    g.cur.var_mut(var::T)[p] = t_prev;
                }
            }
        }
    }
}

/// Voxelise a solid sphere (cylinder in thin domains) into the cell types of
/// a d-grid. `centre`/`radius` in physical coordinates; cells whose centre
/// lies inside become `kind`. For heated solids the fixed temperature is
/// written into all three field generations. Returns the number of cells
/// marked.
pub fn voxelise_sphere(
    g: &mut DGrid,
    bbox: &crate::tree::BBox,
    centre: [f64; 3],
    radius: f64,
    kind: CellType,
    temp: Option<f32>,
    ignore_axis: Option<usize>,
) -> usize {
    let mut count = 0;
    let h = [
        bbox.extent(0) / DGRID_N as f64,
        bbox.extent(1) / DGRID_N as f64,
        bbox.extent(2) / DGRID_N as f64,
    ];
    for i in 0..DGRID_N {
        for j in 0..DGRID_N {
            for k in 0..DGRID_N {
                let c = [
                    bbox.min[0] + (i as f64 + 0.5) * h[0],
                    bbox.min[1] + (j as f64 + 0.5) * h[1],
                    bbox.min[2] + (k as f64 + 0.5) * h[2],
                ];
                let mut d2 = 0.0;
                for a in 0..3 {
                    if Some(a) == ignore_axis {
                        continue;
                    }
                    d2 += (c[a] - centre[a]).powi(2);
                }
                if d2 <= radius * radius {
                    g.cell_type[iidx(i, j, k)] = kind as u8;
                    if let Some(t) = temp {
                        let p = pidx(i + 1, j + 1, k + 1);
                        g.cur.var_mut(var::T)[p] = t;
                        g.prev.var_mut(var::T)[p] = t;
                        g.temp.var_mut(var::T)[p] = t;
                    }
                    count += 1;
                }
            }
        }
    }
    count
}

/// Clear all solid cells from a d-grid (used when steering moves geometry).
pub fn clear_solids(g: &mut DGrid) {
    for ct in g.cell_type.iter_mut() {
        if CellType::from_u8(*ct).is_solid() {
            *ct = CellType::Fluid as u8;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::uid::{LocCode, Uid};
    use crate::tree::BBox;

    fn grid() -> DGrid {
        DGrid::new(Uid::new(0, 0, LocCode::ROOT))
    }

    #[test]
    fn dirichlet_face_value_is_average() {
        let mut g = grid();
        for x in g.cur.var_mut(var::U).iter_mut() {
            *x = 3.0;
        }
        apply_face_bc(&mut g.cur, Face::XM, &FaceBc::inflow(1.0, 300.0));
        // ghost = 2*1 - 3 = -1 ⇒ face average (ghost+interior)/2 = 1
        let ghost = g.cur.var(var::U)[pidx(0, 5, 5)];
        let interior = g.cur.var(var::U)[pidx(1, 5, 5)];
        assert_eq!((ghost + interior) / 2.0, 1.0);
    }

    #[test]
    fn neumann_copies_interior() {
        let mut g = grid();
        g.cur.var_mut(var::P)[pidx(1, 4, 4)] = 7.0;
        apply_face_bc(&mut g.cur, Face::XM, &FaceBc::wall());
        assert_eq!(g.cur.var(var::P)[pidx(0, 4, 4)], 7.0);
    }

    #[test]
    fn wall_noslip_zeroes_face_velocity() {
        let mut g = grid();
        for x in g.cur.var_mut(var::V).iter_mut() {
            *x = 2.0;
        }
        apply_face_bc(&mut g.cur, Face::ZP, &FaceBc::wall());
        let ghost = g.cur.var(var::V)[pidx(5, 5, NPAD - 1)];
        let interior = g.cur.var(var::V)[pidx(5, 5, NPAD - 2)];
        assert_eq!(ghost + interior, 0.0);
    }

    #[test]
    fn solid_mask_zeroes_velocity_and_freezes_t() {
        let mut g = grid();
        g.set_cell_type(2, 2, 2, CellType::HeatedSolid);
        let p = pidx(3, 3, 3);
        g.prev.var_mut(var::T)[p] = 350.0;
        g.cur.var_mut(var::T)[p] = 123.0;
        g.cur.var_mut(var::U)[p] = 9.0;
        apply_solid_mask(&mut g);
        assert_eq!(g.cur.var(var::U)[p], 0.0);
        assert_eq!(g.cur.var(var::T)[p], 350.0);
    }

    #[test]
    fn voxelise_sphere_marks_cells_and_temperature() {
        let mut g = grid();
        let bbox = BBox::unit();
        let n = voxelise_sphere(
            &mut g,
            &bbox,
            [0.5, 0.5, 0.5],
            0.2,
            CellType::HeatedSolid,
            Some(330.0),
            None,
        );
        assert!(n > 0);
        // centre cell marked
        assert!(g.cell_type(8, 8, 8).is_solid());
        assert_eq!(g.cur.var(var::T)[pidx(9, 9, 9)], 330.0);
        // corner cell untouched
        assert_eq!(g.cell_type(0, 0, 0), CellType::Fluid);
    }

    #[test]
    fn voxelise_cylinder_ignores_axis() {
        let mut g = grid();
        let bbox = BBox::unit();
        voxelise_sphere(
            &mut g,
            &bbox,
            [0.5, 0.5, 0.0],
            0.15,
            CellType::Solid,
            None,
            Some(2),
        );
        // cylinder along z: both ends marked
        assert!(g.cell_type(8, 8, 0).is_solid());
        assert!(g.cell_type(8, 8, 15).is_solid());
    }

    #[test]
    fn clear_solids_resets() {
        let mut g = grid();
        g.set_cell_type(1, 1, 1, CellType::Solid);
        clear_solids(&mut g);
        assert_eq!(g.cell_type(1, 1, 1), CellType::Fluid);
    }

    #[test]
    fn channel_bc_layout() {
        let bc = DomainBc::channel(1.5, 293.0);
        assert_eq!(
            bc.face(Face::XM).per_var[var::U],
            VarBc::Dirichlet(1.5)
        );
        assert_eq!(bc.face(Face::XP).per_var[var::P], VarBc::Dirichlet(0.0));
        assert_eq!(bc.face(Face::YM).per_var[var::U], VarBc::Dirichlet(0.0));
    }
}
