//! Pure-Rust reference implementation of the compute kernels.
//!
//! This is the Rust-side twin of `python/compile/kernels/ref.py` — the same
//! discretisation (7-point Laplacian, donor-cell upwind advection, MAC
//! divergence/gradient pair, explicit Euler) written as straightforward
//! loops. It serves three purposes:
//!
//! 1. **Golden oracle**: the integration test `runtime_golden` checks the
//!    AOT-compiled Pallas artifacts against these functions on identical
//!    inputs — closing the L1↔L3 loop.
//! 2. **Fallback backend**: every part of the system (solver, examples,
//!    benches) runs without artifacts present, via
//!    [`RustBackend`]; the PJRT backend in [`crate::runtime`] is selected
//!    when artifacts are available.
//! 3. **Boundary conditions**: cell-type masking and physical-boundary halo
//!    fills live here (they are Rust-side concerns in the three-layer
//!    split; the kernels only see fluid cells).

pub mod backend;
pub mod bc;


pub use backend::{BatchViews, ComputeBackend, RustBackend};

/// Scalar parameters shared by all kernels; the order of
/// [`Params::to_vec`] matches `ref.py`'s packed vector.
#[derive(Clone, Copy, Debug)]
pub struct Params {
    /// Time-step length.
    pub dt: f32,
    /// Grid spacing at the level being operated on.
    pub h: f32,
    /// Kinematic viscosity ν.
    pub nu: f32,
    /// Heat diffusion coefficient α = k/(ρ c_p).
    pub alpha: f32,
    /// Buoyancy factor β·g (Boussinesq, applied to w).
    pub beta_g: f32,
    /// Reference temperature T∞ of the undisturbed fluid.
    pub t_inf: f32,
    /// Internal heat generation q_int/(ρ c_p).
    pub q_int: f32,
    /// Fluid density ρ∞.
    pub rho: f32,
    /// Jacobi damping factor ω (1 = undamped; the multigrid smoother uses
    /// 6/7 — undamped Jacobi does not smooth the 3-D 7-point Laplacian).
    pub omega: f32,
}

impl Params {
    /// Packed parameter vector in the layout `kernels/ref.py` fixes
    /// (12 slots, the last three reserved).
    pub fn to_vec(&self) -> [f32; 12] {
        [
            self.dt,
            self.h,
            self.nu,
            self.alpha,
            self.beta_g,
            self.t_inf,
            self.q_int,
            self.rho,
            self.omega,
            0.0,
            0.0,
            0.0,
        ]
    }

    /// Copy with a different grid spacing (multigrid level change).
    pub fn at_h(&self, h: f32) -> Params {
        Params { h, ..*self }
    }

    /// Neutral parameters for isothermal flow tests.
    pub fn isothermal(dt: f32, h: f32, nu: f32) -> Params {
        Params {
            dt,
            h,
            nu,
            alpha: 0.0,
            beta_g: 0.0,
            t_inf: 0.0,
            q_int: 0.0,
            rho: 1.0,
            omega: 1.0,
        }
    }
}

/// Edge length helpers for a halo-padded block of interior size `n`.
#[inline(always)]
pub fn pad_len(n: usize) -> usize {
    (n + 2) * (n + 2) * (n + 2)
}

#[inline(always)]
pub fn int_len(n: usize) -> usize {
    n * n * n
}

#[inline(always)]
fn pi(n: usize, i: usize, j: usize, k: usize) -> usize {
    (i * (n + 2) + j) * (n + 2) + k
}

#[inline(always)]
fn ii(n: usize, i: usize, j: usize, k: usize) -> usize {
    (i * n + j) * n + k
}

// ---------------------------------------------------------------------------
// single-block kernels (shape (n+2)³ halo-padded in, n³ interior out)
// ---------------------------------------------------------------------------

/// One damped Jacobi sweep:
/// `out = (1−ω)·p + ω·(Σ neighbours − h²·rhs)/6` (interior).
pub fn jacobi_block(n: usize, p: &[f32], rhs: &[f32], par: &Params, out: &mut [f32]) {
    let h2 = par.h * par.h;
    let om = par.omega;
    for i in 1..=n {
        for j in 1..=n {
            for k in 1..=n {
                let nb = p[pi(n, i - 1, j, k)]
                    + p[pi(n, i + 1, j, k)]
                    + p[pi(n, i, j - 1, k)]
                    + p[pi(n, i, j + 1, k)]
                    + p[pi(n, i, j, k - 1)]
                    + p[pi(n, i, j, k + 1)];
                let sweep = (nb - h2 * rhs[ii(n, i - 1, j - 1, k - 1)]) / 6.0;
                out[ii(n, i - 1, j - 1, k - 1)] =
                    (1.0 - om) * p[pi(n, i, j, k)] + om * sweep;
            }
        }
    }
}

/// PPE residual `r = rhs − ∇²p`; returns Σ r² over the block.
pub fn residual_block(n: usize, p: &[f32], rhs: &[f32], par: &Params, r: &mut [f32]) -> f32 {
    let h2 = par.h * par.h;
    let mut ssq = 0.0f32;
    for i in 1..=n {
        for j in 1..=n {
            for k in 1..=n {
                let nb = p[pi(n, i - 1, j, k)]
                    + p[pi(n, i + 1, j, k)]
                    + p[pi(n, i, j - 1, k)]
                    + p[pi(n, i, j + 1, k)]
                    + p[pi(n, i, j, k - 1)]
                    + p[pi(n, i, j, k + 1)];
                let lap = (nb - 6.0 * p[pi(n, i, j, k)]) / h2;
                let idx = ii(n, i - 1, j - 1, k - 1);
                let rv = rhs[idx] - lap;
                r[idx] = rv;
                ssq += rv * rv;
            }
        }
    }
    ssq
}

/// MAC divergence rhs: `(ρ/dt)·(backward differences of u,v,w)/h`.
pub fn divergence_block(
    n: usize,
    u: &[f32],
    v: &[f32],
    w: &[f32],
    par: &Params,
    out: &mut [f32],
) {
    let c = par.rho / (par.dt * par.h);
    for i in 1..=n {
        for j in 1..=n {
            for k in 1..=n {
                let du = u[pi(n, i, j, k)] - u[pi(n, i - 1, j, k)];
                let dv = v[pi(n, i, j, k)] - v[pi(n, i, j - 1, k)];
                let dw = w[pi(n, i, j, k)] - w[pi(n, i, j, k - 1)];
                out[ii(n, i - 1, j - 1, k - 1)] = c * (du + dv + dw);
            }
        }
    }
}

/// MAC projection: `q -= (dt/ρ)·(forward pressure difference)/h`.
/// `u, v, w` are interiors; `p` is halo-padded.
pub fn correct_block(
    n: usize,
    u: &mut [f32],
    v: &mut [f32],
    w: &mut [f32],
    p: &[f32],
    par: &Params,
) {
    let c = par.dt / (par.rho * par.h);
    for i in 1..=n {
        for j in 1..=n {
            for k in 1..=n {
                let pc = p[pi(n, i, j, k)];
                let idx = ii(n, i - 1, j - 1, k - 1);
                u[idx] -= c * (p[pi(n, i + 1, j, k)] - pc);
                v[idx] -= c * (p[pi(n, i, j + 1, k)] - pc);
                w[idx] -= c * (p[pi(n, i, j, k + 1)] - pc);
            }
        }
    }
}

#[inline(always)]
fn upwind(n: usize, q: &[f32], vel: f32, h: f32, a: usize, b: usize, c: usize, axis: usize) -> f32 {
    let (m, p) = match axis {
        0 => (pi(n, a - 1, b, c), pi(n, a + 1, b, c)),
        1 => (pi(n, a, b - 1, c), pi(n, a, b + 1, c)),
        _ => (pi(n, a, b, c - 1), pi(n, a, b, c + 1)),
    };
    let qc = q[pi(n, a, b, c)];
    if vel > 0.0 {
        (qc - q[m]) / h
    } else {
        (q[p] - qc) / h
    }
}

/// Fused predictor: tentative velocity (momentum eq.) + energy equation.
#[allow(clippy::too_many_arguments)]
pub fn predictor_block(
    n: usize,
    u: &[f32],
    v: &[f32],
    w: &[f32],
    t: &[f32],
    par: &Params,
    uo: &mut [f32],
    vo: &mut [f32],
    wo: &mut [f32],
    to: &mut [f32],
) {
    let h2 = par.h * par.h;
    let lap = |q: &[f32], i: usize, j: usize, k: usize| {
        (q[pi(n, i - 1, j, k)]
            + q[pi(n, i + 1, j, k)]
            + q[pi(n, i, j - 1, k)]
            + q[pi(n, i, j + 1, k)]
            + q[pi(n, i, j, k - 1)]
            + q[pi(n, i, j, k + 1)]
            - 6.0 * q[pi(n, i, j, k)])
            / h2
    };
    for i in 1..=n {
        for j in 1..=n {
            for k in 1..=n {
                let (uc, vc, wc, tc) = (
                    u[pi(n, i, j, k)],
                    v[pi(n, i, j, k)],
                    w[pi(n, i, j, k)],
                    t[pi(n, i, j, k)],
                );
                let adv = |q: &[f32]| {
                    uc * upwind(n, q, uc, par.h, i, j, k, 0)
                        + vc * upwind(n, q, vc, par.h, i, j, k, 1)
                        + wc * upwind(n, q, wc, par.h, i, j, k, 2)
                };
                let idx = ii(n, i - 1, j - 1, k - 1);
                uo[idx] = uc + par.dt * (par.nu * lap(u, i, j, k) - adv(u));
                vo[idx] = vc + par.dt * (par.nu * lap(v, i, j, k) - adv(v));
                wo[idx] = wc
                    + par.dt
                        * (par.nu * lap(w, i, j, k) - adv(w)
                            + par.beta_g * (tc - par.t_inf));
                to[idx] =
                    tc + par.dt * (par.alpha * lap(t, i, j, k) - adv(t) + par.q_int);
            }
        }
    }
}

/// Full-weighting restriction: average 2×2×2 fine cells. `fine` is an `n³`
/// interior, `out` is `(n/2)³`.
pub fn restrict_block(n: usize, fine: &[f32], out: &mut [f32]) {
    let m = n / 2;
    for i in 0..m {
        for j in 0..m {
            for k in 0..m {
                let mut s = 0.0f32;
                for (di, dj, dk) in itertools_cube() {
                    s += fine[ii(n, 2 * i + di, 2 * j + dj, 2 * k + dk)];
                }
                out[(i * m + j) * m + k] = s / 8.0;
            }
        }
    }
}

#[inline(always)]
fn itertools_cube() -> [(usize, usize, usize); 8] {
    [
        (0, 0, 0),
        (0, 0, 1),
        (0, 1, 0),
        (0, 1, 1),
        (1, 0, 0),
        (1, 0, 1),
        (1, 1, 0),
        (1, 1, 1),
    ]
}

/// Piecewise-constant prolongation: inject each coarse cell of the `m³`
/// octant `src` into 2×2×2 fine cells of the `n³` output (`n = 2m`),
/// *adding* (multigrid coarse-level correction).
pub fn prolong_add_block(m: usize, src: &[f32], out: &mut [f32]) {
    let n = 2 * m;
    for i in 0..m {
        for j in 0..m {
            for k in 0..m {
                let c = src[(i * m + j) * m + k];
                for (di, dj, dk) in itertools_cube() {
                    out[ii(n, 2 * i + di, 2 * j + dj, 2 * k + dk)] += c;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(h: f32) -> Params {
        Params {
            dt: 0.01,
            h,
            nu: 0.02,
            alpha: 0.01,
            beta_g: 0.5,
            t_inf: 300.0,
            q_int: 0.0,
            rho: 1.0,
            omega: 1.0,
        }
    }

    fn rand_field(len: usize, seed: u64) -> Vec<f32> {
        // small deterministic LCG; no rand dependency needed here
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect()
    }

    #[test]
    fn jacobi_constant_field_fixed_point() {
        let n = 6;
        let p = vec![2.5f32; pad_len(n)];
        let rhs = vec![0.0f32; int_len(n)];
        let mut out = vec![0.0f32; int_len(n)];
        jacobi_block(n, &p, &rhs, &params(0.1), &mut out);
        assert!(out.iter().all(|&x| (x - 2.5).abs() < 1e-6));
    }

    #[test]
    fn residual_zero_when_laplacian_matches() {
        // p linear in x ⇒ ∇²p = 0 ⇒ residual = rhs
        let n = 6;
        let par = params(0.25);
        let mut p = vec![0.0f32; pad_len(n)];
        for i in 0..n + 2 {
            for j in 0..n + 2 {
                for k in 0..n + 2 {
                    p[pi(n, i, j, k)] = 3.0 * i as f32;
                }
            }
        }
        let rhs = vec![0.0f32; int_len(n)];
        let mut r = vec![0.0f32; int_len(n)];
        let ssq = residual_block(n, &p, &rhs, &par, &mut r);
        assert!(ssq < 1e-6, "ssq={ssq}");
    }

    #[test]
    fn mac_divergence_of_gradient_is_compact_laplacian() {
        // the property that makes the projection exact: apply correct() to a
        // zero velocity with pressure p, then divergence() must equal
        // -(ρ/dt)·(dt/ρ)·∇²p = -∇²p (scaled)
        let n = 6;
        let par = Params::isothermal(0.05, 0.2, 0.0);
        let p = rand_field(pad_len(n), 7);
        let mut u = vec![0.0f32; int_len(n)];
        let mut v = vec![0.0f32; int_len(n)];
        let mut w = vec![0.0f32; int_len(n)];
        correct_block(n, &mut u, &mut v, &mut w, &p, &par);
        // re-pad the corrected interiors with the *consistent* neighbour
        // values: u halo must hold the corrected face velocities of
        // neighbouring cells. For this single-block check use the interior
        // only (shrink by one): compare at cells 2..n-1 where all needed
        // values are interior.
        let mut up = vec![0.0f32; pad_len(n)];
        let mut vp = vec![0.0f32; pad_len(n)];
        let mut wp = vec![0.0f32; pad_len(n)];
        for i in 1..=n {
            for j in 1..=n {
                for k in 1..=n {
                    up[pi(n, i, j, k)] = u[ii(n, i - 1, j - 1, k - 1)];
                    vp[pi(n, i, j, k)] = v[ii(n, i - 1, j - 1, k - 1)];
                    wp[pi(n, i, j, k)] = w[ii(n, i - 1, j - 1, k - 1)];
                }
            }
        }
        let mut div = vec![0.0f32; int_len(n)];
        divergence_block(n, &up, &vp, &wp, &par, &mut div);
        // interior-of-interior check against -∇²p/h² scaling:
        let h2 = par.h * par.h;
        for i in 2..n {
            for j in 2..n {
                for k in 2..n {
                    let nb = p[pi(n, i - 1, j, k)]
                        + p[pi(n, i + 1, j, k)]
                        + p[pi(n, i, j - 1, k)]
                        + p[pi(n, i, j + 1, k)]
                        + p[pi(n, i, j, k - 1)]
                        + p[pi(n, i, j, k + 1)];
                    let lap = (nb - 6.0 * p[pi(n, i, j, k)]) / h2;
                    let got = div[ii(n, i - 1, j - 1, k - 1)];
                    assert!(
                        (got + lap).abs() < 1e-3,
                        "({i},{j},{k}): {got} vs {}",
                        -lap
                    );
                }
            }
        }
    }

    #[test]
    fn predictor_pure_diffusion_decays_peak() {
        let n = 6;
        let mut par = params(0.1);
        par.beta_g = 0.0;
        let z = vec![0.0f32; pad_len(n)];
        let mut t = vec![300.0f32; pad_len(n)];
        t[pi(n, 3, 3, 3)] = 310.0;
        let (mut uo, mut vo, mut wo, mut to) = (
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
        );
        predictor_block(n, &z, &z, &z, &t, &par, &mut uo, &mut vo, &mut wo, &mut to);
        assert!(to[ii(n, 2, 2, 2)] < 310.0);
        assert!(to[ii(n, 1, 2, 2)] > 300.0);
        assert!(uo.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn buoyancy_pushes_hot_cell_up() {
        let n = 4;
        let par = params(0.1);
        let z = vec![0.0f32; pad_len(n)];
        let mut t = vec![300.0f32; pad_len(n)];
        t[pi(n, 2, 2, 2)] = 350.0;
        let (mut uo, mut vo, mut wo, mut to) = (
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
            vec![0.0; int_len(n)],
        );
        predictor_block(n, &z, &z, &z, &t, &par, &mut uo, &mut vo, &mut wo, &mut to);
        assert!(wo[ii(n, 1, 1, 1)] > 0.0);
    }

    #[test]
    fn restrict_preserves_constant_and_mean() {
        let n = 8;
        let fine = rand_field(int_len(n), 3);
        let mut coarse = vec![0.0f32; int_len(n / 2)];
        restrict_block(n, &fine, &mut coarse);
        let mean_f: f32 = fine.iter().sum::<f32>() / fine.len() as f32;
        let mean_c: f32 = coarse.iter().sum::<f32>() / coarse.len() as f32;
        assert!((mean_f - mean_c).abs() < 1e-5);
        let cst = vec![4.0f32; int_len(n)];
        restrict_block(n, &cst, &mut coarse);
        assert!(coarse.iter().all(|&x| (x - 4.0).abs() < 1e-6));
    }

    #[test]
    fn prolong_is_right_inverse_of_restrict() {
        // restrict(prolong(c)) == c for piecewise-constant prolongation
        let m = 4;
        let coarse = rand_field(int_len(m), 11);
        let mut fine = vec![0.0f32; int_len(2 * m)];
        prolong_add_block(m, &coarse, &mut fine);
        let mut back = vec![0.0f32; int_len(m)];
        restrict_block(2 * m, &fine, &mut back);
        for (a, b) in coarse.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn jacobi_converges_on_manufactured_solution() {
        // solve ∇²p = rhs with p=0 Dirichlet halo; manufactured rhs from a
        // known p*, iterate: error must shrink monotonically
        let n = 8;
        let par = params(1.0 / n as f32);
        // p* = product of parabolas vanishing at the boundary
        let mut pstar = vec![0.0f32; pad_len(n)];
        for i in 0..n + 2 {
            for j in 0..n + 2 {
                for k in 0..n + 2 {
                    let f = |x: usize| {
                        let t = x as f32 / (n + 1) as f32;
                        t * (1.0 - t)
                    };
                    pstar[pi(n, i, j, k)] = f(i) * f(j) * f(k);
                }
            }
        }
        let mut rhs = vec![0.0f32; int_len(n)];
        // rhs := ∇²p*
        let h2 = par.h * par.h;
        for i in 1..=n {
            for j in 1..=n {
                for k in 1..=n {
                    let nb = pstar[pi(n, i - 1, j, k)]
                        + pstar[pi(n, i + 1, j, k)]
                        + pstar[pi(n, i, j - 1, k)]
                        + pstar[pi(n, i, j + 1, k)]
                        + pstar[pi(n, i, j, k - 1)]
                        + pstar[pi(n, i, j, k + 1)];
                    rhs[ii(n, i - 1, j - 1, k - 1)] =
                        (nb - 6.0 * pstar[pi(n, i, j, k)]) / h2;
                }
            }
        }
        let mut p = vec![0.0f32; pad_len(n)];
        let mut out = vec![0.0f32; int_len(n)];
        let err = |p: &[f32]| -> f32 {
            let mut e = 0.0f32;
            for i in 1..=n {
                for j in 1..=n {
                    for k in 1..=n {
                        e += (p[pi(n, i, j, k)] - pstar[pi(n, i, j, k)]).powi(2);
                    }
                }
            }
            e.sqrt()
        };
        let e0 = err(&p);
        for _ in 0..200 {
            jacobi_block(n, &p, &rhs, &par, &mut out);
            for i in 1..=n {
                for j in 1..=n {
                    for k in 1..=n {
                        p[pi(n, i, j, k)] = out[ii(n, i - 1, j - 1, k - 1)];
                    }
                }
            }
        }
        let e1 = err(&p);
        assert!(e1 < 0.05 * e0, "e0={e0} e1={e1}");
    }
}
