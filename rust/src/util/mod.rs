//! Small in-tree replacements for crates unavailable in this build
//! environment (rayon, rand, proptest): a work-stealing-free parallel-for,
//! a deterministic SplitMix/xoshiro RNG, and a tiny property-test driver.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod synth;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Global cap on [`parallel_for`] worker threads (0 = use all cores).
/// Used by the strong-scaling benches (Fig 2b) to emulate varying process
/// counts on one host.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Cap the number of threads `parallel_for` may use (0 restores all cores).
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// Run `f(i)` for `i in 0..n` on all available cores (scoped threads with an
/// atomic work counter). `f` must be safe to call concurrently for distinct
/// `i` — the typical use is writing to disjoint chunks of an output buffer
/// through [`SendPtr`].
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    let cap = MAX_THREADS.load(Ordering::Relaxed);
    let threads = std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(1)
        .min(if cap == 0 { usize::MAX } else { cap })
        .min(n);
    // tiny batches: thread-spawn overhead (~50 µs) exceeds the work on the
    // coarse multigrid levels — run serially (perf pass)
    if threads <= 1 || n < 8 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Debug-build claims registry backing [`SendPtr`]'s disjointness
/// contract: every non-aliased [`SendPtr::slice`] records its range under
/// the buffer's base address and panics if it overlaps a range already
/// reconstructed since the buffer's last [`SendPtr::new`]. Claims are
/// cleared when a new `SendPtr` is built over the same address — at that
/// point the caller holds `&mut [T]`, so every prior reconstruction is
/// dead by contract.
#[cfg(debug_assertions)]
mod claims {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    fn table() -> &'static Mutex<HashMap<usize, Vec<(usize, usize)>>> {
        static TABLE: OnceLock<Mutex<HashMap<usize, Vec<(usize, usize)>>>> = OnceLock::new();
        TABLE.get_or_init(|| Mutex::new(HashMap::new()))
    }

    pub fn reset(base: usize) {
        table().lock().unwrap().remove(&base);
    }

    pub fn claim(base: usize, start: usize, len: usize) {
        let mut t = table().lock().unwrap();
        let ranges = t.entry(base).or_default();
        for &(s, l) in ranges.iter() {
            if start < s + l && s < start + len {
                panic!(
                    "SendPtr: reconstruction [{start}, {}) overlaps live \
                     reconstruction [{s}, {}) — ranges must be disjoint \
                     (or build the pointer with SendPtr::new_aliased)",
                    start + len,
                    s + l,
                );
            }
        }
        ranges.push((start, len));
    }
}

/// A raw pointer wrapper asserting cross-thread use is externally
/// synchronised (disjoint index ranges). Used to hand mutable buffers to
/// [`parallel_for`] closures.
///
/// Debug builds back the contract with checks: every [`SendPtr::slice`]
/// is bounds-checked against the buffer's captured length, and — unless
/// the pointer was built with [`SendPtr::new_aliased`] — its range is
/// recorded in a process-wide registry that panics on overlap with any
/// other range reconstructed since the buffer's last [`SendPtr::new`].
/// Release builds compile both checks away.
#[derive(Clone, Copy)]
pub struct SendPtr<T> {
    ptr: *mut T,
    /// Backing-buffer length captured at construction (bounds checks).
    len: usize,
    /// Overlapping reconstructions are allowed by contract (shared reads
    /// of regions no concurrent task writes); skip the claims registry.
    aliased: bool,
}

// SAFETY: SendPtr is a plain address + metadata; all dereferences go
// through `slice`/`base`, whose callers take on the synchronisation
// obligation (disjoint ranges, or aliased ranges nobody concurrently
// writes). The wrapper itself carries no thread-affine state.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: as for Send — `&SendPtr` exposes nothing beyond the Copy value.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Raw view of `slice` whose debug-build reconstructions must be
    /// pairwise disjoint. Clears any stale claims a previous `SendPtr`
    /// over the same buffer recorded (`&mut` proves they are dead).
    pub fn new(slice: &mut [T]) -> SendPtr<T> {
        #[cfg(debug_assertions)]
        claims::reset(slice.as_mut_ptr() as usize);
        SendPtr {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            aliased: false,
        }
    }

    /// Raw view whose reconstructions may overlap — the ghost-exchange
    /// pattern: each task takes `&mut` to its own element and `&` to
    /// peers' elements, with writes confined to regions no other task
    /// reads in the same pass. Bounds checks still apply in debug; the
    /// disjointness registry does not.
    pub fn new_aliased(slice: &mut [T]) -> SendPtr<T> {
        #[cfg(debug_assertions)]
        claims::reset(slice.as_mut_ptr() as usize);
        SendPtr {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            aliased: true,
        }
    }

    /// The buffer's base pointer, for callers doing sub-element-grained
    /// disjoint writes (e.g. per-cell octant folds) that `slice`'s
    /// whole-range claims cannot express.
    pub fn base(&self) -> *mut T {
        self.ptr
    }

    /// # Safety
    /// Caller guarantees `[offset, offset+len)` is in bounds and disjoint
    /// from every other concurrently reconstructed slice (for an
    /// [`SendPtr::new_aliased`] pointer: overlapping reconstructions are
    /// permitted, but no element may be written by one task while another
    /// reads or writes it).
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &'static mut [T] {
        #[cfg(debug_assertions)]
        {
            let end = offset
                .checked_add(len)
                .expect("SendPtr::slice: offset + len overflows");
            assert!(
                end <= self.len,
                "SendPtr::slice: [{offset}, {end}) out of bounds of {}",
                self.len
            );
            if !self.aliased && len > 0 {
                claims::claim(self.ptr as usize, offset, len);
            }
        }
        // SAFETY: in bounds per the caller's contract (checked above in
        // debug); aliasing discipline is the caller's obligation.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(offset), len) }
    }
}

/// Format a byte count as a human-readable string (for bench tables).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a GB/s bandwidth value the way the paper's plots label them.
pub fn fmt_gbps(bytes: f64, seconds: f64) -> String {
    format!("{:.2} GB/s", bytes / seconds / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1000;
        let mut out = vec![0u32; n];
        let ptr = SendPtr::new(&mut out);
        parallel_for(n, |i| {
            // SAFETY: one task per index, disjoint single cells.
            let s = unsafe { ptr.slice(i, 1) };
            s[0] = i as u32 + 1;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let mut hit = vec![false];
        let ptr = SendPtr::new(&mut hit);
        // SAFETY: single task, single cell.
        parallel_for(1, |i| unsafe { ptr.slice(i, 1)[0] = true });
        assert!(hit[0]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "ranges must be disjoint")]
    fn overlapping_reconstruction_panics_in_debug() {
        let mut buf = vec![0u8; 16];
        let ptr = SendPtr::new(&mut buf);
        // SAFETY: the overlapping claim panics before `_b` materialises,
        // so no two live &mut ever alias.
        let _a = unsafe { ptr.slice(0, 8) };
        let _b = unsafe { ptr.slice(4, 8) }; // overlaps [0, 8)
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_reconstruction_panics_in_debug() {
        let mut buf = vec![0u8; 16];
        let ptr = SendPtr::new(&mut buf);
        // SAFETY: the bounds assert panics before the slice materialises.
        let _ = unsafe { ptr.slice(8, 9) };
    }

    #[test]
    fn aliased_reconstructions_are_allowed() {
        let mut buf = vec![0u8; 16];
        let ptr = SendPtr::new_aliased(&mut buf);
        // SAFETY: in bounds; `a` is abandoned once `b` exists below.
        let a = unsafe { ptr.slice(0, 8) };
        a[4] = 7;
        // overlap is the contract; `a` is not touched again once `b` exists
        // SAFETY: in bounds; sole live reconstruction from here on.
        let b = unsafe { ptr.slice(4, 4) };
        assert_eq!(b[0], 7);
    }

    #[test]
    fn rebuilding_clears_stale_claims() {
        let mut buf = vec![0u8; 16];
        let ptr = SendPtr::new(&mut buf);
        // SAFETY: whole-buffer reconstruction, immediately dropped.
        let _ = unsafe { ptr.slice(0, 16) };
        // a fresh SendPtr over the same buffer starts a new claims epoch
        let ptr2 = SendPtr::new(&mut buf);
        // SAFETY: as above — the prior reconstruction is dead.
        let _ = unsafe { ptr2.slice(0, 16) };
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512.00 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(337 * 1024 * 1024 * 1024), "337.00 GiB");
    }

    #[test]
    fn fmt_gbps_scaling() {
        assert_eq!(fmt_gbps(2e9, 1.0), "2.00 GB/s");
        assert_eq!(fmt_gbps(1e9, 2.0), "0.50 GB/s");
    }
}
