//! Small in-tree replacements for crates unavailable in this build
//! environment (rayon, rand, proptest): a work-stealing-free parallel-for,
//! a deterministic SplitMix/xoshiro RNG, and a tiny property-test driver.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod synth;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Global cap on [`parallel_for`] worker threads (0 = use all cores).
/// Used by the strong-scaling benches (Fig 2b) to emulate varying process
/// counts on one host.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Cap the number of threads `parallel_for` may use (0 restores all cores).
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// Run `f(i)` for `i in 0..n` on all available cores (scoped threads with an
/// atomic work counter). `f` must be safe to call concurrently for distinct
/// `i` — the typical use is writing to disjoint chunks of an output buffer
/// through [`SendPtr`].
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    let cap = MAX_THREADS.load(Ordering::Relaxed);
    let threads = std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(1)
        .min(if cap == 0 { usize::MAX } else { cap })
        .min(n);
    // tiny batches: thread-spawn overhead (~50 µs) exceeds the work on the
    // coarse multigrid levels — run serially (perf pass)
    if threads <= 1 || n < 8 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// A raw pointer wrapper asserting cross-thread use is externally
/// synchronised (disjoint index ranges). Used to hand mutable buffers to
/// [`parallel_for`] closures.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(slice: &mut [T]) -> SendPtr<T> {
        SendPtr(slice.as_mut_ptr())
    }

    /// # Safety
    /// Caller guarantees `[offset, offset+len)` is in bounds and disjoint
    /// from every other concurrently reconstructed slice.
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &'static mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

/// Format a byte count as a human-readable string (for bench tables).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a GB/s bandwidth value the way the paper's plots label them.
pub fn fmt_gbps(bytes: f64, seconds: f64) -> String {
    format!("{:.2} GB/s", bytes / seconds / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1000;
        let mut out = vec![0u32; n];
        let ptr = SendPtr::new(&mut out);
        parallel_for(n, |i| {
            let s = unsafe { ptr.slice(i, 1) };
            s[0] = i as u32 + 1;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let mut hit = vec![false];
        let ptr = SendPtr::new(&mut hit);
        parallel_for(1, |i| unsafe { ptr.slice(i, 1)[0] = true });
        assert!(hit[0]);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512.00 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(337 * 1024 * 1024 * 1024), "337.00 GiB");
    }

    #[test]
    fn fmt_gbps_scaling() {
        assert_eq!(fmt_gbps(2e9, 1.0), "2.00 GB/s");
        assert_eq!(fmt_gbps(1e9, 2.0), "0.50 GB/s");
    }
}
