//! Deterministic pseudo-random numbers (SplitMix64 + xoshiro256**), used by
//! the workload generators, property tests and examples. Seeded explicitly
//! everywhere so every experiment in EXPERIMENTS.md is reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias negligible for our n ≪ 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.f32_range(lo, hi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit: {seen:?}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
