//! Synthetic f32 field generators for codec benches and tests.
//!
//! Three canonical inputs spanning the compressibility range of real cell
//! data, all fully deterministic (fixed seeds, no wall-clock anywhere):
//!
//! * [`smooth_field`] — a slow sine, the best case for the shuffle/delta
//!   pipeline (near-constant exponent and high-mantissa planes);
//! * [`turbulent_field`] — a band-limited multi-mode field with a
//!   Kolmogorov-like spectrum: every mode resolved on the grid
//!   (frequencies below Nyquist), amplitudes `∝ w^(-5/6)` (energy
//!   `∝ k^(-5/3)`), deterministic LCG phases. Rough at sample scale —
//!   the low-mantissa byte planes are effectively incompressible, which
//!   is exactly what resolved turbulence looks like to a lossless codec;
//! * [`noise_bytes`] — xorshift bytes, incompressible by construction
//!   (the adaptive selector must fall back to `Store`).

/// Smooth cell data: `1.0 + 0.25·sin(i/1000)`.
pub fn smooth_field(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| 1.0 + (i as f32 * 1e-3).sin() * 0.25)
        .collect()
}

/// Default phase seed of [`turbulent_field`] (π's mantissa bits).
pub const TURB_SEED: u64 = 0x243F_6A88_85A3_08D3;

/// Band-limited Kolmogorov-spectrum field: 24 modes, geometric
/// frequencies in `[0.02, 1.2]` rad/sample, amplitude `w^(-5/6)`
/// normalised to an RMS of `scale = 0.4` around a mean of 2.0. `seed`
/// drives the LCG phase sequence.
pub fn turbulent_field(n: usize, seed: u64) -> Vec<f32> {
    const MODES: usize = 24;
    const W_MIN: f64 = 0.02;
    const W_MAX: f64 = 1.2;
    const SCALE: f64 = 0.4;
    let r = (W_MAX / W_MIN).powf(1.0 / (MODES - 1) as f64);
    let amps: Vec<f64> = (0..MODES)
        .map(|m| (W_MIN * r.powi(m as i32)).powf(-5.0 / 6.0))
        .collect();
    let norm = (amps.iter().map(|a| a * a).sum::<f64>() / 2.0).sqrt();
    let mut phase = seed;
    let modes: Vec<(f64, f64, f64)> = (0..MODES)
        .map(|m| {
            phase = phase
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let ph = (phase >> 11) as f64 / (1u64 << 53) as f64 * std::f64::consts::TAU;
            let w = W_MIN * r.powi(m as i32);
            (amps[m] / norm * SCALE, w, ph)
        })
        .collect();
    (0..n)
        .map(|i| {
            let x = i as f64;
            let f: f64 = modes.iter().map(|&(a, w, ph)| a * (x * w + ph).sin()).sum();
            (2.0 + f) as f32
        })
        .collect()
}

/// Deterministic xorshift64 byte noise (the corpus' incompressible leg).
pub fn noise_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 24) as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fields_are_deterministic_and_bounded() {
        let a = turbulent_field(4096, TURB_SEED);
        let b = turbulent_field(4096, TURB_SEED);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| x > 0.0 && x < 4.5), "amplitude bound");
        let c = turbulent_field(4096, 99);
        assert_ne!(a, c, "seed must matter");
        assert_eq!(smooth_field(8)[0], 1.0);
        assert_eq!(noise_bytes(3, 16), noise_bytes(3, 16));
    }

    #[test]
    fn turbulent_field_is_rough_but_not_noise() {
        // sample-to-sample deltas must be non-trivial (unlike the smooth
        // field) yet bounded (unlike white noise) — the property the codec
        // benches rely on
        let f = turbulent_field(8192, TURB_SEED);
        let mean_abs_delta: f32 = f
            .windows(2)
            .map(|w| (w[1] - w[0]).abs())
            .sum::<f32>()
            / (f.len() - 1) as f32;
        assert!(mean_abs_delta > 0.01, "too smooth: {mean_abs_delta}");
        assert!(mean_abs_delta < 1.0, "too rough: {mean_abs_delta}");
    }
}
