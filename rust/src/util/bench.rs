//! Tiny measurement harness for the `cargo bench` targets (criterion is not
//! available offline): warmup + repeated timing with min/mean/max reporting.

use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub iters: u32,
    pub min: f64,
    pub mean: f64,
    pub max: f64,
}

impl Sample {
    pub fn fmt_ms(&self) -> String {
        format!(
            "min {:.3} ms  mean {:.3} ms  max {:.3} ms  ({} iters)",
            self.min * 1e3,
            self.mean * 1e3,
            self.max * 1e3,
            self.iters
        )
    }
}

/// Run `f` once for warmup then `iters` times, timing each run.
pub fn measure(iters: u32, mut f: impl FnMut()) -> Sample {
    f(); // warmup
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Sample {
        iters,
        min,
        mean,
        max,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_ordered_stats() {
        let s = measure(5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert_eq!(s.iters, 5);
        assert!(!s.fmt_ms().is_empty());
    }
}
