//! A miniature property-testing driver (stand-in for proptest, which is not
//! available offline): runs a property over `CASES` seeded random inputs and
//! reports the failing seed so a case can be replayed deterministically.

use super::rng::Rng;

/// Number of random cases per property (tuned for CI wall-clock).
pub const CASES: u64 = 64;

/// Run `prop(rng)` for [`CASES`] distinct deterministic seeds derived from
/// `base_seed`. Panics (with the seed) on the first failing case.
pub fn check(name: &str, base_seed: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..CASES {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 1, |_| count += 1);
        assert_eq!(count, CASES);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 2, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn seeds_are_distinct_across_cases() {
        let mut values = Vec::new();
        check("collect", 3, |rng| values.push(rng.next_u64()));
        values.sort_unstable();
        values.dedup();
        assert_eq!(values.len(), CASES as usize);
    }
}
