//! A minimal JSON parser (stand-in for serde_json, unavailable offline).
//! Supports the full JSON value grammar; used to read the AOT artifact
//! manifest and scenario configs.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("json: trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "json: expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            );
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("json: unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("json: expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("json: expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("json: unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => bail!("json: bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // pass raw UTF-8 bytes through
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "n": 16, "default_batch": 32,
            "entries": [
                {"name": "jacobi", "file": "jacobi_b32_n16.hlo.txt",
                 "batch": 32, "inputs": [{"shape": [32, 18, 18, 18], "dtype": "float32"}],
                 "outputs": 1}
            ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(16));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("jacobi"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 4);
        assert_eq!(shape[1].as_usize(), Some(18));
    }

    #[test]
    fn parses_scalars_and_nesting() {
        let j = Json::parse(r#"{"a": [1, -2.5, true, false, null, "s\"x"], "b": {}}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_bool(), Some(true));
        assert_eq!(a[4], Json::Null);
        assert_eq!(a[5].as_str(), Some("s\"x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn exponent_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""AZ""#).unwrap().as_str(),
            Some("AZ")
        );
    }
}
