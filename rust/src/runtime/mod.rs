//! The **PJRT runtime** — loads the AOT-compiled HLO artifacts and executes
//! them on the hot path.
//!
//! `make artifacts` (Python, build time only) lowers each L2 entry point to
//! HLO text plus a `manifest.json`; this module loads the text through
//! `HloModuleProto::from_text_file`, compiles once per entry with
//! `PjRtClient::cpu()`, and exposes the result behind the
//! [`ComputeBackend`] trait so the solver/coordinator are agnostic between
//! this backend and the pure-Rust oracle.
//!
//! Batching: artifacts are shape-specialised (default B = 32 plus a B = 1
//! variant). [`PjrtBackend`] chops an arbitrary batch into full-B chunks
//! and runs the tail through the B = 1 executable — the d-grid batcher in
//! the coordinator feeds it multiples of B wherever possible.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::physics::{ComputeBackend, Params};
use crate::util::json::Json;
use crate::DGRID_N;

/// One entry of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub n: usize,
    /// Input shapes (excluding dtype — everything is f32).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: usize,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n: usize,
    pub default_batch: usize,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("runtime: read {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("runtime: parse manifest.json")?;
        let need = |k: &str| j.get(k).ok_or_else(|| anyhow!("manifest missing '{k}'"));
        let n = need("n")?.as_usize().unwrap_or(0);
        let default_batch = need("default_batch")?.as_usize().unwrap_or(0);
        let mut entries = Vec::new();
        for e in need("entries")?.as_arr().unwrap_or(&[]) {
            let shapes = e
                .get("inputs")
                .and_then(|i| i.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|s| {
                    s.get("shape")
                        .and_then(|x| x.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
                file: e
                    .get("file")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
                batch: e.get("batch").and_then(|x| x.as_usize()).unwrap_or(1),
                n: e.get("n").and_then(|x| x.as_usize()).unwrap_or(n),
                inputs: shapes,
                outputs: e.get("outputs").and_then(|x| x.as_usize()).unwrap_or(1),
            });
        }
        if entries.is_empty() {
            bail!("runtime: manifest has no entries");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            n,
            default_batch,
            entries,
        })
    }
}

/// Everything PJRT: client + one compiled executable per (entry, batch).
struct Inner {
    _client: xla::PjRtClient,
    exes: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
}

// SAFETY: the PJRT C API is thread-safe for execution; the `Rc` inside
// `PjRtClient` is never cloned across threads because all access goes
// through the `Mutex` in `PjrtBackend` (one dispatch at a time — the CPU
// client parallelises internally across its Eigen thread pool).
unsafe impl Send for Inner {}

/// [`ComputeBackend`] implementation executing the AOT artifacts.
pub struct PjrtBackend {
    inner: Mutex<Inner>,
    pub manifest: Manifest,
    /// Dispatch counter for the perf report.
    pub dispatches: std::sync::atomic::AtomicU64,
}

impl PjrtBackend {
    /// Load `artifacts/` (or the dir in `MPFLUID_ARTIFACTS`), compiling
    /// every manifest entry.
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(dir)?;
        if manifest.n != DGRID_N {
            bail!(
                "runtime: artifacts lowered for N={} but crate fixes DGRID_N={}",
                manifest.n,
                DGRID_N
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for e in &manifest.entries {
            let path = manifest.dir.join(&e.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|err| anyhow!("load {path:?}: {err:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|err| anyhow!("compile {}: {err:?}", e.file))?;
            exes.insert((e.name.clone(), e.batch), exe);
        }
        Ok(PjrtBackend {
            inner: Mutex::new(Inner {
                _client: client,
                exes,
            }),
            manifest,
            dispatches: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Default artifact location: `$MPFLUID_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<PjrtBackend> {
        let dir = std::env::var("MPFLUID_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        PjrtBackend::load(Path::new(&dir))
    }

    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Execute entry `name` at exactly batch `b` (an available artifact
    /// batch size). `fields` are the tensor inputs (without params); the
    /// params vector is appended automatically. Returns the flattened f32
    /// outputs in entry order.
    fn exec_exact(
        &self,
        name: &str,
        b: usize,
        fields: &[(&[f32], &[usize])],
        par: &Params,
    ) -> Result<Vec<Vec<f32>>> {
        let inner = self.inner.lock().unwrap();
        let exe = inner
            .exes
            .get(&(name.to_string(), b))
            .ok_or_else(|| anyhow!("runtime: no artifact '{name}' at batch {b}"))?;
        let mut lits = Vec::with_capacity(fields.len() + 1);
        for (data, dims) in fields {
            // SAFETY: reinterpreting an f32 slice as its raw bytes — same
            // allocation, length in bytes = len * size_of::<f32>(), and u8
            // has no alignment or validity requirements.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            lits.push(
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    dims,
                    bytes,
                )
                .map_err(|e| anyhow!("literal: {e:?}"))?,
            );
        }
        let pv = par.to_vec();
        lits.push(xla::Literal::vec1(&pv[..]));
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        self.dispatches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Execute entry `name` over an arbitrary batch `b`, chunking into the
    /// default artifact batch; a ragged tail of one block uses the B = 1
    /// artifact, any other tail is zero-padded up to the default batch (one
    /// dispatch instead of a per-block loop — perf pass, EXPERIMENTS §Perf).
    /// `ins`: per input, (data, per-block element count, trailing dims).
    /// `outs`: per output, (dest, per-block element count).
    fn exec_chunked(
        &self,
        name: &str,
        b: usize,
        ins: &[(&[f32], usize, Vec<usize>)],
        outs: &mut [(&mut [f32], usize)],
        par: &Params,
    ) -> Result<()> {
        let bb = self.manifest.default_batch.max(1);
        let mut done = 0usize;
        // reusable padding buffers (one per input) for the final chunk
        let mut padded: Vec<Vec<f32>> = Vec::new();
        while done < b {
            let rem = b - done;
            let (chunk, run) = if rem >= bb {
                (bb, bb) // full chunk
            } else if rem == 1 {
                (1, 1) // B = 1 artifact
            } else {
                (rem, bb) // pad the tail up to bb
            };
            let results = if run == chunk {
                let fields: Vec<(&[f32], Vec<usize>)> = ins
                    .iter()
                    .map(|(data, per, dims)| {
                        let mut shape = vec![chunk];
                        shape.extend_from_slice(dims);
                        (&data[done * per..(done + chunk) * per], shape)
                    })
                    .collect();
                let refs: Vec<(&[f32], &[usize])> =
                    fields.iter().map(|(d, s)| (*d, s.as_slice())).collect();
                self.exec_exact(name, run, &refs, par)?
            } else {
                if padded.is_empty() {
                    padded = ins.iter().map(|(_, per, _)| vec![0.0f32; run * per]).collect();
                }
                for ((data, per, _), buf) in ins.iter().zip(padded.iter_mut()) {
                    buf[..chunk * per].copy_from_slice(&data[done * per..(done + chunk) * per]);
                    buf[chunk * per..].fill(0.0);
                }
                let fields: Vec<(&[f32], Vec<usize>)> = ins
                    .iter()
                    .zip(padded.iter())
                    .map(|((_, _, dims), buf)| {
                        let mut shape = vec![run];
                        shape.extend_from_slice(dims);
                        (buf.as_slice(), shape)
                    })
                    .collect();
                let refs: Vec<(&[f32], &[usize])> =
                    fields.iter().map(|(d, s)| (*d, s.as_slice())).collect();
                self.exec_exact(name, run, &refs, par)?
            };
            if results.len() != outs.len() {
                bail!(
                    "runtime: entry '{name}' returned {} outputs, expected {}",
                    results.len(),
                    outs.len()
                );
            }
            for (res, (dest, per)) in results.iter().zip(outs.iter_mut()) {
                dest[done * *per..(done + chunk) * *per]
                    .copy_from_slice(&res[..chunk * *per]);
            }
            done += chunk;
        }
        Ok(())
    }
}

const NPAD: usize = DGRID_N + 2;

fn halo_dims() -> Vec<usize> {
    vec![NPAD, NPAD, NPAD]
}

fn int_dims() -> Vec<usize> {
    vec![DGRID_N, DGRID_N, DGRID_N]
}

const PAD: usize = NPAD * NPAD * NPAD;
const INT: usize = DGRID_N * DGRID_N * DGRID_N;

impl ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn preferred_batch(&self) -> usize {
        self.manifest.default_batch
    }

    fn jacobi(&self, b: usize, p: &[f32], rhs: &[f32], par: &Params, out: &mut [f32]) {
        self.exec_chunked(
            "jacobi",
            b,
            &[(p, PAD, halo_dims()), (rhs, INT, int_dims())],
            &mut [(out, INT)],
            par,
        )
        .expect("pjrt jacobi");
    }

    fn residual(
        &self,
        b: usize,
        p: &[f32],
        rhs: &[f32],
        par: &Params,
        r: &mut [f32],
        ssq: &mut [f32],
    ) {
        self.exec_chunked(
            "residual",
            b,
            &[(p, PAD, halo_dims()), (rhs, INT, int_dims())],
            &mut [(r, INT), (ssq, 1)],
            par,
        )
        .expect("pjrt residual");
    }

    fn divergence(&self, b: usize, u: &[f32], v: &[f32], w: &[f32], par: &Params, out: &mut [f32]) {
        self.exec_chunked(
            "divergence",
            b,
            &[
                (u, PAD, halo_dims()),
                (v, PAD, halo_dims()),
                (w, PAD, halo_dims()),
            ],
            &mut [(out, INT)],
            par,
        )
        .expect("pjrt divergence");
    }

    fn correct(
        &self,
        b: usize,
        u: &[f32],
        v: &[f32],
        w: &[f32],
        p: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
    ) {
        self.exec_chunked(
            "correct",
            b,
            &[
                (u, INT, int_dims()),
                (v, INT, int_dims()),
                (w, INT, int_dims()),
                (p, PAD, halo_dims()),
            ],
            &mut [(uo, INT), (vo, INT), (wo, INT)],
            par,
        )
        .expect("pjrt correct");
    }

    fn predictor(
        &self,
        b: usize,
        u: &[f32],
        v: &[f32],
        w: &[f32],
        t: &[f32],
        par: &Params,
        uo: &mut [f32],
        vo: &mut [f32],
        wo: &mut [f32],
        to: &mut [f32],
    ) {
        self.exec_chunked(
            "predictor",
            b,
            &[
                (u, PAD, halo_dims()),
                (v, PAD, halo_dims()),
                (w, PAD, halo_dims()),
                (t, PAD, halo_dims()),
            ],
            &mut [(uo, INT), (vo, INT), (wo, INT), (to, INT)],
            par,
        )
        .expect("pjrt predictor");
    }

    fn restrict(&self, b: usize, fine: &[f32], out: &mut [f32]) {
        let par = Params::isothermal(1.0, 1.0, 0.0);
        let half = INT / 8;
        self.exec_chunked(
            "restrict",
            b,
            &[(fine, INT, int_dims())],
            &mut [(out, half)],
            &par,
        )
        .expect("pjrt restrict");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_minimal() {
        let dir = std::env::temp_dir().join(format!("manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"n": 16, "default_batch": 4, "entries": [
                {"name": "jacobi", "file": "jacobi_b4_n16.hlo.txt", "batch": 4,
                 "n": 16, "inputs": [{"shape": [4,18,18,18], "dtype": "float32"},
                 {"shape": [4,16,16,16], "dtype": "float32"},
                 {"shape": [8], "dtype": "float32"}], "outputs": 1}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.default_batch, 4);
        assert_eq!(m.entries[0].inputs[0], vec![4, 18, 18, 18]);
        assert_eq!(m.entries[0].outputs, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    // Full PJRT execution is covered by rust/tests/runtime_golden.rs,
    // which requires `make artifacts` to have run.
}
