//! Scenario configuration: a small declarative description of a simulation
//! run (domain, refinement, physics, BCs, obstacles, I/O), parseable from
//! JSON and constructible programmatically. The named presets correspond to
//! the scenarios the paper evaluates: the Schäfer–Turek channel (Fig 6),
//! the operation theatre (Fig 7), and a plain heated cavity.

use anyhow::{anyhow, bail, Result};

use crate::cluster::{IoTuning, Machine};
use crate::coordinator::Simulation;
use crate::nbs::Face;
use crate::physics::bc::{DomainBc, FaceBc};
use crate::physics::Params;
use crate::steering::{self, SteerCommand};
use crate::tree::{BBox, SpaceTree};
use crate::util::json::Json;

/// An obstacle in the initial geometry.
#[derive(Clone, Debug)]
pub struct Obstacle {
    pub centre: [f64; 3],
    pub radius: f64,
    /// Fixed surface temperature (heated solid) or None (plain solid).
    pub temp: Option<f32>,
    /// Cylinder axis (distance computed ignoring this axis) or None.
    pub axis: Option<usize>,
}

/// Full description of a run.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub depth: u32,
    /// Refine only around obstacles up to `depth` (adaptive) instead of
    /// a fully refined tree.
    pub adaptive: bool,
    pub ranks: u32,
    pub params: Params,
    pub bc: DomainBc,
    pub obstacles: Vec<Obstacle>,
    /// Initial temperature everywhere.
    pub t0: f32,
    pub steps: u64,
    pub checkpoint_every: u64,
    pub machine: Machine,
    pub tuning: IoTuning,
    /// FS block alignment for the output file.
    pub alignment: u64,
}

impl Scenario {
    /// Lid-/inflow-driven channel with one cylinder — the Schäfer–Turek
    /// benchmark behind Fig 6 (2-D in the paper; realised here as a thin
    /// 3-D slab, one d-grid deep in z at every refinement level).
    pub fn channel(depth: u32) -> Scenario {
        Scenario {
            name: "channel".into(),
            depth,
            adaptive: false,
            ranks: 4,
            params: Params {
                dt: 0.004,
                h: 0.0,
                nu: 0.005, // Re = u·D/ν ≈ 100 with D = 0.25, u = 2
                alpha: 0.005,
                beta_g: 0.0,
                t_inf: 293.0,
                q_int: 0.0,
                rho: 1.0,
                omega: 1.0,
            },
            bc: DomainBc::channel(1.0, 293.0),
            obstacles: vec![Obstacle {
                centre: [0.25, 0.5, 0.5],
                radius: 0.125,
                temp: None,
                axis: Some(2),
            }],
            t0: 293.0,
            steps: 200,
            checkpoint_every: 50,
            machine: Machine::local(),
            tuning: IoTuning::default(),
            alignment: 4096,
        }
    }

    /// Thermally coupled room with heated "lamps" and "bodies" — the
    /// operation-theatre scenario of Fig 7 (§4): inflow over one full wall,
    /// slightly open door opposite, fixed-temperature geometry.
    pub fn theatre(depth: u32) -> Scenario {
        let mut bc = DomainBc::all_walls();
        *bc.face_mut(Face::XM) = FaceBc::inflow(0.3, 292.0);
        *bc.face_mut(Face::XP) = FaceBc::outflow();
        Scenario {
            name: "theatre".into(),
            depth,
            adaptive: false,
            ranks: 4,
            params: Params {
                dt: 0.004,
                h: 0.0,
                nu: 0.01,
                alpha: 0.01,
                beta_g: 0.4, // Boussinesq coupling
                t_inf: 292.0,
                q_int: 0.0,
                rho: 1.0,
                omega: 1.0,
            },
            bc,
            obstacles: vec![
                // lamps (heated, T = 324.66 K per the paper)
                Obstacle {
                    centre: [0.45, 0.4, 0.8],
                    radius: 0.07,
                    temp: Some(324.66),
                    axis: None,
                },
                Obstacle {
                    centre: [0.6, 0.6, 0.8],
                    radius: 0.07,
                    temp: Some(324.66),
                    axis: None,
                },
                // patient (T = 299.50 K)
                Obstacle {
                    centre: [0.5, 0.5, 0.3],
                    radius: 0.12,
                    temp: Some(299.50),
                    axis: Some(0),
                },
                // assistants
                Obstacle {
                    centre: [0.35, 0.3, 0.35],
                    radius: 0.08,
                    temp: Some(299.50),
                    axis: Some(2),
                },
                Obstacle {
                    centre: [0.65, 0.7, 0.35],
                    radius: 0.08,
                    temp: Some(299.50),
                    axis: Some(2),
                },
            ],
            t0: 292.0,
            steps: 200,
            checkpoint_every: 40,
            machine: Machine::local(),
            tuning: IoTuning::default(),
            alignment: 4096,
        }
    }

    /// Buoyancy-driven heated cavity (quickstart scenario).
    pub fn cavity(depth: u32) -> Scenario {
        Scenario {
            name: "cavity".into(),
            depth,
            adaptive: false,
            ranks: 2,
            params: Params {
                dt: 0.002,
                h: 0.0,
                nu: 0.01,
                alpha: 0.01,
                beta_g: 1.0,
                t_inf: 300.0,
                q_int: 0.0,
                rho: 1.0,
                omega: 1.0,
            },
            bc: DomainBc::all_walls(),
            obstacles: vec![Obstacle {
                centre: [0.5, 0.5, 0.25],
                radius: 0.12,
                temp: Some(330.0),
                axis: None,
            }],
            t0: 300.0,
            steps: 100,
            checkpoint_every: 25,
            machine: Machine::local(),
            tuning: IoTuning::default(),
            alignment: 4096,
        }
    }

    pub fn by_name(name: &str, depth: u32) -> Result<Scenario> {
        Ok(match name {
            "channel" => Scenario::channel(depth),
            "theatre" => Scenario::theatre(depth),
            "cavity" => Scenario::cavity(depth),
            other => bail!("unknown scenario '{other}' (channel|theatre|cavity)"),
        })
    }

    /// Parse overrides from a JSON document on top of a named preset:
    /// `{"scenario": "channel", "depth": 2, "ranks": 8, "steps": 500,
    ///   "dt": 0.002, "nu": 0.01, "checkpoint_every": 100,
    ///   "machine": "juqueen", "collective_buffering": false, ...}`.
    pub fn from_json(doc: &str) -> Result<Scenario> {
        let j = Json::parse(doc)?;
        let name = j
            .get("scenario")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("config: missing 'scenario'"))?;
        let depth = j.get("depth").and_then(|x| x.as_usize()).unwrap_or(1) as u32;
        let mut sc = Scenario::by_name(name, depth)?;
        if let Some(v) = j.get("ranks").and_then(|x| x.as_usize()) {
            sc.ranks = v as u32;
        }
        if let Some(v) = j.get("steps").and_then(|x| x.as_usize()) {
            sc.steps = v as u64;
        }
        if let Some(v) = j.get("checkpoint_every").and_then(|x| x.as_usize()) {
            sc.checkpoint_every = v as u64;
        }
        if let Some(v) = j.get("dt").and_then(|x| x.as_f64()) {
            sc.params.dt = v as f32;
        }
        if let Some(v) = j.get("nu").and_then(|x| x.as_f64()) {
            sc.params.nu = v as f32;
        }
        if let Some(v) = j.get("alpha").and_then(|x| x.as_f64()) {
            sc.params.alpha = v as f32;
        }
        if let Some(v) = j.get("beta_g").and_then(|x| x.as_f64()) {
            sc.params.beta_g = v as f32;
        }
        if let Some(v) = j.get("alignment").and_then(|x| x.as_usize()) {
            sc.alignment = v as u64;
        }
        if let Some(m) = j.get("machine").and_then(|x| x.as_str()) {
            sc.machine = match m {
                "juqueen" => Machine::juqueen(),
                "supermuc" => Machine::supermuc(),
                "local" => Machine::local(),
                other => bail!("config: unknown machine '{other}'"),
            };
        }
        if let Some(v) = j.get("collective_buffering").and_then(|x| x.as_bool()) {
            sc.tuning.collective_buffering = v;
        }
        if let Some(v) = j.get("file_locking").and_then(|x| x.as_bool()) {
            sc.tuning.file_locking = v;
        }
        if let Some(v) = j.get("adaptive").and_then(|x| x.as_bool()) {
            sc.adaptive = v;
        }
        Ok(sc)
    }

    /// Materialise the scenario into a ready-to-step [`Simulation`].
    pub fn build(&self) -> Simulation {
        let domain = BBox::unit();
        let tree = if self.adaptive {
            let obstacles = self.obstacles.clone();
            SpaceTree::adaptive(domain, self.depth, &move |b: &BBox, _| {
                obstacles.iter().any(|o| {
                    let c = o.centre;
                    b.contains_point(c)
                        || (0..3).all(|a| {
                            c[a] + o.radius > b.min[a] && c[a] - o.radius < b.max[a]
                        })
                })
            })
        } else {
            SpaceTree::full(domain, self.depth)
        };
        let mut sim = Simulation::new(tree, self.ranks, self.bc, self.params);
        sim.init_temperature(self.t0);
        for o in &self.obstacles {
            steering::apply(
                &mut sim,
                &SteerCommand::AddObstacle {
                    centre: o.centre,
                    radius: o.radius,
                    temp: o.temp,
                    ignore_axis: o.axis,
                },
            );
        }
        sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build() {
        for name in ["channel", "theatre", "cavity"] {
            let sc = Scenario::by_name(name, 1).unwrap();
            let sim = sc.build();
            assert_eq!(sim.nbs.tree.len(), 9);
            if !sc.obstacles.is_empty() {
                assert!(sim.has_solids);
            }
        }
    }

    #[test]
    fn unknown_scenario_rejected() {
        assert!(Scenario::by_name("warpdrive", 1).is_err());
    }

    #[test]
    fn json_overrides_apply() {
        let sc = Scenario::from_json(
            r#"{"scenario": "channel", "depth": 2, "ranks": 8, "steps": 42,
                "dt": 0.001, "machine": "juqueen", "file_locking": true}"#,
        )
        .unwrap();
        assert_eq!(sc.depth, 2);
        assert_eq!(sc.ranks, 8);
        assert_eq!(sc.steps, 42);
        assert!((sc.params.dt - 0.001).abs() < 1e-9);
        assert_eq!(sc.machine.name, "JuQueen");
        assert!(sc.tuning.file_locking);
    }

    #[test]
    fn json_missing_scenario_is_error() {
        assert!(Scenario::from_json(r#"{"depth": 2}"#).is_err());
    }

    #[test]
    fn adaptive_tree_smaller_than_full() {
        let mut sc = Scenario::cavity(2);
        sc.adaptive = true;
        let sim = sc.build();
        let full = SpaceTree::full(BBox::unit(), 2).len();
        assert!(sim.nbs.tree.len() <= full);
    }

    #[test]
    fn theatre_has_heated_lamps() {
        let sc = Scenario::theatre(1);
        let sim = sc.build();
        let heated: usize = sim
            .grids
            .iter()
            .map(|g| {
                g.cell_type
                    .iter()
                    .filter(|&&c| c == crate::tree::dgrid::CellType::HeatedSolid as u8)
                    .count()
            })
            .sum();
        assert!(heated > 0);
    }
}
