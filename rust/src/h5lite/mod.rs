//! **h5lite** — a from-scratch, self-describing hierarchical file format.
//!
//! The image has no libhdf5, so the substrate the paper builds on (§3:
//! groups, datasets, attributes, hyperslabs, contiguous storage, alignment)
//! is implemented here directly. The format keeps HDF5's data model:
//!
//! * a tree of **groups** starting at a root group, each holding child
//!   groups, **datasets** (n-dimensional typed arrays) and **attributes**;
//! * a **storage model** with two dataset layouts: *contiguous* (one
//!   header-described linear array of raw little-endian bytes, optionally
//!   aligned to the file system's block size, paper §5.2) and — since
//!   format v2 — *chunked* (fixed row-count chunks, each stored as an
//!   independently compressed extent, mirroring HDF5's chunked storage +
//!   filter pipeline);
//! * **self-description**: a superblock with magic/version/endian tag and a
//!   metadata footer that fully describes the tree, so a reader needs no
//!   external schema;
//! * **hyperslab** I/O: row-range reads/writes against a dataset's first
//!   dimension, the access pattern of the paper's kernel (one contiguous
//!   row block per rank — disjointness is what makes disabling file locks
//!   safe). Chunked datasets decompress transparently on [`H5File::read_rows`].
//!
//! ## On-disk layout (format v2.1)
//!
//! ```text
//! [superblock 40 B] [data region …grows…] [metadata footer]
//! superblock: magic "MPH5LITE" | version u32 (1|2|3) | endian u32 = 0x01020304
//!           | footer_off u64 | footer_len u64 | alignment u32
//!           (version 3 on disk is spoken of as "format v2.1": v2 plus the
//!            free-list footer record below)
//!
//! data region:   contiguous payloads (aligned), compressed chunk extents
//!                (packed), retired footers and free holes, in allocation
//!                order — the free-space manager recycles the holes
//!
//! footer (per group, recursive):
//!   attrs:    n, then (name, tag u8, value)*
//!   datasets: n, then (name, dtype u8, shape u64s, layout)*
//!     layout v1:          offset u64                      (contiguous only)
//!     layout v2 tag 0:    offset u64                      (contiguous)
//!     layout v2 tag 1:    chunk_rows u64 | codec u8 | n_chunks u64
//!                         | n_present u32
//!                         | (chunk_no u64, offset u64, stored u64,
//!                            raw u64, checksum u32, chunk_codec u8)*
//!   groups:   n, then (name, group)*                      (recursive)
//!   free list (v2.1 only, after the root group):
//!             n u32, then (offset u64, len u64)*          offset-sorted,
//!                                                         coalesced
//! ```
//!
//! A v2.1 reader opens v1 and v2 files (v1 datasets decode as contiguous;
//! v2 files simply carry no free-list record); a v1 file refuses chunked
//! dataset creation. Chunk extents record *which* codec was actually
//! applied — a generalisation of HDF5's per-chunk filter mask carried by
//! the `chunk_codec` byte: `0` = stored raw (incompressible, never
//! expanded), `1` = the dataset's declared codec (the only non-zero value
//! pre-codec-v2 writers emitted, so old files decode unchanged), `2 + c` =
//! explicitly codec `c`. The codec-v2 **adaptive selector**
//! ([`codec::encode_chunk_adaptive`]) uses the explicit form to pick
//! LZ-family / entropy-family / `Store` per chunk: each writer
//! trial-compresses the chunk's token stream and stores whichever of
//! {raw, LZ, LZ + range-coder frame, LZ + tANS frame} is smallest —
//! preferring tANS between the two entropy backends while it stays within
//! a small ratio margin, for its decode speed — so smooth chunks get a
//! full two-stage pipeline while incompressible chunks never pay an
//! entropy stage. The entropy frame layout and the bypass of
//! high-entropy byte planes are documented in [`codec`]. (Deliberate
//! forward-compat caveat: the on-disk version tag stays 3, so a
//! pre-codec-v2 reader opens a file carrying explicit codec bytes and
//! fails the affected chunk *reads* — unknown-codec or checksum errors —
//! rather than refusing the open; shipping through the codec byte with no
//! version bump is what keeps every pre-existing file byte-compatible.)
//!
//! ## Free-space management (format v2.1)
//!
//! Rewriting a chunk retires its old extent to the **free-space manager**
//! instead of leaking it (the garbage HDF5 accrues until `h5repack`).
//! [`H5File::alloc`] serves new extents best-fit from the free list before
//! growing the file, so steering workloads that rewrite cell data repeatedly
//! keep the file near its single-write size. Two reuse policies
//! ([`ReusePolicy`]):
//!
//! * [`ReusePolicy::AfterCommit`] (default) — extents freed in the current
//!   commit epoch stay *pending* until the next [`H5File::commit`] durably
//!   supersedes the footer that references them; only then do they become
//!   allocatable. A crash at any point leaves the last committed
//!   superblock → footer → extent chain fully intact.
//! * [`ReusePolicy::Immediate`] — freed extents are allocatable at once
//!   (HDF5-like, minimal file growth): a rewrite that fits recycles its own
//!   slot in place, and fresh extents carry ~6 % adjacent slack so
//!   slightly-larger rewrites grow in place too. The price: a crash
//!   mid-epoch — or a reader that opened the file before the rewrite —
//!   finds the committed snapshot's rewritten chunks overwritten, failing
//!   their checksums (detected, never silent). Writer-exclusive sessions
//!   only; concurrent-reader workloads stay on `AfterCommit`.
//!
//! On top of `AfterCommit`, [`H5File::pin_epoch`] extends the one-commit
//! guarantee into a real single-writer/multi-reader contract: while an
//! [`EpochPin`] is alive, extents retired by later rewrites (and the
//! superseded footers) are **parked** in a generation-tagged retire queue
//! instead of becoming allocatable, so a reader holding its own handle on
//! the pinned epoch's committed state keeps reading byte-identical data
//! across arbitrarily many writer commits. The parked bytes stay part of
//! the free partition for [`H5File::verify`]'s accounting (their on-disk
//! free record already lists them — pins are in-process state), and they
//! release to the allocator the moment the last pin at or below their tag
//! drops. The `window::SnapshotReader` session is the intended consumer.
//!
//! Because a pinned epoch's extents are immutable for the pin's lifetime,
//! decoded chunk bytes can be shared *across handles*: the
//! [`SharedChunkCache`] keys entries by `(file, epoch, dataset, chunk)` and
//! serves every attached descriptor from one global byte budget, with
//! single-flight coalescing so concurrent misses on one chunk decode
//! exactly once. `window::ReaderPool` is the intended consumer; unattached
//! handles keep their private per-descriptor [`H5File::set_chunk_cache_budget`]
//! cache.
//!
//! [`H5File::repack`] is the `h5repack` analogue: it rewrites the file into
//! a fresh one with zero fragmentation (chunk extents copied verbatim, no
//! re-encode) and atomically renames it over the original.
//! [`H5File::verify`] is the `fsck` analogue: it walks superblock → footer →
//! chunk registry → extents → free list and reports overlaps, leaks and
//! checksum mismatches in a [`VerifyReport`].
//!
//! ## Commit protocol (crash consistency)
//!
//! [`H5File::commit`] writes the footer into a free hole (or *appends* it
//! past the end of the data region) — never over the live one — then issues
//! a durability barrier, updates the superblock in place, and barriers
//! again. The two barriers order footer-before-superblock, so a torn commit
//! leaves the previous superblock pointing at the previous, untouched
//! footer. The superseded footer's extent is retired to the free-space
//! manager (v2.1) once the new one is live, and footer placement itself
//! recycles those holes via a two-pass record-sizing dance: the free record
//! is encoded once to learn the footer's size, the hole is carved, and the
//! record is re-encoded (now reflecting the carve — at alignment 1 the
//! re-encode can only shrink) and zero-padded to the reserved size. Heavy
//! commit churn therefore stays bounded even for contiguous-only files.
//! Files are only ever grown, never truncated: a concurrent reader (the
//! offline sliding window reading snapshots while the run continues) can
//! never see the file shrink below a committed footer. Dataset payload
//! writes go through the store's positional I/O, so concurrent writers (the
//! collective-buffering aggregators) need no shared cursor and no locking.
//!
//! ## Storage backends
//!
//! Every raw byte operation goes through the [`store::Store`] seam
//! (selected at create/open time via [`H5File::create_backed`] /
//! [`H5File::open_backed`], defaulting to direct):
//!
//! * [`store::Backing::Direct`] ([`store::DirectFile`]) — positional I/O
//!   straight to the descriptor. **Durability contract:** every dataset
//!   write is on disk when the call returns; each commit barrier is a
//!   synchronous `sync_data`, so when [`H5File::commit`] returns the epoch
//!   is durable.
//! * [`store::Backing::Paged`] ([`store::PagedImage`]) — writes land in a
//!   64 MiB-paged in-memory image and return at memory speed; commit's
//!   barriers snapshot the dirty ranges (contents included) into an ordered
//!   queue that a background flusher streams to disk, fsyncing between
//!   batches. **Durability contract:** when [`H5File::commit`] returns the
//!   epoch is *consistent in the image* and its durability ordering is
//!   recorded; it becomes durable asynchronously, strictly in barrier
//!   order, so a crash mid-flush recovers to the last *durably* committed
//!   epoch (never a torn one). [`H5File::wait_durable`] blocks until every
//!   issued barrier has hit disk; [`H5File::flush_stats`] exposes the
//!   backlog. After the handle drops, both backends leave byte-identical
//!   files.
//!
//! On format v2.1 under [`ReusePolicy::AfterCommit`], contiguous dataset
//! rewrites are *epoch-versioned*: the first write after a commit goes to a
//! freshly allocated extent (untouched bytes copied over) and the committed
//! extent retires through the pin-aware queue, mirroring what chunk extents
//! and the footer always did. Committed bytes are therefore never
//! overwritten in place on any layout, which closes the one torn-flush
//! caveat the paged backend had (a crash mid-flush used to be able to tear
//! a rewritten contiguous extent of the *recovered* epoch) and makes every
//! flush batch self-contained for the in-transit streaming tee
//! ([`H5File::set_batch_sink`] / [`crate::stream`]).
//!
//! `verify()`, epoch pins, the shared chunk cache and SWMR semantics are
//! backend-independent: they act on the logical byte store, which both
//! backends present identically.

pub mod codec;
pub mod store;

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};

use codec::{Codec, Dec, Enc};
use store::{DirectFile, PagedImage};
pub use store::{Backing, BatchSink, FlushStats, Store};

const MAGIC: &[u8; 8] = b"MPH5LITE";
/// Original contiguous-only format.
pub const FORMAT_V1: u32 = 1;
/// Chunked + compressed dataset storage.
pub const FORMAT_V2: u32 = 2;
/// Format v2.1 (on-disk version tag 3): v2 plus the persistent free-list
/// record — abandoned chunk extents and superseded footers are recycled by
/// the free-space manager instead of leaked.
pub const FORMAT_V21: u32 = 3;
/// Default format for newly created files.
pub const VERSION: u32 = FORMAT_V21;
const ENDIAN_TAG: u32 = 0x0102_0304;
const SUPERBLOCK_LEN: u64 = 40;

/// Element type of a dataset (subset of HDF5's type system used here).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dtype {
    F32,
    F64,
    U64,
    U8,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
            Dtype::U64 => 8,
            Dtype::U8 => 1,
        }
    }

    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::U64 => 2,
            Dtype::U8 => 3,
        }
    }

    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F64,
            2 => Dtype::U64,
            3 => Dtype::U8,
            _ => bail!("h5lite: unknown dtype code {c}"),
        })
    }
}

/// Attribute value (attached to groups, as in HDF5).
#[derive(Clone, PartialEq, Debug)]
pub enum Attr {
    F64(f64),
    I64(i64),
    Str(String),
    F64Vec(Vec<f64>),
}

/// Physical storage layout of a dataset.
#[derive(Clone, PartialEq, Debug)]
pub enum Layout {
    /// One linear reservation at `offset` (format v1's only layout).
    Contiguous { offset: u64 },
    /// Fixed `chunk_rows`-row chunks, each an independently compressed
    /// extent located through the file's chunk registry (key `id`).
    Chunked {
        chunk_rows: u64,
        codec: Codec,
        id: u64,
    },
}

/// Location of one written chunk in the data region.
#[derive(Clone, Copy, Debug)]
pub struct ChunkLoc {
    /// Absolute file offset of the stored extent.
    pub offset: u64,
    /// Stored (possibly compressed) byte count.
    pub stored: u64,
    /// Raw (decoded) byte count.
    pub raw: u64,
    /// FNV-1a checksum of the raw bytes, verified on read.
    pub checksum: u32,
    /// The codec that produced the stored extent: `None` = stored raw
    /// (incompressible — HDF5's per-chunk filter mask), `Some(c)` = decode
    /// with `c`, which the adaptive selector may pick per chunk
    /// independently of the dataset's declared codec.
    pub codec: Option<Codec>,
}

/// Per-dataset chunk index: entry `i` locates chunk `i`, `None` = never
/// written (reads return zeros, matching HDF5 fill-value semantics).
struct ChunkTable {
    entries: Vec<Option<ChunkLoc>>,
}

type ChunkRegistry = HashMap<u64, ChunkTable>;

/// When a freed extent becomes allocatable again (format v2.1).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReusePolicy {
    /// Freed extents stay pending until the next [`H5File::commit`]: the
    /// footer that referenced them must be durably superseded before their
    /// bytes may be overwritten, so a crash at any point leaves the last
    /// committed superblock → footer → extent chain intact. The price is
    /// one commit epoch of lag before space comes back.
    AfterCommit,
    /// Freed extents are allocatable immediately (HDF5-like): a chunk
    /// rewrite that fits recycles its own slot in place, fresh extents
    /// carry ~1/16 adjacent slack so slightly-larger rewrites grow in
    /// place too, and the file barely grows. The trade-off is that bytes
    /// the *committed* footer references get overwritten mid-epoch: a
    /// crash — or a concurrent reader that opened the file before the
    /// rewrite — sees checksum-mismatch errors on the rewritten chunks
    /// (detected, never silent). Pick [`ReusePolicy::AfterCommit`] when
    /// readers work the file while the run keeps writing; pick this for
    /// writer-exclusive steering sessions where file growth matters most.
    Immediate,
}

/// The free-space manager's extent set: offset → length, non-overlapping,
/// coalesced (no two entries touch). Persisted in the v2.1 footer.
///
/// Two views of the same extents are kept in lockstep: the offset-ordered
/// map (coalescing, persistence, range carving) and a size-ordered index
/// making [`FreeList::alloc`]'s best-fit O(log n) — steering runs with
/// thousands of chunks under [`ReusePolicy::Immediate`] fragment heavily,
/// and the old linear scan ran under the free mutex on every chunk write.
#[derive(Clone, Debug, Default)]
struct FreeList {
    extents: BTreeMap<u64, u64>,
    /// `(len, off)` per extent — iteration order *is* best-fit order
    /// (smallest fitting length, lowest offset among ties), matching the
    /// linear scan this index replaced (property-tested below).
    by_size: BTreeSet<(u64, u64)>,
    /// Cached sum of all extent lengths.
    total: u64,
}

impl FreeList {
    /// Add one extent to both views (no coalescing, no `total` update).
    fn attach(&mut self, off: u64, len: u64) {
        self.extents.insert(off, len);
        self.by_size.insert((len, off));
    }

    /// Remove one extent from both views (no `total` update).
    fn detach(&mut self, off: u64, len: u64) {
        self.extents.remove(&off);
        self.by_size.remove(&(len, off));
    }

    /// Add `[offset, offset + len)`, coalescing with touching neighbours.
    fn insert(&mut self, offset: u64, len: u64) {
        if len == 0 {
            return;
        }
        self.total += len;
        let mut off = offset;
        let mut len = len;
        let prev = self
            .extents
            .range(..off)
            .next_back()
            .map(|(&po, &pl)| (po, pl));
        if let Some((po, pl)) = prev {
            if po + pl == off {
                self.detach(po, pl);
                off = po;
                len += pl;
            }
        }
        let next = self
            .extents
            .range(off + len..)
            .next()
            .map(|(&no, &nl)| (no, nl));
        if let Some((no, nl)) = next {
            if off + len == no {
                self.detach(no, nl);
                len += nl;
            }
        }
        self.attach(off, len);
    }

    /// Best-fit allocation honouring `align`: carve `nbytes` out of the
    /// smallest extent that can hold them at an aligned start. Head and
    /// tail fragments go back on the list. O(log n) through the size
    /// index; the walk past the lower bound only visits extents big enough
    /// to fit, and almost always takes the first (alignment can skip a
    /// few).
    fn alloc(&mut self, nbytes: u64, align: u64) -> Option<u64> {
        if nbytes == 0 {
            return None;
        }
        let align = align.max(1);
        let mut found: Option<(u64, u64)> = None; // (len, off)
        for &(len, off) in self.by_size.range((nbytes, 0)..) {
            let aligned = off.next_multiple_of(align);
            if aligned - off + nbytes <= len {
                found = Some((len, off));
                break;
            }
        }
        let (len, off) = found?;
        self.detach(off, len);
        self.total -= len;
        let aligned = off.next_multiple_of(align);
        self.insert(off, aligned - off);
        self.insert(aligned + nbytes, off + len - (aligned + nbytes));
        Some(aligned)
    }

    /// Carve exactly `[offset, offset + len)` out of the free set if that
    /// whole range is currently free — used to grow a chunk in place into
    /// the slack left after it.
    fn take_range(&mut self, offset: u64, len: u64) -> bool {
        if len == 0 {
            return true;
        }
        let covering = self
            .extents
            .range(..=offset)
            .next_back()
            .map(|(&eo, &el)| (eo, el));
        let Some((eo, el)) = covering else {
            return false;
        };
        if eo + el < offset + len {
            return false;
        }
        self.detach(eo, el);
        self.total -= el;
        self.insert(eo, offset - eo);
        self.insert(offset + len, eo + el - (offset + len));
        true
    }

    /// Move every extent of `other` into `self`.
    fn absorb(&mut self, other: FreeList) {
        for (off, len) in other.extents {
            self.insert(off, len);
        }
    }
}

/// Free-space state shared between an [`H5File`] handle and the
/// [`EpochPin`]s held by long-lived readers (the `window::SnapshotReader`
/// session): a pin must survive `&mut` use of the file handle — the writer
/// keeps rewriting and committing while sessions read — so this state
/// lives behind an `Arc` instead of in the handle itself.
struct SpaceShared {
    /// Allocatable free extents.
    free: OrderedMutex<FreeList>,
    /// Extents retired this epoch under [`ReusePolicy::AfterCommit`]: the
    /// live committed footer still references them.
    pending: OrderedMutex<FreeList>,
    /// Generation-tagged retire queue: extents (and superseded footers)
    /// already unreferenced by the live footer, but retired while commit
    /// epoch `tag` was current. A session pinned at epoch `P` opened the
    /// footer of commit `P`, which may reference any extent tagged `>= P`,
    /// so an entry releases to `free` only once every pin `<= tag` is
    /// gone. On disk these bytes are recorded as free — pins are
    /// in-process state, and a fresh open has no sessions to protect.
    parked: OrderedMutex<BTreeMap<u64, FreeList>>,
    /// Pinned commit epoch → number of live [`EpochPin`]s. Held across the
    /// commit's epoch-bump + park-vs-free decision and across
    /// [`H5File::pin_epoch`]'s load + insert, so neither side can slip
    /// between the other's steps (the freed-while-pinned race — model (b)
    /// in [`crate::sync::protocols`]).
    pins: OrderedMutex<BTreeMap<u64, u64>>,
    /// Commits completed through this handle (the in-process epoch clock;
    /// not persisted — see `parked` for why that is sound).
    epoch: AtomicU64,
}

impl Default for SpaceShared {
    fn default() -> SpaceShared {
        SpaceShared {
            free: OrderedMutex::new(LockRank::SpaceFree, FreeList::default()),
            pending: OrderedMutex::new(LockRank::SpacePending, FreeList::default()),
            parked: OrderedMutex::new(LockRank::SpaceParked, BTreeMap::new()),
            pins: OrderedMutex::new(LockRank::SpacePins, BTreeMap::new()),
            epoch: AtomicU64::new(0),
        }
    }
}

impl SpaceShared {
    /// Smallest pinned epoch, if any session is alive.
    fn min_pin(&self) -> Option<u64> {
        self.pins.lock().unwrap().keys().next().copied()
    }

    /// Bytes held in the generation-tagged retire queue.
    fn parked_bytes(&self) -> u64 {
        self.parked.lock().unwrap().values().map(|fl| fl.total).sum()
    }

    /// Release every parked generation no pin can still reference back to
    /// the free list. Called when a pin drops and after each commit.
    fn release_parked(&self) {
        let min_pin = self.min_pin();
        let released: Vec<FreeList> = {
            let mut parked = self.parked.lock().unwrap();
            match min_pin {
                // entries tagged >= the smallest pin stay parked
                Some(p) => {
                    let keep = parked.split_off(&p);
                    std::mem::replace(&mut *parked, keep).into_values().collect()
                }
                None => std::mem::take(&mut *parked).into_values().collect(),
            }
        };
        if !released.is_empty() {
            let mut free = self.free.lock().unwrap();
            for fl in released {
                free.absorb(fl);
            }
        }
    }
}

/// Guard returned by [`H5File::pin_epoch`]. While it lives, every extent
/// the pinned commit epoch's footer references — including extents retired
/// by later rewrites and the superseded footer itself — stays off the
/// allocator, so a reader that opened the file at that epoch keeps reading
/// byte-identical data across any number of later commits. This is the
/// SWMR contract behind the `window::SnapshotReader` session; it extends
/// the one-commit [`ReusePolicy::AfterCommit`] guarantee to arbitrarily
/// many epochs. Not honoured by [`ReusePolicy::Immediate`] (which recycles
/// extents in place and is writer-exclusive by contract) and meaningless
/// on v1/v2 files (they never recycle at all). Dropping the pin releases
/// the extents it parked back to the free list at once.
pub struct EpochPin {
    space: Arc<SpaceShared>,
    epoch: u64,
}

impl EpochPin {
    /// The pinned commit epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for EpochPin {
    fn drop(&mut self) {
        {
            let mut pins = self.space.pins.lock().unwrap();
            if let Some(n) = pins.get_mut(&self.epoch) {
                *n -= 1;
                if *n == 0 {
                    pins.remove(&self.epoch);
                }
            }
        }
        self.space.release_parked();
    }
}

/// Space accounting of one file's data region (see [`H5File::space_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpaceStats {
    /// Physical bytes past the superblock (file length − 40).
    pub file_bytes: u64,
    /// Allocatable free bytes (the free list).
    pub free_bytes: u64,
    /// Bytes retired since the last commit, allocatable after it.
    pub pending_bytes: u64,
    /// Bytes already unreferenced by the live footer but parked for epoch
    /// pins ([`H5File::pin_epoch`]) — allocatable once the pinning read
    /// sessions drop.
    pub pinned_bytes: u64,
    /// Cumulative bytes ever retired to the free-space manager.
    pub reclaimed_bytes: u64,
    /// Cumulative bytes served from the free list instead of appended.
    pub reused_bytes: u64,
}

/// Cumulative physical-read accounting of one file handle (see
/// [`H5File::read_stats`]) — the read-side counterpart of [`SpaceStats`],
/// used by the `window::SnapshotReader` session to report index-read
/// amortisation and chunk-cache effectiveness.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReadStats {
    /// Payload bytes physically read from disk: stored chunk extents plus
    /// contiguous slabs. Decoded-chunk cache hits read nothing.
    pub read_bytes: u64,
    /// Chunk reads served from the decoded-chunk cache.
    pub cache_hits: u64,
    /// Chunk reads that had to load (and decode) the extent.
    pub cache_misses: u64,
    /// Of the cache hits, reads that *waited on another thread's in-flight
    /// decode* of the same chunk instead of decoding it again — the
    /// [`SharedChunkCache`]'s single-flight coalescing. Always 0 on the
    /// private per-handle cache (it never coalesces).
    pub coalesced: u64,
}

/// Outcome of an fsck-style [`H5File::verify`] walk.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// End of the data region (physical file length).
    pub data_end: u64,
    /// Dataset payload bytes: contiguous reservations + stored chunk
    /// extents.
    pub live_bytes: u64,
    /// Metadata bytes: superblock + the committed footer.
    pub meta_bytes: u64,
    /// Free bytes known to the free-space manager (free + pending).
    pub free_bytes: u64,
    /// Bytes accounted to nothing: alignment padding, superseded footers
    /// and extents leaked before the free-space manager existed (v1/v2).
    pub leaked_bytes: u64,
    pub n_datasets: u64,
    pub n_chunks: u64,
    /// Human-readable findings: overlaps, out-of-bounds extents, checksum
    /// mismatches. Empty ⇔ the file is consistent.
    pub errors: Vec<String>,
}

impl VerifyReport {
    /// True when the walk found no structural damage.
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// A dataset: typed n-dimensional array with a contiguous or chunked layout.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dtype: Dtype,
    /// Shape; the first dimension is the row (hyperslab) dimension.
    pub shape: Vec<u64>,
    pub layout: Layout,
}

impl Dataset {
    pub fn n_elems(&self) -> u64 {
        self.shape.iter().product()
    }

    pub fn n_bytes(&self) -> u64 {
        self.n_elems() * self.dtype.size() as u64
    }

    /// Elements per row (product of all dims after the first).
    pub fn row_elems(&self) -> u64 {
        self.shape.iter().skip(1).product()
    }

    pub fn row_bytes(&self) -> u64 {
        self.row_elems() * self.dtype.size() as u64
    }

    pub fn is_chunked(&self) -> bool {
        matches!(self.layout, Layout::Chunked { .. })
    }

    /// `(chunk_rows, codec, registry id)` for chunked datasets.
    pub fn chunk_meta(&self) -> Option<(u64, Codec, u64)> {
        match self.layout {
            Layout::Chunked {
                chunk_rows,
                codec,
                id,
            } => Some((chunk_rows, codec, id)),
            Layout::Contiguous { .. } => None,
        }
    }

    /// Payload offset of a contiguous dataset.
    pub fn contiguous_offset(&self) -> Option<u64> {
        match self.layout {
            Layout::Contiguous { offset } => Some(offset),
            Layout::Chunked { .. } => None,
        }
    }

    /// Number of chunks (0 for contiguous datasets).
    pub fn n_chunks(&self) -> u64 {
        match self.layout {
            Layout::Chunked { chunk_rows, .. } => self.shape[0].div_ceil(chunk_rows),
            Layout::Contiguous { .. } => 0,
        }
    }

    /// Rows in chunk `chunk_no` (the last chunk may be short).
    pub fn chunk_rows_at(&self, chunk_no: u64) -> u64 {
        match self.layout {
            Layout::Chunked { chunk_rows, .. } => {
                chunk_rows.min(self.shape[0].saturating_sub(chunk_no * chunk_rows))
            }
            Layout::Contiguous { .. } => 0,
        }
    }

    /// Walk the row range `[row_start, row_start + rows)` chunk by chunk,
    /// yielding `(chunk_no, row offset within the chunk, rows taken)` —
    /// the one place the chunk-boundary arithmetic lives, shared by the
    /// writer, the reader and the pario chunk bucketing. Empty for
    /// contiguous datasets and for ranges beyond the dataset extent
    /// (callers bounds-check first; this just refuses to spin).
    pub fn chunk_spans(&self, row_start: u64, rows: u64) -> impl Iterator<Item = (u64, u64, u64)> {
        let chunk_rows = match self.layout {
            Layout::Chunked { chunk_rows, .. } => chunk_rows,
            Layout::Contiguous { .. } => 0,
        };
        let shape0 = self.shape.first().copied().unwrap_or(0);
        let end = row_start + rows;
        let mut row = row_start;
        std::iter::from_fn(move || {
            if chunk_rows == 0 || row >= end {
                return None;
            }
            let chunk_no = row / chunk_rows;
            let chunk_first = chunk_no * chunk_rows;
            let rows_here = chunk_rows.min(shape0.saturating_sub(chunk_first));
            let chunk_end = chunk_first + rows_here;
            if chunk_end <= row {
                return None; // out of range: refuse to loop forever
            }
            let take = chunk_end.min(end) - row;
            let item = (chunk_no, row - chunk_first, take);
            row += take;
            Some(item)
        })
    }
}

/// A group: named attributes, child groups and datasets (BTreeMap for a
/// stable, deterministic iteration order in listings and the footer).
#[derive(Clone, Debug, Default)]
pub struct Group {
    pub attrs: BTreeMap<String, Attr>,
    pub groups: BTreeMap<String, Group>,
    pub datasets: BTreeMap<String, Dataset>,
}

/// Per-dataset state of the epoch-versioned contiguous write-aside (see
/// [`H5File::write_rows`]). Keyed in [`H5File`]'s `contig` map by the
/// dataset's *tree* offset — the offset recorded in the in-memory [`Layout`],
/// which never changes after creation and so stays a stable identity across
/// relocations (every consumer, pario included, keys datasets by it).
#[derive(Clone, Copy, Debug)]
struct ContigState {
    /// Where the payload currently lives; the footer encoder resolves the
    /// tree offset to this at commit time.
    cur: u64,
    /// Extent length in bytes. Kept here rather than derived from the
    /// [`Dataset`]: the collective writer passes synthetic handles whose
    /// shape is a row-addressing fiction, so relocation must size from the
    /// reservation, never from `Dataset::n_bytes` of the handle in hand.
    len: u64,
    /// Epoch the current extent was allocated in. `!=` the live epoch means
    /// the extent is referenced by the durable footer and the next write
    /// must go aside; `u64::MAX` (set on open) forces that on first write.
    epoch: u64,
}

/// Resolve a contiguous dataset's tree offset to the current payload extent.
fn resolve_contig(map: &HashMap<u64, ContigState>, tree_off: u64) -> u64 {
    map.get(&tree_off).map_or(tree_off, |s| s.cur)
}

/// Seed the write-aside map from a decoded tree: every extent the footer
/// references is committed, so `epoch: u64::MAX` forces the first
/// post-open write to relocate instead of tearing it.
fn seed_contig(g: &Group, map: &mut HashMap<u64, ContigState>) {
    for ds in g.datasets.values() {
        if let Layout::Contiguous { offset } = ds.layout {
            map.insert(
                offset,
                ContigState {
                    cur: offset,
                    len: ds.n_bytes(),
                    epoch: u64::MAX,
                },
            );
        }
    }
    for sub in g.groups.values() {
        seed_contig(sub, map);
    }
}

impl Group {
    fn encode(
        &self,
        e: &mut Enc,
        version: u32,
        reg: &ChunkRegistry,
        contig: &HashMap<u64, ContigState>,
    ) -> Result<()> {
        e.u32(self.attrs.len() as u32);
        for (name, a) in &self.attrs {
            e.str(name);
            match a {
                Attr::F64(v) => {
                    e.u8(0);
                    e.f64(*v);
                }
                Attr::I64(v) => {
                    e.u8(1);
                    e.i64(*v);
                }
                Attr::Str(v) => {
                    e.u8(2);
                    e.str(v);
                }
                Attr::F64Vec(v) => {
                    e.u8(3);
                    e.f64s(v);
                }
            }
        }
        e.u32(self.datasets.len() as u32);
        for (name, d) in &self.datasets {
            e.str(name);
            e.u8(d.dtype.code());
            e.u64s(&d.shape);
            match (&d.layout, version) {
                (Layout::Contiguous { offset }, FORMAT_V1) => {
                    e.u64(resolve_contig(contig, *offset))
                }
                (Layout::Chunked { .. }, FORMAT_V1) => {
                    bail!("h5lite: dataset '{name}' is chunked; format v1 cannot store it")
                }
                (Layout::Contiguous { offset }, _) => {
                    e.u8(0);
                    e.u64(resolve_contig(contig, *offset));
                }
                (
                    Layout::Chunked {
                        chunk_rows,
                        codec,
                        id,
                    },
                    _,
                ) => {
                    e.u8(1);
                    e.u64(*chunk_rows);
                    e.u8(codec.code());
                    let table = reg
                        .get(id)
                        .ok_or_else(|| anyhow!("h5lite: chunk table missing for '{name}'"))?;
                    e.u64(table.entries.len() as u64);
                    let present: Vec<(u64, ChunkLoc)> = table
                        .entries
                        .iter()
                        .enumerate()
                        .filter_map(|(i, l)| l.map(|loc| (i as u64, loc)))
                        .collect();
                    e.u32(present.len() as u32);
                    for (i, loc) in present {
                        e.u64(i);
                        e.u64(loc.offset);
                        e.u64(loc.stored);
                        e.u64(loc.raw);
                        e.u32(loc.checksum);
                        e.u8(codec::chunk_codec_to_byte(*codec, loc.codec));
                    }
                }
            }
        }
        e.u32(self.groups.len() as u32);
        for (name, g) in &self.groups {
            e.str(name);
            g.encode(e, version, reg, contig)?;
        }
        Ok(())
    }

    fn decode(
        d: &mut Dec,
        version: u32,
        reg: &mut ChunkRegistry,
        next_id: &mut u64,
    ) -> Result<Group> {
        let mut g = Group::default();
        let n_attrs = d.u32()?;
        for _ in 0..n_attrs {
            let name = d.str()?;
            let attr = match d.u8()? {
                0 => Attr::F64(d.f64()?),
                1 => Attr::I64(d.i64()?),
                2 => Attr::Str(d.str()?),
                3 => Attr::F64Vec(d.f64s()?),
                c => bail!("h5lite: unknown attr code {c}"),
            };
            g.attrs.insert(name, attr);
        }
        let n_ds = d.u32()?;
        for _ in 0..n_ds {
            let name = d.str()?;
            let dtype = Dtype::from_code(d.u8()?)?;
            let shape = d.u64s()?;
            let layout = if version == FORMAT_V1 {
                Layout::Contiguous { offset: d.u64()? }
            } else {
                match d.u8()? {
                    0 => Layout::Contiguous { offset: d.u64()? },
                    1 => {
                        let chunk_rows = d.u64()?;
                        let codec = Codec::from_code(d.u8()?)?;
                        let n_chunks = d.u64()?;
                        if chunk_rows == 0 {
                            bail!("h5lite: dataset '{name}' has zero chunk_rows");
                        }
                        let rows = shape.first().copied().unwrap_or(0);
                        if n_chunks != rows.div_ceil(chunk_rows) {
                            bail!(
                                "h5lite: dataset '{name}' chunk count {n_chunks} \
                                 inconsistent with {rows} rows / {chunk_rows}"
                            );
                        }
                        let mut entries: Vec<Option<ChunkLoc>> = vec![None; n_chunks as usize];
                        let n_present = d.u32()?;
                        for _ in 0..n_present {
                            let i = d.u64()? as usize;
                            if i >= entries.len() {
                                bail!("h5lite: chunk index {i} out of range in '{name}'");
                            }
                            entries[i] = Some(ChunkLoc {
                                offset: d.u64()?,
                                stored: d.u64()?,
                                raw: d.u64()?,
                                checksum: d.u32()?,
                                codec: codec::chunk_codec_from_byte(codec, d.u8()?)?,
                            });
                        }
                        let id = *next_id;
                        *next_id += 1;
                        reg.insert(id, ChunkTable { entries });
                        Layout::Chunked {
                            chunk_rows,
                            codec,
                            id,
                        }
                    }
                    t => bail!("h5lite: unknown layout tag {t}"),
                }
            };
            g.datasets.insert(
                name,
                Dataset {
                    dtype,
                    shape,
                    layout,
                },
            );
        }
        let n_groups = d.u32()?;
        for _ in 0..n_groups {
            let name = d.str()?;
            g.groups.insert(name, Group::decode(d, version, reg, next_id)?);
        }
        Ok(g)
    }
}

/// Decoded-chunk LRU cache keyed by `(dataset id, chunk no)`: the offline
/// sliding window and the snapshot restore read rows one at a time,
/// interleaving the three cell-data datasets, and multi-grid window
/// queries straddle chunk boundaries — the old one-slot-per-dataset cache
/// thrashed on the straddle and re-inflated the same chunks per query.
/// Capacity is a **byte budget** (the old fixed 16-slot cap made cache
/// size depend on chunk geometry): least-recently-used chunks evict until
/// the decoded bytes fit, so a long-lived reader session can size its
/// working set to the zoom sequence it serves
/// ([`H5File::set_chunk_cache_budget`]).
struct ChunkCache {
    map: HashMap<(u64, u64), (u64, Arc<Vec<u8>>)>,
    /// Monotonic access counter driving the LRU order.
    tick: u64,
    /// Decoded bytes currently resident.
    bytes: u64,
    budget: u64,
}

/// Default decoded-chunk cache budget per file handle: roughly the old
/// 16-slot cap at the 640 KiB cell-data chunk size, rounded up. Reader
/// sessions override it per workload.
pub const DEFAULT_CHUNK_CACHE_BYTES: u64 = 16 << 20;

impl Default for ChunkCache {
    fn default() -> ChunkCache {
        ChunkCache {
            map: HashMap::new(),
            tick: 0,
            bytes: 0,
            budget: DEFAULT_CHUNK_CACHE_BYTES,
        }
    }
}

/// Under [`ReusePolicy::Immediate`], fresh chunk extents are allocated
/// with `len / CHUNK_SLACK_DIV` bytes of adjacent slack (left on the free
/// list right after the extent), so a rewrite that compresses a few
/// percent *larger* still grows in place instead of abandoning its slot —
/// without it, steady-state file size under realistically varying chunk
/// sizes creeps toward ~1.5× (measured in simulation; with 1/16 slack it
/// stays ≤ ~1.06× through ±3 % size variance).
const CHUNK_SLACK_DIV: u64 = 16;

impl ChunkCache {
    fn get(&mut self, id: u64, chunk_no: u64) -> Option<Arc<Vec<u8>>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&(id, chunk_no)).map(|e| {
            e.0 = tick;
            Arc::clone(&e.1)
        })
    }

    fn insert(&mut self, id: u64, chunk_no: u64, data: Arc<Vec<u8>>) {
        let len = data.len() as u64;
        if len > self.budget {
            // larger than the whole budget: caching it would evict every
            // other resident chunk for one that cannot stay anyway
            self.invalidate(id, chunk_no);
            return;
        }
        self.tick += 1;
        if let Some((_, old)) = self.map.insert((id, chunk_no), (self.tick, data)) {
            self.bytes -= old.len() as u64;
        }
        self.bytes += len;
        while self.bytes > self.budget {
            let evict = self
                .map
                .iter()
                .filter(|(&k, _)| k != (id, chunk_no))
                .min_by_key(|(_, (tick, _))| *tick)
                .map(|(&k, _)| k);
            let Some(k) = evict else { break };
            self.invalidate(k.0, k.1);
        }
    }

    fn invalidate(&mut self, id: u64, chunk_no: u64) {
        if let Some((_, data)) = self.map.remove(&(id, chunk_no)) {
            self.bytes -= data.len() as u64;
        }
    }

    fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
        while self.bytes > self.budget {
            let evict = self
                .map
                .iter()
                .min_by_key(|(_, (tick, _))| *tick)
                .map(|(&k, _)| k);
            let Some(k) = evict else { break };
            self.invalidate(k.0, k.1);
        }
    }
}

// ---------------------------------------------------------------------------
// process-wide shared decoded-chunk cache (multi-tenant read serving)
// ---------------------------------------------------------------------------

/// Shards of a [`SharedChunkCache`]: enough that 64+ concurrent reader
/// sessions rarely contend on one lock, few enough that the global byte
/// budget split stays meaningful per shard.
const CACHE_SHARDS: usize = 16;

/// Key of one decoded chunk in a [`SharedChunkCache`]. The **epoch** is
/// what makes sharing across sessions sound: under the
/// [`ReusePolicy::AfterCommit`] + [`H5File::pin_epoch`] SWMR contract, the
/// bytes a pinned epoch's footer references are immutable while any pin at
/// that epoch lives, so an entry keyed by `(file, epoch, dataset, chunk)`
/// can never go stale — a writer commit simply moves fresh sessions to a
/// new epoch and new keys, and old-epoch entries age out by LRU.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct SharedKey {
    /// Registered file identity ([`SharedChunkCache::file_key`]).
    file: u64,
    /// Commit epoch the reading handle pinned at open.
    epoch: u64,
    /// Dataset id (deterministic per footer decode order).
    ds: u64,
    chunk: u64,
}

impl SharedKey {
    fn shard(&self) -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut h);
        (h.finish() as usize) % CACHE_SHARDS
    }
}

/// State of one in-flight chunk decode (the single-flight slot).
#[derive(Default)]
enum FlightState {
    #[default]
    Pending,
    Done(Arc<Vec<u8>>),
    /// The leader's load failed; waiters retry the full protocol (one of
    /// them becomes the next leader).
    Failed,
}

struct Inflight {
    state: OrderedMutex<FlightState>,
    cv: OrderedCondvar,
}

impl Default for Inflight {
    fn default() -> Inflight {
        Inflight {
            state: OrderedMutex::new(LockRank::FlightState, FlightState::default()),
            cv: OrderedCondvar::new(),
        }
    }
}

impl Inflight {
    fn resolve(&self, s: FlightState) {
        *self.state.lock().unwrap() = s;
        self.cv.notify_all();
    }

    /// Block until the leader resolves; `None` = the leader failed.
    fn wait(&self) -> Option<Arc<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        loop {
            match &*st {
                FlightState::Pending => st = self.cv.wait(st).unwrap(),
                FlightState::Done(d) => return Some(Arc::clone(d)),
                FlightState::Failed => return None,
            }
        }
    }
}

#[derive(Default)]
struct CacheShard {
    map: HashMap<SharedKey, (u64, Arc<Vec<u8>>)>,
    /// Decodes currently running with this shard's keys.
    inflight: HashMap<SharedKey, Arc<Inflight>>,
    bytes: u64,
}

/// Where a shared-cache request was served from.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum SharedOutcome {
    /// Resident in the cache.
    Hit,
    /// Waited on another thread's in-flight decode of the same chunk.
    Coalesced,
    /// This thread was the leader: it read and decoded the extent.
    Loaded,
}

/// Counter snapshot of a [`SharedChunkCache`] (see
/// [`SharedChunkCache::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SharedCacheStats {
    /// Decoded bytes currently resident across all shards.
    pub resident_bytes: u64,
    /// Requests served from a resident entry.
    pub hits: u64,
    /// Requests that read and decoded the extent (each decodes exactly
    /// once per `(file, epoch, dataset, chunk)` however many sessions
    /// miss concurrently).
    pub misses: u64,
    /// Requests that waited on another thread's in-flight decode instead
    /// of decoding again — the work the single-flight protocol saved.
    pub coalesced: u64,
    /// Raw decoded bytes produced by misses (the aggregate decode work;
    /// divide by bytes served to get the fan-out dedup factor).
    pub loaded_bytes: u64,
    /// Entries evicted to stay inside the byte budget.
    pub evictions: u64,
}

/// Process-wide, sharded, **epoch-aware** decoded-chunk cache: one
/// instance shared by every `window::SnapshotReader` session of a reader
/// pool, replacing N private per-descriptor caches that each decoded the
/// same chunks.
///
/// * Entries are keyed `(file, epoch, dataset, chunk)` ([`SharedKey`]) —
///   immutable under the epoch-pin SWMR contract, so sharing needs no
///   invalidation protocol across sessions.
/// * One **global byte budget** bounds all shards together; each shard
///   evicts its own LRU entries until the global total fits (hashed keys
///   keep shard occupancy balanced, so the approximation stays tight).
/// * Concurrent misses on one chunk **coalesce**: the first becomes the
///   leader and decodes outside every lock, the rest block on its
///   in-flight slot and are counted in [`SharedCacheStats::coalesced`].
///
/// Attach a handle with [`H5File::attach_shared_cache`]; reads then route
/// here instead of the private [`ChunkCache`].
pub struct SharedChunkCache {
    shards: Vec<OrderedMutex<CacheShard>>,
    budget: AtomicU64,
    /// Resident decoded bytes across all shards.
    bytes: AtomicU64,
    /// Global LRU clock (ticks are comparable across shards).
    tick: AtomicU64,
    /// Canonical path → registered file key.
    files: OrderedMutex<HashMap<PathBuf, u64>>,
    next_file: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    loaded_bytes: AtomicU64,
    evictions: AtomicU64,
}

impl SharedChunkCache {
    /// A cache bounded by `budget` decoded bytes (0 disables residency —
    /// single-flight coalescing still deduplicates concurrent decodes).
    pub fn new(budget: u64) -> Arc<SharedChunkCache> {
        Arc::new(SharedChunkCache {
            shards: (0..CACHE_SHARDS)
                .map(|_| OrderedMutex::new(LockRank::CacheShard, CacheShard::default()))
                .collect(),
            budget: AtomicU64::new(budget),
            bytes: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            files: OrderedMutex::new(LockRank::CacheFiles, HashMap::new()),
            next_file: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            loaded_bytes: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// Stable identity for `path` within this cache: same path → same key,
    /// so every handle opened on one file shares entries.
    pub fn file_key(&self, path: &Path) -> u64 {
        let mut files = self.files.lock().unwrap();
        if let Some(&k) = files.get(path) {
            return k;
        }
        let k = self.next_file.fetch_add(1, Ordering::Relaxed) + 1;
        files.insert(path.to_path_buf(), k);
        k
    }

    /// Current byte budget.
    pub fn budget(&self) -> u64 {
        self.budget.load(Ordering::Relaxed)
    }

    /// Change the byte budget, evicting LRU entries down to it.
    pub fn set_budget(&self, bytes: u64) {
        self.budget.store(bytes, Ordering::Relaxed);
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            while self.bytes.load(Ordering::Relaxed) > bytes {
                if !self.evict_lru_locked(&mut s, None) {
                    break;
                }
            }
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> SharedCacheStats {
        SharedCacheStats {
            resident_bytes: self.bytes.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            loaded_bytes: self.loaded_bytes.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// The single-flight read protocol. `load` runs outside every cache
    /// lock and only on the leader — concurrent callers of the same key
    /// block on the leader's slot instead. A failed leader wakes the
    /// waiters to retry (one becomes the next leader and calls its own
    /// `load`), so an I/O error never wedges the slot.
    fn get_or_load(
        &self,
        key: SharedKey,
        load: impl Fn() -> Result<Vec<u8>>,
    ) -> Result<(Arc<Vec<u8>>, SharedOutcome)> {
        let shard_no = key.shard();
        loop {
            let flight = {
                let mut shard = self.shards[shard_no].lock().unwrap();
                if let Some(entry) = shard.map.get_mut(&key) {
                    entry.0 = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(&entry.1), SharedOutcome::Hit));
                }
                match shard.inflight.get(&key) {
                    Some(f) => Arc::clone(f),
                    None => {
                        // leader: claim the slot, decode with no lock held
                        let slot = Arc::new(Inflight::default());
                        shard.inflight.insert(key, Arc::clone(&slot));
                        drop(shard);
                        let res = load();
                        let mut shard = self.shards[shard_no].lock().unwrap();
                        shard.inflight.remove(&key);
                        return match res {
                            Ok(raw) => {
                                let data = Arc::new(raw);
                                self.misses.fetch_add(1, Ordering::Relaxed);
                                self.loaded_bytes
                                    .fetch_add(data.len() as u64, Ordering::Relaxed);
                                self.insert_locked(&mut shard, key, Arc::clone(&data));
                                slot.resolve(FlightState::Done(Arc::clone(&data)));
                                Ok((data, SharedOutcome::Loaded))
                            }
                            Err(e) => {
                                slot.resolve(FlightState::Failed);
                                Err(e)
                            }
                        };
                    }
                }
            };
            if let Some(data) = flight.wait() {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                return Ok((data, SharedOutcome::Coalesced));
            }
            // leader failed — retry the protocol from the top
        }
    }

    /// Insert under the shard lock, then evict this shard's LRU entries
    /// while the **global** total exceeds the budget. A shard that runs
    /// empty leaves the residue to the other shards' next inserts — a
    /// bounded transient, since hashed keys spread occupancy evenly.
    fn insert_locked(&self, shard: &mut CacheShard, key: SharedKey, data: Arc<Vec<u8>>) {
        let len = data.len() as u64;
        let budget = self.budget.load(Ordering::Relaxed);
        if len > budget {
            return; // would evict everything for an entry that cannot stay
        }
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some((_, old)) = shard.map.insert(key, (tick, data)) {
            shard.bytes -= old.len() as u64;
            self.bytes.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
        shard.bytes += len;
        self.bytes.fetch_add(len, Ordering::Relaxed);
        while self.bytes.load(Ordering::Relaxed) > budget {
            if !self.evict_lru_locked(shard, Some(key)) {
                break;
            }
        }
    }

    /// Evict the shard's LRU entry (sparing `keep`); false if none left.
    fn evict_lru_locked(&self, shard: &mut CacheShard, keep: Option<SharedKey>) -> bool {
        let victim = shard
            .map
            .iter()
            .filter(|(&k, _)| Some(k) != keep)
            .min_by_key(|(_, (tick, _))| *tick)
            .map(|(&k, _)| k);
        let Some(k) = victim else { return false };
        let (_, old) = shard.map.remove(&k).unwrap();
        shard.bytes -= old.len() as u64;
        self.bytes.fetch_sub(old.len() as u64, Ordering::Relaxed);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Drop one entry (a writer rewrote the chunk through a shared-attached
    /// handle at this epoch).
    fn invalidate(&self, key: SharedKey) {
        let mut shard = self.shards[key.shard()].lock().unwrap();
        if let Some((_, old)) = shard.map.remove(&key) {
            shard.bytes -= old.len() as u64;
            self.bytes.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
    }
}

/// A handle's binding to a process-wide [`SharedChunkCache`].
struct SharedAttachment {
    cache: Arc<SharedChunkCache>,
    file_key: u64,
    /// The commit epoch this handle's footer belongs to (pinned by the
    /// opener) — baked into every cache key.
    epoch: u64,
}

/// An h5lite file handle.
///
/// Creation/structure mutation requires `&mut self` (matching Parallel
/// HDF5's rule that groups and datasets are created *collectively*); slab
/// reads/writes take `&self` and may run concurrently from many threads
/// (each rank/aggregator owns a disjoint row range, and the chunk
/// allocator/index are internally locked).
pub struct H5File {
    /// The byte store every raw I/O goes through — [`DirectFile`] or
    /// [`PagedImage`], fixed when the handle is created/opened.
    file: Box<dyn Store>,
    pub path: PathBuf,
    pub root: Group,
    /// Next free data offset (end of data region).
    data_end: OrderedMutex<u64>,
    /// Alignment for contiguous dataset payload starts (paper §5.2;
    /// 1 = none). Compressed chunk extents are packed unaligned.
    pub alignment: u64,
    version: u32,
    chunks: OrderedMutex<ChunkRegistry>,
    next_ds_id: AtomicU64,
    /// Free-space manager state (free / pending / parked extents, the
    /// epoch clock and the pin table), shared with [`EpochPin`]s so read
    /// sessions outlive `&mut` use of this handle. Always empty on v1/v2.
    space: Arc<SpaceShared>,
    /// Extent of the footer the on-disk superblock points at, `(off, len)`
    /// (`(0, 0)` before the first commit). Never overwritten in place;
    /// retired to the free-space manager when superseded.
    committed_footer: OrderedMutex<(u64, u64)>,
    reuse_policy: ReusePolicy,
    /// Cumulative bytes retired to the free-space manager.
    reclaimed: AtomicU64,
    /// Cumulative bytes served from the free list instead of appended.
    reused: AtomicU64,
    /// Cumulative payload bytes physically read (see [`ReadStats`]).
    read_bytes: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache: OrderedMutex<ChunkCache>,
    /// Bumped on every chunk-extent write; readers snapshot it before
    /// loading an extent and only populate the cache if it is unchanged
    /// after decoding, so a write racing a reader of the same chunk can
    /// never leave pre-write bytes cached (the returned slice itself is
    /// safe — disjoint-range readers only consume rows the writer did not
    /// touch).
    cache_gen: AtomicU64,
    /// Of the cache hits, reads that coalesced onto another thread's
    /// in-flight decode (shared cache only; see [`ReadStats::coalesced`]).
    cache_coalesced: AtomicU64,
    /// When set, chunk reads route to this process-wide epoch-keyed cache
    /// instead of the private [`ChunkCache`]
    /// (see [`H5File::attach_shared_cache`]).
    shared_cache: Option<SharedAttachment>,
    /// Serialises read-modify-write row writes on chunked datasets: two
    /// disjoint row ranges can share a chunk, and the RMW (read, patch,
    /// re-encode, swap extent) is not atomic per chunk. Chunk-granular
    /// writers ([`H5File::write_chunk_encoded`], used by the aggregators)
    /// bypass this and stay fully parallel.
    rmw: OrderedMutex<()>,
    /// Epoch-versioned contiguous write-aside state, keyed by tree offset
    /// (see [`ContigState`]). Always consulted for resolution; relocation
    /// itself only happens on v2.1 under [`ReusePolicy::AfterCommit`].
    contig: OrderedMutex<HashMap<u64, ContigState>>,
}

impl H5File {
    /// Create a new file (truncating any existing one) in the default
    /// format. `alignment` aligns every contiguous dataset payload to that
    /// many bytes (use the file system block size; 1 disables).
    pub fn create<P: AsRef<Path>>(path: P, alignment: u64) -> Result<H5File> {
        H5File::create_versioned(path, alignment, VERSION)
    }

    /// [`H5File::create`] on an explicit storage backend.
    pub fn create_backed<P: AsRef<Path>>(
        path: P,
        alignment: u64,
        backing: Backing,
    ) -> Result<H5File> {
        H5File::create_versioned_backed(path, alignment, VERSION, backing)
    }

    /// Create a new file in an explicit format version (v1 = contiguous
    /// only, for compatibility tests and old readers; v2 = chunked +
    /// compressed storage; v2.1 = v2 + the persistent free-space manager).
    pub fn create_versioned<P: AsRef<Path>>(
        path: P,
        alignment: u64,
        version: u32,
    ) -> Result<H5File> {
        H5File::create_versioned_backed(path, alignment, version, Backing::Direct)
    }

    /// [`H5File::create_versioned`] on an explicit storage backend.
    pub fn create_versioned_backed<P: AsRef<Path>>(
        path: P,
        alignment: u64,
        version: u32,
        backing: Backing,
    ) -> Result<H5File> {
        assert!(alignment >= 1);
        if !(FORMAT_V1..=FORMAT_V21).contains(&version) {
            bail!("h5lite: cannot create format v{version}");
        }
        let file: Box<dyn Store> = match backing {
            Backing::Direct => Box::new(
                DirectFile::create(path.as_ref())
                    .with_context(|| format!("h5lite: create {:?}", path.as_ref()))?,
            ),
            Backing::Paged => Box::new(
                PagedImage::create(path.as_ref())
                    .with_context(|| format!("h5lite: create {:?}", path.as_ref()))?,
            ),
        };
        let mut f = H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root: Group::default(),
            data_end: OrderedMutex::new(LockRank::FileDataEnd, SUPERBLOCK_LEN),
            alignment,
            version,
            chunks: OrderedMutex::new(LockRank::FileChunks, HashMap::new()),
            next_ds_id: AtomicU64::new(1),
            space: Arc::new(SpaceShared::default()),
            committed_footer: OrderedMutex::new(LockRank::FileCommittedFooter, (0, 0)),
            reuse_policy: ReusePolicy::AfterCommit,
            reclaimed: AtomicU64::new(0),
            reused: AtomicU64::new(0),
            read_bytes: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache: OrderedMutex::new(LockRank::FileCache, ChunkCache::default()),
            cache_gen: AtomicU64::new(0),
            cache_coalesced: AtomicU64::new(0),
            shared_cache: None,
            rmw: OrderedMutex::new(LockRank::FileRmw, ()),
            contig: OrderedMutex::new(LockRank::FileContig, HashMap::new()),
        };
        f.commit()?;
        Ok(f)
    }

    /// Open an existing file (read + write). Accepts formats v1, v2 and
    /// v2.1.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<H5File> {
        H5File::open_backed(path, Backing::Direct)
    }

    /// [`H5File::open`] on an explicit storage backend.
    pub fn open_backed<P: AsRef<Path>>(path: P, backing: Backing) -> Result<H5File> {
        let file: Box<dyn Store> = match backing {
            Backing::Direct => Box::new(
                DirectFile::open(path.as_ref())
                    .with_context(|| format!("h5lite: open {:?}", path.as_ref()))?,
            ),
            Backing::Paged => Box::new(
                PagedImage::open(path.as_ref())
                    .with_context(|| format!("h5lite: open {:?}", path.as_ref()))?,
            ),
        };
        let mut sb = [0u8; SUPERBLOCK_LEN as usize];
        file.read_exact_at(&mut sb, 0)
            .context("h5lite: short superblock")?;
        if &sb[0..8] != MAGIC {
            bail!("h5lite: bad magic in {:?}", path.as_ref());
        }
        let mut d = Dec::new(&sb[8..]);
        let version = d.u32()?;
        if !(FORMAT_V1..=FORMAT_V21).contains(&version) {
            bail!("h5lite: unsupported version {version}");
        }
        let endian = d.u32()?;
        if endian != ENDIAN_TAG {
            bail!("h5lite: endianness tag mismatch (cross-endian file?)");
        }
        let footer_off = d.u64()?;
        let footer_len = d.u64()?;
        let alignment = d.u32()? as u64;
        let mut footer = vec![0u8; footer_len as usize];
        file.read_exact_at(&mut footer, footer_off)
            .context("h5lite: short footer")?;
        let mut fd = Dec::new(&footer);
        let mut reg = HashMap::new();
        let mut next_id = 1u64;
        let root = Group::decode(&mut fd, version, &mut reg, &mut next_id)?;
        let mut free = FreeList::default();
        if version >= FORMAT_V21 {
            let n = fd.u32()?;
            for _ in 0..n {
                let off = fd.u64()?;
                let len = fd.u64()?;
                free.insert(off, len);
            }
        }
        // The data region spans the whole file: the committed footer is an
        // allocation like any other (appended by commit, never overwritten
        // in place). Trailing bytes past the footer — writes after the last
        // commit of a crashed run — are treated as leaked, never reused.
        let file_len = file
            .len()
            .context("h5lite: stat")?
            .max(footer_off.saturating_add(footer_len));
        let mut contig = HashMap::new();
        seed_contig(&root, &mut contig);
        Ok(H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root,
            data_end: OrderedMutex::new(LockRank::FileDataEnd, file_len),
            alignment,
            version,
            chunks: OrderedMutex::new(LockRank::FileChunks, reg),
            next_ds_id: AtomicU64::new(next_id),
            space: Arc::new(SpaceShared {
                free: OrderedMutex::new(LockRank::SpaceFree, free),
                ..SpaceShared::default()
            }),
            committed_footer: OrderedMutex::new(LockRank::FileCommittedFooter, (footer_off, footer_len)),
            reuse_policy: ReusePolicy::AfterCommit,
            reclaimed: AtomicU64::new(0),
            reused: AtomicU64::new(0),
            read_bytes: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache: OrderedMutex::new(LockRank::FileCache, ChunkCache::default()),
            cache_gen: AtomicU64::new(0),
            cache_coalesced: AtomicU64::new(0),
            shared_cache: None,
            rmw: OrderedMutex::new(LockRank::FileRmw, ()),
            contig: OrderedMutex::new(LockRank::FileContig, contig),
        })
    }

    /// Route this handle's chunk reads through a process-wide
    /// [`SharedChunkCache`] instead of the private per-handle cache.
    ///
    /// `epoch` must identify the commit whose footer this handle opened —
    /// callers pin it first ([`H5File::pin_epoch`]) and attach immediately
    /// after open, before any read. Under that contract every extent the
    /// footer references is immutable while the pin lives, so entries keyed
    /// `(file, epoch, dataset, chunk)` are shared safely across any number
    /// of concurrently reading handles and sessions.
    pub fn attach_shared_cache(&mut self, cache: &Arc<SharedChunkCache>, epoch: u64) {
        let file_key = cache.file_key(&self.path);
        self.shared_cache = Some(SharedAttachment {
            cache: Arc::clone(cache),
            file_key,
            epoch,
        });
    }

    /// On-disk format version of this file.
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Which storage backend this handle runs on (see the module-level
    /// *Storage backends* section for the durability contract of each).
    pub fn backing(&self) -> Backing {
        self.file.backing()
    }

    /// Counter snapshot of the backend's flush machinery: dirty/backlog
    /// bytes and pages, flushed bytes, flusher busy time, barriers
    /// issued/durable. On the direct backend everything is synchronous, so
    /// the backlog is always zero.
    pub fn flush_stats(&self) -> FlushStats {
        self.file.flush_stats()
    }

    /// Block until every barrier issued so far (two per [`H5File::commit`])
    /// is durable on disk. Immediate on the direct backend; errors if the
    /// paged backend's flusher died.
    pub fn wait_durable(&self) -> Result<()> {
        self.file.wait_durable()
    }

    /// Crash-test hook: kill the background flusher before the write op
    /// that would push cumulative flushed bytes past `after_bytes`. Returns
    /// `false` on backends with no flusher.
    pub fn inject_flush_fault(&self, after_bytes: u64) -> bool {
        self.file.set_flush_fault(after_bytes)
    }

    /// Attach a streaming tee observing every flush batch of the paged
    /// backend ([`BatchSink`]; the hook behind
    /// [`crate::stream::EpochPublisher`]). `None` detaches. Returns `false`
    /// on backends with no batch queue — direct I/O is synchronous, there
    /// is no batch stream to observe.
    pub fn set_batch_sink(&self, sink: Option<Arc<dyn BatchSink>>) -> bool {
        self.file.set_batch_sink(sink)
    }

    /// Encode the v2.1 free-list record: everything allocatable from the
    /// new footer's point of view — the free list, the extents retired this
    /// epoch (pending), the generations parked for epoch pins (pins are
    /// in-process state; a fresh open has no sessions to protect) and the
    /// footer being superseded. None of them is referenced by the footer
    /// being written, but none may be overwritten until it is durably live,
    /// so the in-memory lists are only merged after the superblock flip.
    fn encode_free_record(&self) -> Vec<u8> {
        let mut record = self.space.free.lock().unwrap().clone();
        for (&off, &len) in &self.space.pending.lock().unwrap().extents {
            record.insert(off, len);
        }
        for fl in self.space.parked.lock().unwrap().values() {
            for (&off, &len) in &fl.extents {
                record.insert(off, len);
            }
        }
        let (fo, fl) = *self.committed_footer.lock().unwrap();
        if fl > 0 {
            record.insert(fo, fl);
        }
        let mut e = Enc::new();
        e.u32(record.extents.len() as u32);
        for (&off, &len) in &record.extents {
            e.u64(off);
            e.u64(len);
        }
        e.buf
    }

    /// Flush metadata: place the footer into a free hole (or append it past
    /// the end of the data region), make it durable, then flip the
    /// superblock to it. Readers opening the file at any point — including
    /// after a crash anywhere inside this sequence — see a consistent
    /// superblock → footer chain: the footer is never written over the live
    /// one, never over an extent the live footer references, and a
    /// durability barrier orders it before the superblock update (plus one
    /// after, so the flip itself is ordered durable when `commit` returns —
    /// synchronously on the direct backend, in flush order on the paged
    /// one).
    pub fn commit(&mut self) -> Result<()> {
        let mut e = Enc::new();
        {
            let reg = self.chunks.lock().unwrap();
            let contig = self.contig.lock().unwrap();
            self.root.encode(&mut e, self.version, &reg, &contig)?;
        }
        // Footer placement. v2.1 tries the free list first via a two-pass
        // record-sizing dance: encode the free record once to learn the
        // total footer size, carve a hole of that size out of `free` alone
        // (pending/parked/the live footer are still referenced by the
        // on-disk chain — a torn write into them would corrupt the previous
        // epoch, while free extents are damage-free scratch by definition),
        // then re-encode the record so it reflects the carve. At alignment
        // 1 the carve leaves no head fragment and at most one tail
        // fragment, so the second encoding never exceeds the first; the
        // difference is zero-padded (the decoder reads the record
        // sequentially and ignores trailing bytes). Without a hole — and
        // always on v1/v2 — the footer appends past the data region.
        let (footer_off, footer_len) = if self.version >= FORMAT_V21 {
            let rec1 = self.encode_free_record();
            let total = (e.buf.len() + rec1.len()) as u64;
            let hole = self.space.free.lock().unwrap().alloc(total, 1);
            if let Some(offset) = hole {
                self.reused.fetch_add(total, Ordering::Relaxed);
                let rec2 = self.encode_free_record();
                debug_assert!(rec2.len() <= rec1.len());
                e.buf.extend_from_slice(&rec2);
                e.buf.resize(total as usize, 0);
                (offset, total)
            } else {
                e.buf.extend_from_slice(&rec1);
                let mut end = self.data_end.lock().unwrap();
                let offset = *end;
                self.file.set_len_min(offset + total)?;
                *end = offset + total;
                (offset, total)
            }
        } else {
            let total = e.buf.len() as u64;
            let mut end = self.data_end.lock().unwrap();
            let offset = *end;
            self.file.set_len_min(offset + total)?;
            *end = offset + total;
            (offset, total)
        };
        self.file
            .write_all_at(&e.buf, footer_off)
            .context("h5lite: footer write")?;
        // barrier: the footer must be durable before the superblock points
        // at it — without this, a crash can leave a valid superblock
        // referencing a footer that never hit the platter
        self.file.barrier().context("h5lite: footer sync")?;
        let mut sb = Vec::with_capacity(SUPERBLOCK_LEN as usize);
        sb.extend_from_slice(MAGIC);
        let mut se = Enc::new();
        se.u32(self.version);
        se.u32(ENDIAN_TAG);
        se.u64(footer_off);
        se.u64(footer_len);
        se.u32(self.alignment as u32);
        sb.extend_from_slice(&se.buf);
        sb.resize(SUPERBLOCK_LEN as usize, 0);
        self.file
            .write_all_at(&sb, 0)
            .context("h5lite: superblock write")?;
        self.file.barrier().context("h5lite: superblock sync")?;
        // The new footer is live: the superseded one and every extent
        // retired this epoch are no longer referenced by anything on disk.
        // They become allocatable unless a session still pins this epoch
        // (or an earlier one) — a pinned reader opened a footer that still
        // references them — in which case they park in the
        // generation-tagged retire queue until the pins drop.
        let prev = std::mem::replace(
            &mut *self.committed_footer.lock().unwrap(),
            (footer_off, footer_len),
        );
        if self.version >= FORMAT_V21 {
            let mut retired = std::mem::take(&mut *self.space.pending.lock().unwrap());
            if prev.1 > 0 {
                self.reclaimed.fetch_add(prev.1, Ordering::Relaxed);
                retired.insert(prev.0, prev.1);
            }
            {
                // The pins lock is held across the epoch bump AND the
                // park-vs-free decision: a concurrent pin_epoch observes
                // either (old epoch, retired extents still pending/about
                // to park) or (new epoch, decision already made) — never
                // the half-state where the bump landed, the pin table
                // looked empty, and these extents got freed under a pin
                // that was one instruction from existing. Model (b) in
                // crate::sync::protocols explores exactly this; its buggy
                // variant is the unlocked shape this replaces.
                let pins = self.space.pins.lock().unwrap();
                let epoch = self.space.epoch.fetch_add(1, Ordering::Relaxed);
                if pins.keys().next().map_or(false, |&p| p <= epoch) {
                    self.space
                        .parked
                        .lock()
                        .unwrap()
                        .entry(epoch)
                        .or_default()
                        .absorb(retired);
                } else {
                    self.space.free.lock().unwrap().absorb(retired);
                }
            }
            // pins may have dropped since the last release trigger
            self.space.release_parked();
        }
        Ok(())
    }

    /// Pin the current commit epoch: until the returned [`EpochPin`]
    /// drops, extents retired from now on — and the footers their commits
    /// supersede — are parked in a generation-tagged queue instead of
    /// becoming allocatable, so a reader holding its own handle on this
    /// epoch's committed state keeps reading byte-identical data across
    /// any number of writer commits. This is the primitive behind the
    /// `window::SnapshotReader` session; see [`EpochPin`] for the policy
    /// caveats ([`ReusePolicy::Immediate`] is not covered).
    pub fn pin_epoch(&self) -> EpochPin {
        // Load the epoch UNDER the pins lock: loading first and inserting
        // under the lock afterwards races commit — it can bump the epoch,
        // see an empty pin table, and free this epoch's retired extents
        // between our load and our insert (freed-while-pinned; caught by
        // the sync::protocols pin-retire model's buggy variant).
        let mut pins = self.space.pins.lock().unwrap();
        let epoch = self.space.epoch.load(Ordering::Relaxed);
        *pins.entry(epoch).or_insert(0) += 1;
        drop(pins);
        EpochPin {
            space: Arc::clone(&self.space),
            epoch,
        }
    }

    /// Resolve a `/`-separated group path, creating missing groups.
    pub fn ensure_group(&mut self, path: &str) -> &mut Group {
        let mut g = &mut self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g.groups.entry(part.to_string()).or_default();
        }
        g
    }

    /// Resolve a group path read-only.
    pub fn group(&self, path: &str) -> Result<&Group> {
        let mut g = &self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g
                .groups
                .get(part)
                .ok_or_else(|| anyhow!("h5lite: no group '{part}' in '{path}'"))?;
        }
        Ok(g)
    }

    /// Reserve `nbytes` of data-region space aligned to `align`: best-fit
    /// from the free list when the format persists one (v2.1), else by
    /// extending the file. Thread-safe (the chunk writers allocate
    /// concurrently). The file is only ever *grown* — shrinking below a
    /// committed footer would truncate it behind a concurrent reader's
    /// already-validated superblock.
    fn alloc(&self, nbytes: u64, align: u64) -> Result<u64> {
        if self.version >= FORMAT_V21 {
            if let Some(offset) = self.space.free.lock().unwrap().alloc(nbytes, align) {
                self.reused.fetch_add(nbytes, Ordering::Relaxed);
                return Ok(offset);
            }
        }
        self.alloc_append(nbytes, align)
    }

    /// Append-only allocation: used for contiguous reservations, which
    /// rely on `set_len` zero-fill for their unwritten rows (HDF5
    /// fill-value semantics — a recycled extent would leak stale bytes
    /// into those reads). Chunk extents are always written whole
    /// immediately, so only they go through the free list.
    fn alloc_append(&self, nbytes: u64, align: u64) -> Result<u64> {
        let mut end = self.data_end.lock().unwrap();
        let offset = end.next_multiple_of(align.max(1));
        self.file.set_len_min(offset + nbytes)?;
        *end = offset + nbytes;
        Ok(offset)
    }

    /// Hand `[offset, offset + len)` back to the free-space manager
    /// (no-op on v1/v2 files, which leak abandoned extents by design).
    fn retire_extent(&self, offset: u64, len: u64) {
        if self.version < FORMAT_V21 || len == 0 {
            return;
        }
        self.reclaimed.fetch_add(len, Ordering::Relaxed);
        match self.reuse_policy {
            ReusePolicy::Immediate => self.space.free.lock().unwrap().insert(offset, len),
            ReusePolicy::AfterCommit => {
                self.space.pending.lock().unwrap().insert(offset, len)
            }
        }
    }

    /// Choose when freed extents become allocatable (see [`ReusePolicy`]).
    pub fn set_reuse_policy(&mut self, policy: ReusePolicy) {
        self.reuse_policy = policy;
    }

    /// Create a contiguous dataset under `group_path`, reserving (aligned)
    /// space for the full shape. Like Parallel HDF5, creation is collective:
    /// the caller must know the global shape; individual ranks then write
    /// their hyperslabs independently.
    pub fn create_dataset(
        &mut self,
        group_path: &str,
        name: &str,
        dtype: Dtype,
        shape: &[u64],
    ) -> Result<Dataset> {
        if self.group(group_path).map_or(false, |g| g.datasets.contains_key(name)) {
            bail!("h5lite: dataset '{group_path}/{name}' already exists");
        }
        let ds = Dataset {
            dtype,
            shape: shape.to_vec(),
            layout: Layout::Contiguous { offset: 0 },
        };
        let offset = self.alloc_append(ds.n_bytes(), self.alignment)?;
        // a fresh reservation is not referenced by any footer yet: writes
        // this epoch stay in place, the first write after a commit goes aside
        self.contig.lock().unwrap().insert(
            offset,
            ContigState {
                cur: offset,
                len: ds.n_bytes(),
                epoch: self.space.epoch.load(Ordering::Relaxed),
            },
        );
        let ds = Dataset {
            layout: Layout::Contiguous { offset },
            ..ds
        };
        self.ensure_group(group_path)
            .datasets
            .insert(name.to_string(), ds.clone());
        Ok(ds)
    }

    /// Create a chunked dataset (format v2): rows are grouped into
    /// `chunk_rows`-row chunks, each stored as an independent extent
    /// encoded with `codec`. No space is reserved up front — extents are
    /// allocated as chunks are written.
    pub fn create_dataset_chunked(
        &mut self,
        group_path: &str,
        name: &str,
        dtype: Dtype,
        shape: &[u64],
        chunk_rows: u64,
        codec: Codec,
    ) -> Result<Dataset> {
        if self.version < FORMAT_V2 {
            bail!("h5lite: chunked datasets need format v2 (file is v{})", self.version);
        }
        if chunk_rows == 0 {
            bail!("h5lite: chunk_rows must be >= 1");
        }
        if shape.is_empty() {
            bail!("h5lite: chunked dataset needs at least one dimension");
        }
        if self.group(group_path).map_or(false, |g| g.datasets.contains_key(name)) {
            bail!("h5lite: dataset '{group_path}/{name}' already exists");
        }
        let id = self.next_ds_id.fetch_add(1, Ordering::Relaxed);
        let n_chunks = shape[0].div_ceil(chunk_rows);
        self.chunks.lock().unwrap().insert(
            id,
            ChunkTable {
                entries: vec![None; n_chunks as usize],
            },
        );
        let ds = Dataset {
            dtype,
            shape: shape.to_vec(),
            layout: Layout::Chunked {
                chunk_rows,
                codec,
                id,
            },
        };
        self.ensure_group(group_path)
            .datasets
            .insert(name.to_string(), ds.clone());
        Ok(ds)
    }

    /// Look up a dataset by group path + name.
    pub fn dataset(&self, group_path: &str, name: &str) -> Result<Dataset> {
        self.group(group_path)?
            .datasets
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("h5lite: no dataset '{name}' in '{group_path}'"))
    }

    /// Write rows of raw bytes starting at `row_start` (hyperslab along the
    /// first dimension). Concurrent-safe for disjoint ranges: contiguous
    /// writes are positional pwrites; chunked writes read-modify-write the
    /// touched chunks under an internal per-file lock (disjoint row ranges
    /// may share a chunk, so the RMW must serialise — the collective path
    /// stays parallel by writing whole chunks via
    /// [`H5File::write_chunk_encoded`] instead).
    pub fn write_rows(&self, ds: &Dataset, row_start: u64, data: &[u8]) -> Result<()> {
        let rb = ds.row_bytes();
        if data.len() as u64 % rb != 0 {
            bail!("h5lite: write not a whole number of rows");
        }
        let rows = data.len() as u64 / rb;
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        match ds.layout {
            Layout::Contiguous { offset } => {
                self.write_rows_contig(offset, row_start * rb, data)
            }
            Layout::Chunked { .. } => self.write_rows_chunked(ds, row_start, data),
        }
    }

    /// Contiguous hyperslab write with the epoch-versioned write-aside
    /// (v2.1 + [`ReusePolicy::AfterCommit`]): the first write into an
    /// extent the durable footer references relocates the dataset — a fresh
    /// extent is allocated, the bytes around the incoming slab are copied
    /// over from the committed extent, and the old extent retires through
    /// the pin-aware queue. Committed contiguous data is therefore never
    /// overwritten in place, so a torn flush (or a teed stream batch)
    /// always carries epoch `j`'s contiguous payloads whole — the same
    /// never-overwrite rule chunk extents and the footer already follow.
    /// Later writes in the same epoch land in place in the new extent.
    /// Other formats/policies keep the historical in-place behaviour.
    fn write_rows_contig(&self, tree_off: u64, byte_start: u64, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let mut contig = self.contig.lock().unwrap();
        let versioned =
            self.version >= FORMAT_V21 && self.reuse_policy == ReusePolicy::AfterCommit;
        let cur = match contig.get_mut(&tree_off) {
            Some(entry) => {
                let epoch = self.space.epoch.load(Ordering::Relaxed);
                if versioned && entry.epoch != epoch {
                    // Write-aside. The whole new extent gets defined right
                    // here (head + payload + tail), so `alloc` may hand
                    // back a recycled free-list extent without leaking
                    // stale bytes — the zero-fill argument that restricts
                    // *reservations* to alloc_append does not apply.
                    let len = entry.len;
                    let old = entry.cur;
                    let fresh = self.alloc(len, self.alignment)?;
                    let wend = (byte_start + data.len() as u64).min(len);
                    self.copy_extent(old, fresh, 0, byte_start)?;
                    self.copy_extent(old, fresh, wend, len.saturating_sub(wend))?;
                    self.retire_extent(old, len);
                    entry.cur = fresh;
                    entry.epoch = epoch;
                }
                entry.cur
            }
            // no reservation on record (foreign handle): historical in-place
            None => tree_off,
        };
        drop(contig);
        self.file
            .write_all_at(data, cur + byte_start)
            .context("h5lite: slab write")
    }

    /// Copy `[src + at, src + at + len)` to the same range of `dst` in
    /// bounded blocks (relocation helper; both extents are fully inside the
    /// store).
    fn copy_extent(&self, src: u64, dst: u64, at: u64, len: u64) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        let mut buf = vec![0u8; REPACK_BLOCK_BYTES.min(len) as usize];
        let mut done = 0u64;
        while done < len {
            let take = (len - done).min(buf.len() as u64) as usize;
            self.file
                .read_exact_at(&mut buf[..take], src + at + done)
                .context("h5lite: relocate read")?;
            self.file
                .write_all_at(&buf[..take], dst + at + done)
                .context("h5lite: relocate write")?;
            done += take as u64;
        }
        Ok(())
    }

    fn write_rows_chunked(&self, ds: &Dataset, row_start: u64, data: &[u8]) -> Result<()> {
        let rb = ds.row_bytes();
        let (_, codec, _) = ds.chunk_meta().unwrap();
        let rows = data.len() as u64 / rb;
        let mut done = 0u64;
        for (chunk_no, row_in_chunk, take) in ds.chunk_spans(row_start, rows) {
            let src = &data[(done * rb) as usize..((done + take) * rb) as usize];
            if row_in_chunk == 0 && take == ds.chunk_rows_at(chunk_no) {
                // whole chunk replaced: encode straight from the caller's
                // buffer, no lock — disjoint-range writers can never pair a
                // whole-chunk write with another write of the same chunk,
                // so threaded whole-chunk callers compress in parallel
                self.encode_and_write_chunk(ds, chunk_no, src, codec)?;
            } else {
                // partial: read-modify-write against existing content;
                // serialised because two disjoint row ranges can share this
                // chunk and the read→patch→re-encode→swap is not atomic
                let _rmw = self.rmw.lock().unwrap();
                let mut raw = self.read_chunk_raw(ds, chunk_no)?.as_ref().clone();
                let off = (row_in_chunk * rb) as usize;
                raw[off..off + src.len()].copy_from_slice(src);
                self.encode_and_write_chunk(ds, chunk_no, &raw, codec)?;
            }
            done += take;
        }
        Ok(())
    }

    fn encode_and_write_chunk(
        &self,
        ds: &Dataset,
        chunk_no: u64,
        raw: &[u8],
        codec: Codec,
    ) -> Result<()> {
        let enc = codec::encode_chunk_adaptive(codec, raw, ds.dtype.size());
        self.write_chunk_encoded(
            ds,
            chunk_no,
            enc.stored_or(raw),
            raw.len() as u64,
            enc.checksum,
            enc.codec,
        )
    }

    /// Store one already-encoded chunk extent and record it in the chunk
    /// index. Used by the collective-buffering aggregators, which run the
    /// codec on their own threads during the fill phase; `codec = None`
    /// stores the raw bytes (incompressible chunk), `Some(c)` records the
    /// pipeline the adaptive selector actually applied.
    pub fn write_chunk_encoded(
        &self,
        ds: &Dataset,
        chunk_no: u64,
        stored: &[u8],
        raw_len: u64,
        checksum: u32,
        codec: Option<Codec>,
    ) -> Result<()> {
        let (_, _, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: write_chunk_encoded on contiguous dataset"))?;
        if chunk_no >= ds.n_chunks() {
            bail!("h5lite: chunk {chunk_no} out of range ({})", ds.n_chunks());
        }
        let expect_raw = ds.chunk_rows_at(chunk_no) * ds.row_bytes();
        if raw_len != expect_raw {
            bail!("h5lite: chunk {chunk_no} raw length {raw_len}, expected {expect_raw}");
        }
        let prev = {
            let reg = self.chunks.lock().unwrap();
            let table = reg
                .get(&id)
                .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
            table.entries[chunk_no as usize]
        };
        // Slot choice. Under Immediate reuse a rewrite stays in place when
        // the new extent fits the old slot (shrink surplus back to the
        // allocator) or can grow into the free slack right after it; a
        // fresh slot is allocated with ~6 % adjacent slack so future small
        // grows stay in place too (see CHUNK_SLACK_DIV). A torn in-place
        // write is caught by the chunk checksum — the crash-safety
        // trade-off the policy documents — and the free list never holds
        // bytes the chunk index still references, so a failed write below
        // cannot hand a live extent to another writer. AfterCommit always
        // allocates fresh (packed) and parks the old extent on the pending
        // list after the index swap.
        let new_len = stored.len() as u64;
        let immediate =
            self.reuse_policy == ReusePolicy::Immediate && self.version >= FORMAT_V21;
        let in_place = immediate
            && match prev {
                Some(old) if new_len <= old.stored => true,
                Some(old) => self
                    .space
                    .free
                    .lock()
                    .unwrap()
                    .take_range(old.offset + old.stored, new_len - old.stored),
                None => false,
            };
        let offset = if in_place {
            prev.unwrap().offset
        } else if immediate {
            let cap = new_len + new_len / CHUNK_SLACK_DIV;
            let off = self.alloc(cap, 1)?;
            self.space.free.lock().unwrap().insert(off + new_len, cap - new_len);
            off
        } else {
            self.alloc(new_len, 1)?
        };
        self.file
            .write_all_at(stored, offset)
            .context("h5lite: chunk extent write")?;
        {
            let mut reg = self.chunks.lock().unwrap();
            let table = reg
                .get_mut(&id)
                .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
            table.entries[chunk_no as usize] = Some(ChunkLoc {
                offset,
                stored: new_len,
                raw: raw_len,
                checksum,
                codec,
            });
        }
        if let Some(old) = prev {
            if in_place {
                // the old slot was recycled in place; a shrink's surplus
                // goes back to the allocator (a grow already carved its
                // extra bytes out of the free list above)
                self.reused.fetch_add(new_len, Ordering::Relaxed);
                self.reclaimed.fetch_add(old.stored, Ordering::Relaxed);
                if new_len < old.stored {
                    self.space
                        .free
                        .lock()
                        .unwrap()
                        .insert(old.offset + new_len, old.stored - new_len);
                }
            } else {
                self.retire_extent(old.offset, old.stored);
            }
        }
        // bump BEFORE invalidating: a reader that passes its generation
        // check inserted before this point, so the removal below cleans it
        // up; a reader checking after this point skips its insert. The
        // reverse order would leave a window (after removal, before bump)
        // where a stale insert survives.
        self.cache_gen.fetch_add(1, Ordering::Release);
        self.cache.lock().unwrap().invalidate(id, chunk_no);
        // A shared-attached writer also drops the process-wide entry for its
        // own epoch key (other epochs' entries are pinned-immutable bytes
        // and stay valid by construction).
        if let Some(att) = &self.shared_cache {
            att.cache.invalidate(SharedKey {
                file: att.file_key,
                epoch: att.epoch,
                ds: id,
                chunk: chunk_no,
            });
        }
        Ok(())
    }

    /// Test-only: corrupt a chunk's recorded extent offset, to exercise
    /// [`H5File::verify`]'s overlap detection.
    #[cfg(test)]
    fn poke_chunk_offset(&self, ds: &Dataset, chunk_no: u64, offset: u64) {
        let (_, _, id) = ds.chunk_meta().unwrap();
        let mut reg = self.chunks.lock().unwrap();
        if let Some(loc) = reg.get_mut(&id).unwrap().entries[chunk_no as usize].as_mut() {
            loc.offset = offset;
        }
    }

    /// Test-only: park a bogus extent as if pinned at `epoch`, to exercise
    /// [`H5File::verify`]'s partition-overflow detection (a pin
    /// over-accounting bug would manifest exactly like this: bytes both
    /// live and "pinned-free").
    #[cfg(test)]
    fn poke_parked_extent(&self, epoch: u64, off: u64, len: u64) {
        self.space
            .parked
            .lock()
            .unwrap()
            .entry(epoch)
            .or_default()
            .insert(off, len);
    }

    /// Test-only: decoded chunks currently held by the LRU cache.
    #[cfg(test)]
    fn cached_chunks(&self) -> usize {
        self.cache.lock().unwrap().map.len()
    }

    /// Test-only: decoded bytes currently held by the LRU cache.
    #[cfg(test)]
    fn cached_bytes(&self) -> u64 {
        self.cache.lock().unwrap().bytes
    }

    /// Chunk index entry for `chunk_no` (`None` = not yet written).
    pub fn chunk_loc(&self, ds: &Dataset, chunk_no: u64) -> Result<Option<ChunkLoc>> {
        let (_, _, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: chunk_loc on contiguous dataset"))?;
        let reg = self.chunks.lock().unwrap();
        let table = reg
            .get(&id)
            .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
        table
            .entries
            .get(chunk_no as usize)
            .copied()
            .ok_or_else(|| anyhow!("h5lite: chunk {chunk_no} out of range"))
    }

    /// Read, decode and checksum one whole chunk from disk without
    /// touching any cache — the load path shared by the private cache
    /// miss and the [`SharedChunkCache`] single-flight leader.
    fn load_chunk_raw_uncached(&self, ds: &Dataset, chunk_no: u64) -> Result<Vec<u8>> {
        let loc = self.chunk_loc(ds, chunk_no)?;
        let expect_raw = (ds.chunk_rows_at(chunk_no) * ds.row_bytes()) as usize;
        match loc {
            None => Ok(vec![0u8; expect_raw]),
            Some(loc) => {
                let mut stored = vec![0u8; loc.stored as usize];
                self.file
                    .read_exact_at(&mut stored, loc.offset)
                    .context("h5lite: chunk extent read")?;
                self.read_bytes.fetch_add(loc.stored, Ordering::Relaxed);
                // decode with the chunk's own recorded codec — the
                // adaptive selector may store any pipeline of the family,
                // not just the dataset's declared one
                let raw = match loc.codec {
                    Some(c) => c.decode(&stored, ds.dtype.size(), loc.raw as usize)?,
                    None => {
                        if stored.len() as u64 != loc.raw {
                            bail!("h5lite: raw-stored chunk length mismatch");
                        }
                        stored
                    }
                };
                if raw.len() != expect_raw {
                    bail!(
                        "h5lite: chunk {chunk_no} decoded to {} bytes, expected {expect_raw}",
                        raw.len()
                    );
                }
                if codec::checksum32(&raw) != loc.checksum {
                    bail!("h5lite: chunk {chunk_no} checksum mismatch (corrupt extent?)");
                }
                Ok(raw)
            }
        }
    }

    /// Read and decode one whole chunk (zeros if never written). Decoded
    /// chunks are held in the file's LRU cache for row-at-a-time readers —
    /// or, when [`H5File::attach_shared_cache`] bound this handle to a
    /// process-wide cache, in that cache's epoch-keyed map, where
    /// concurrent misses of one chunk coalesce onto a single decode.
    pub fn read_chunk_raw(&self, ds: &Dataset, chunk_no: u64) -> Result<Arc<Vec<u8>>> {
        let (_, _, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: read_chunk_raw on contiguous dataset"))?;
        if let Some(att) = &self.shared_cache {
            let key = SharedKey {
                file: att.file_key,
                epoch: att.epoch,
                ds: id,
                chunk: chunk_no,
            };
            let (raw, outcome) = att
                .cache
                .get_or_load(key, || self.load_chunk_raw_uncached(ds, chunk_no))?;
            match outcome {
                SharedOutcome::Hit => {
                    self.cache_hits.fetch_add(1, Ordering::Relaxed);
                }
                SharedOutcome::Coalesced => {
                    self.cache_hits.fetch_add(1, Ordering::Relaxed);
                    self.cache_coalesced.fetch_add(1, Ordering::Relaxed);
                }
                SharedOutcome::Loaded => {
                    self.cache_misses.fetch_add(1, Ordering::Relaxed);
                }
            }
            return Ok(raw);
        }
        if let Some(data) = self.cache.lock().unwrap().get(id, chunk_no) {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(data);
        }
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
        let gen0 = self.cache_gen.load(Ordering::Acquire);
        let raw = Arc::new(self.load_chunk_raw_uncached(ds, chunk_no)?);
        // Only cache if no write landed while we were decoding — a racing
        // write of this chunk would otherwise leave pre-write bytes cached.
        // The generation check runs under the cache lock: the writer bumps
        // the generation *before* taking this lock to invalidate, so either
        // we insert first and its removal cleans us up, or we see the bump
        // and skip.
        {
            let mut cache = self.cache.lock().unwrap();
            if self.cache_gen.load(Ordering::Acquire) == gen0 {
                cache.insert(id, chunk_no, Arc::clone(&raw));
            }
        }
        Ok(raw)
    }

    /// Read `rows` rows starting at `row_start` as raw bytes; chunked
    /// datasets decompress transparently.
    pub fn read_rows(&self, ds: &Dataset, row_start: u64, rows: u64) -> Result<Vec<u8>> {
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        let rb = ds.row_bytes();
        match ds.layout {
            Layout::Contiguous { offset } => {
                let cur = resolve_contig(&self.contig.lock().unwrap(), offset);
                let mut buf = vec![0u8; (rows * rb) as usize];
                self.file
                    .read_exact_at(&mut buf, cur + row_start * rb)
                    .context("h5lite: slab read")?;
                self.read_bytes.fetch_add(rows * rb, Ordering::Relaxed);
                Ok(buf)
            }
            Layout::Chunked { .. } => {
                let mut out = Vec::with_capacity((rows * rb) as usize);
                for (chunk_no, row_in_chunk, take) in ds.chunk_spans(row_start, rows) {
                    let raw = self.read_chunk_raw(ds, chunk_no)?;
                    let off = (row_in_chunk * rb) as usize;
                    out.extend_from_slice(&raw[off..off + (take * rb) as usize]);
                }
                Ok(out)
            }
        }
    }

    /// Physical payload bytes a dataset occupies on disk: the reservation
    /// for contiguous layouts, the sum of stored extents for chunked ones
    /// (the compression win the fig8 bench reports).
    pub fn dataset_stored_bytes(&self, ds: &Dataset) -> Result<u64> {
        match ds.layout {
            Layout::Contiguous { .. } => Ok(ds.n_bytes()),
            Layout::Chunked { id, .. } => {
                let reg = self.chunks.lock().unwrap();
                let table = reg
                    .get(&id)
                    .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
                Ok(table
                    .entries
                    .iter()
                    .flatten()
                    .map(|l| l.stored)
                    .sum())
            }
        }
    }

    /// Convenience: write a full `f32` dataset in one call.
    pub fn write_all_f32(&self, ds: &Dataset, data: &[f32]) -> Result<()> {
        if data.len() as u64 != ds.n_elems() {
            bail!("h5lite: length mismatch");
        }
        self.write_rows(ds, 0, &codec::f32s_to_bytes(data))
    }

    /// Convenience: read a full `u64` dataset.
    pub fn read_all_u64(&self, ds: &Dataset) -> Result<Vec<u64>> {
        Ok(codec::bytes_to_u64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Convenience: read a full `f64` dataset.
    pub fn read_all_f64(&self, ds: &Dataset) -> Result<Vec<f64>> {
        Ok(codec::bytes_to_f64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Payload size of the data region — physical bytes minus the committed
    /// footer and the free-space manager's holes; the quantity the paper
    /// reports as "checkpoint size".
    pub fn data_bytes(&self) -> u64 {
        let end = *self.data_end.lock().unwrap();
        let (_, footer_len) = *self.committed_footer.lock().unwrap();
        let free = self.space.free.lock().unwrap().total;
        let pending = self.space.pending.lock().unwrap().total;
        let pinned = self.space.parked_bytes();
        end.saturating_sub(SUPERBLOCK_LEN)
            .saturating_sub(footer_len)
            .saturating_sub(free)
            .saturating_sub(pending)
            .saturating_sub(pinned)
    }

    /// Total bytes the free-space manager holds (allocatable + pending +
    /// parked for epoch pins).
    pub fn free_bytes(&self) -> u64 {
        self.space.free.lock().unwrap().total
            + self.space.pending.lock().unwrap().total
            + self.space.parked_bytes()
    }

    /// Space-accounting snapshot of the data region.
    pub fn space_stats(&self) -> SpaceStats {
        SpaceStats {
            file_bytes: self.data_end.lock().unwrap().saturating_sub(SUPERBLOCK_LEN),
            free_bytes: self.space.free.lock().unwrap().total,
            pending_bytes: self.space.pending.lock().unwrap().total,
            pinned_bytes: self.space.parked_bytes(),
            reclaimed_bytes: self.reclaimed.load(Ordering::Relaxed),
            reused_bytes: self.reused.load(Ordering::Relaxed),
        }
    }

    /// Physical-read accounting of this handle: payload bytes actually
    /// read from disk and the decoded-chunk cache hit/miss split. The
    /// `window::SnapshotReader` session reports these to show index-open
    /// amortisation and cache effectiveness across a query sequence.
    pub fn read_stats(&self) -> ReadStats {
        ReadStats {
            read_bytes: self.read_bytes.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            coalesced: self.cache_coalesced.load(Ordering::Relaxed),
        }
    }

    /// Set the decoded-chunk cache budget in bytes, evicting down to it if
    /// needed; `0` disables caching entirely. Long-lived reader sessions
    /// size this to the working set of the zoom sequence they serve
    /// (default [`DEFAULT_CHUNK_CACHE_BYTES`]).
    pub fn set_chunk_cache_budget(&self, bytes: u64) {
        self.cache.lock().unwrap().set_budget(bytes);
    }

    /// Current decoded-chunk cache budget in bytes.
    pub fn chunk_cache_budget(&self) -> u64 {
        self.cache.lock().unwrap().budget
    }

    /// Read, decode and checksum one chunk extent directly from disk,
    /// bypassing the decoded-chunk cache — [`H5File::verify`]'s integrity
    /// probe (a cached copy would mask on-disk corruption that happened
    /// after the chunk was last read).
    fn check_chunk_on_disk(&self, ds: &Dataset, chunk_no: u64, loc: ChunkLoc) -> Result<()> {
        if ds.chunk_meta().is_none() {
            bail!("h5lite: chunk check on contiguous dataset");
        }
        let mut stored = vec![0u8; loc.stored as usize];
        self.file
            .read_exact_at(&mut stored, loc.offset)
            .context("h5lite: chunk extent read")?;
        let raw = match loc.codec {
            Some(c) => c.decode(&stored, ds.dtype.size(), loc.raw as usize)?,
            None => {
                if stored.len() as u64 != loc.raw {
                    bail!("h5lite: raw-stored chunk length mismatch");
                }
                stored
            }
        };
        let expect_raw = (ds.chunk_rows_at(chunk_no) * ds.row_bytes()) as usize;
        if raw.len() != expect_raw {
            bail!(
                "h5lite: chunk {chunk_no} decoded to {} bytes, expected {expect_raw}",
                raw.len()
            );
        }
        if codec::checksum32(&raw) != loc.checksum {
            bail!("h5lite: chunk {chunk_no} checksum mismatch (corrupt extent?)");
        }
        Ok(())
    }

    /// fsck-style consistency walk: superblock → footer → chunk registry →
    /// extents → free list. Reports extent overlaps, out-of-bounds extents,
    /// chunk checksum mismatches, and accounts every byte of the data
    /// region as live, metadata, free or leaked. Chunk payloads are read
    /// straight from disk (the decoded-chunk cache is bypassed). Never
    /// panics on damage — findings land in [`VerifyReport::errors`].
    pub fn verify(&self) -> Result<VerifyReport> {
        let data_end = *self.data_end.lock().unwrap();
        let (footer_off, footer_len) = *self.committed_footer.lock().unwrap();
        let mut report = VerifyReport {
            data_end,
            meta_bytes: SUPERBLOCK_LEN + footer_len,
            ..VerifyReport::default()
        };
        // every claimed extent: (offset, len, label)
        let mut extents: Vec<(u64, u64, String)> = Vec::new();
        extents.push((0, SUPERBLOCK_LEN, "superblock".into()));
        if footer_len > 0 {
            extents.push((footer_off, footer_len, "footer".into()));
        }
        let mut stack: Vec<(String, &Group)> = vec![(String::new(), &self.root)];
        while let Some((path, g)) = stack.pop() {
            for (name, ds) in &g.datasets {
                report.n_datasets += 1;
                match ds.layout {
                    Layout::Contiguous { offset } => {
                        let cur = resolve_contig(&self.contig.lock().unwrap(), offset);
                        report.live_bytes += ds.n_bytes();
                        extents.push((cur, ds.n_bytes(), format!("{path}/{name}")));
                    }
                    Layout::Chunked { .. } => {
                        for chunk_no in 0..ds.n_chunks() {
                            let Some(loc) = self.chunk_loc(ds, chunk_no)? else {
                                continue;
                            };
                            report.n_chunks += 1;
                            report.live_bytes += loc.stored;
                            extents.push((
                                loc.offset,
                                loc.stored,
                                format!("{path}/{name}[{chunk_no}]"),
                            ));
                            // straight from disk, never the decoded-chunk
                            // cache: fsck must see the bytes as they are,
                            // not as they were when last read
                            if let Err(e) = self.check_chunk_on_disk(ds, chunk_no, loc) {
                                report
                                    .errors
                                    .push(format!("{path}/{name} chunk {chunk_no}: {e}"));
                            }
                        }
                    }
                }
            }
            for (name, sub) in &g.groups {
                stack.push((format!("{path}/{name}"), sub));
            }
        }
        {
            let free = self.space.free.lock().unwrap();
            let pending = self.space.pending.lock().unwrap();
            report.free_bytes = free.total + pending.total;
            for (&off, &len) in free.extents.iter().chain(pending.extents.iter()) {
                extents.push((off, len, "free".into()));
            }
        }
        {
            // extents parked for epoch pins are free space whose reuse is
            // merely deferred: they count as free in the partition (their
            // on-disk record already lists them free) and join the overlap
            // walk so a bad allocation into pinned bytes is caught
            let parked = self.space.parked.lock().unwrap();
            for fl in parked.values() {
                report.free_bytes += fl.total;
                for (&off, &len) in &fl.extents {
                    extents.push((off, len, "pinned-free".into()));
                }
            }
        }
        for (off, len, label) in &extents {
            let end = off.saturating_add(*len);
            if end > data_end {
                report.errors.push(format!(
                    "extent '{label}' [{off}, {end}) exceeds data end {data_end}"
                ));
            }
        }
        extents.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        for w in extents.windows(2) {
            let (ao, al, an) = (w[0].0, w[0].1, &w[0].2);
            let (bo, bn) = (w[1].0, &w[1].2);
            let aend = ao.saturating_add(al);
            if aend > bo && al > 0 {
                report.errors.push(format!(
                    "extents overlap: '{an}' [{ao}, {aend}) and '{bn}' at {bo}"
                ));
            }
        }
        // The partition must fit inside the data region. A claimed total
        // beyond `data_end` means some byte is accounted twice — a free
        // extent also referenced live, or a pin over-accounted — which a
        // saturating subtraction would silently flatten into
        // `leaked_bytes = 0` and a green report. Make it a hard finding.
        let claimed = report
            .live_bytes
            .saturating_add(report.meta_bytes)
            .saturating_add(report.free_bytes);
        if claimed > data_end {
            report.errors.push(format!(
                "space partition exceeds data end: live {} + meta {} + free {} = {claimed} > {data_end} \
                 (double-counted extent or pin over-accounting)",
                report.live_bytes, report.meta_bytes, report.free_bytes
            ));
        }
        report.leaked_bytes = data_end.saturating_sub(claimed);
        Ok(report)
    }

    /// Offline compaction (the `h5repack` analogue): rewrite this file into
    /// a fresh one with zero fragmentation — groups, attributes and
    /// datasets copied in deterministic order, chunk extents copied
    /// *verbatim* (stored bytes, checksum and filter mask preserved, no
    /// re-encode) — then atomically rename it over the original and reopen.
    /// Returns the number of physical bytes reclaimed.
    pub fn repack(&mut self) -> Result<u64> {
        let before = *self.data_end.lock().unwrap();
        let tmp = self.path.with_file_name(format!(
            "{}.repack",
            self.path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or("h5lite")
        ));
        let backing = self.file.backing();
        let mut dst =
            H5File::create_versioned_backed(&tmp, self.alignment, self.version, backing)?;
        let root = self.root.clone();
        let copy_result = copy_group_into(self, &root, &mut dst, "");
        // wait_durable before the drop/reopen/rename sequence: on the paged
        // backend a flusher failure would otherwise only surface as an
        // opaque decode error from the half-flushed temp file
        let committed = copy_result
            .and_then(|_| dst.commit())
            .and_then(|_| dst.wait_durable());
        let after = *dst.data_end.lock().unwrap();
        drop(dst);
        if let Err(e) = committed {
            std::fs::remove_file(&tmp).ok();
            return Err(e);
        }
        // Open the compacted file *before* the rename: the descriptor
        // follows the inode through it, so there is no window where a
        // failure could leave this handle pointing at an unlinked file
        // (writes silently lost). Any error up to the rename leaves the
        // original file and handle untouched.
        let mut reopened = match H5File::open_backed(&tmp, backing) {
            Ok(f) => f,
            Err(e) => {
                std::fs::remove_file(&tmp).ok();
                return Err(e);
            }
        };
        if let Err(e) = std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("h5lite: repack rename over {:?}", self.path))
        {
            std::fs::remove_file(&tmp).ok();
            return Err(e);
        }
        // the handle swap must not reset caller-visible state: keep the
        // path, the configured reuse policy, the cache budget and the
        // cumulative counters. (Sessions that pinned an epoch before the
        // repack keep reading the *old* inode through their own descriptor
        // — the rename only unlinks the name — so their data stays intact
        // without the new handle knowing about them.)
        reopened.path = self.path.clone();
        reopened.reuse_policy = self.reuse_policy;
        reopened.reclaimed = AtomicU64::new(self.reclaimed.load(Ordering::Relaxed));
        reopened.reused = AtomicU64::new(self.reused.load(Ordering::Relaxed));
        reopened.read_bytes = AtomicU64::new(self.read_bytes.load(Ordering::Relaxed));
        reopened.cache_hits = AtomicU64::new(self.cache_hits.load(Ordering::Relaxed));
        reopened.cache_misses = AtomicU64::new(self.cache_misses.load(Ordering::Relaxed));
        reopened.cache_coalesced =
            AtomicU64::new(self.cache_coalesced.load(Ordering::Relaxed));
        reopened.set_chunk_cache_budget(self.chunk_cache_budget());
        *self = reopened;
        Ok(before.saturating_sub(after))
    }
}

/// Row-block size for streaming contiguous datasets through
/// [`H5File::repack`]: the copy loop holds at most this many payload bytes
/// (rounded up to one row), so snapshots larger than RAM repack fine —
/// buffering each dataset whole capped compaction at the available memory.
const REPACK_BLOCK_BYTES: u64 = 1 << 20;

/// Recursively copy `g` (a group of `src`) into `dst` under `path` —
/// the repack work loop.
fn copy_group_into(src: &H5File, g: &Group, dst: &mut H5File, path: &str) -> Result<()> {
    dst.ensure_group(path).attrs = g.attrs.clone();
    for (name, ds) in &g.datasets {
        match ds.layout {
            Layout::Contiguous { .. } => {
                let nds = dst.create_dataset(path, name, ds.dtype, &ds.shape)?;
                let rows = ds.shape.first().copied().unwrap_or(0);
                let block_rows = (REPACK_BLOCK_BYTES / ds.row_bytes().max(1)).max(1);
                let mut row = 0u64;
                while row < rows {
                    let take = block_rows.min(rows - row);
                    let data = src.read_rows(ds, row, take)?;
                    dst.write_rows(&nds, row, &data)?;
                    row += take;
                }
            }
            Layout::Chunked {
                chunk_rows, codec, ..
            } => {
                let nds = dst.create_dataset_chunked(
                    path, name, ds.dtype, &ds.shape, chunk_rows, codec,
                )?;
                for chunk_no in 0..ds.n_chunks() {
                    let Some(loc) = src.chunk_loc(ds, chunk_no)? else {
                        continue;
                    };
                    let mut stored = vec![0u8; loc.stored as usize];
                    src.file
                        .read_exact_at(&mut stored, loc.offset)
                        .context("h5lite: repack chunk read")?;
                    dst.write_chunk_encoded(
                        &nds,
                        chunk_no,
                        &stored,
                        loc.raw,
                        loc.checksum,
                        loc.codec,
                    )?;
                }
            }
        }
    }
    for (name, sub) in &g.groups {
        let sub_path = format!("{path}/{name}");
        copy_group_into(src, sub, dst, &sub_path)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::OpenOptions;
    use std::os::unix::fs::FileExt;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("h5lite_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn create_open_roundtrip_empty() {
        let p = tmp("empty");
        {
            H5File::create(&p, 1).unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert!(f.root.groups.is_empty());
        assert_eq!(f.version(), FORMAT_V21);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn groups_attrs_roundtrip() {
        let p = tmp("attrs");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let g = f.ensure_group("/common");
            g.attrs.insert("dt".into(), Attr::F64(0.01));
            g.attrs.insert("scheme".into(), Attr::Str("chorin".into()));
            g.attrs
                .insert("spacings".into(), Attr::F64Vec(vec![0.1, 0.05]));
            g.attrs.insert("steps".into(), Attr::I64(500));
            f.ensure_group("/simulation/t=0.000000");
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let g = f.group("/common").unwrap();
        assert_eq!(g.attrs["dt"], Attr::F64(0.01));
        assert_eq!(g.attrs["scheme"], Attr::Str("chorin".into()));
        assert_eq!(g.attrs["spacings"], Attr::F64Vec(vec![0.1, 0.05]));
        assert_eq!(g.attrs["steps"], Attr::I64(500));
        assert!(f.group("/simulation/t=0.000000").is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dataset_write_read_full() {
        let p = tmp("full");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/sim", "cells", Dtype::F32, &[4, 8])
                .unwrap();
            let data: Vec<f32> = (0..32).map(|x| x as f32 * 0.5).collect();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/sim", "cells").unwrap();
        assert_eq!(ds.shape, vec![4, 8]);
        assert_eq!(ds.dtype, Dtype::F32);
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 4).unwrap());
        assert_eq!(back[5], 2.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_disjoint_writes() {
        let p = tmp("slab");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[10, 3])
            .unwrap();
        // two "ranks" write rows [0,5) and [5,10)
        let a: Vec<u64> = (0..15).collect();
        let b: Vec<u64> = (100..115).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&a)).unwrap();
        f.write_rows(&ds, 5, &codec::u64s_to_bytes(&b)).unwrap();
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[14], 14);
        assert_eq!(all[15], 100);
        assert_eq!(all[29], 114);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_bounds_checked() {
        let p = tmp("bounds");
        let f0 = {
            let mut f = H5File::create(&p, 1).unwrap();
            f.create_dataset("/g", "d", Dtype::U8, &[4, 2]).unwrap();
            f
        };
        let ds = f0.dataset("/g", "d").unwrap();
        assert!(f0.write_rows(&ds, 3, &[0u8; 4]).is_err()); // 2 rows at 3 > 4
        assert!(f0.read_rows(&ds, 0, 5).is_err());
        assert!(f0.write_rows(&ds, 0, &[0u8; 3]).is_err()); // partial row
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn alignment_respected() {
        let p = tmp("align");
        let mut f = H5File::create(&p, 4096).unwrap();
        let d1 = f.create_dataset("/g", "a", Dtype::U8, &[10]).unwrap();
        let d2 = f.create_dataset("/g", "b", Dtype::U8, &[10]).unwrap();
        assert_eq!(d1.contiguous_offset().unwrap() % 4096, 0);
        assert_eq!(d2.contiguous_offset().unwrap() % 4096, 0);
        assert!(d2.contiguous_offset().unwrap() >= d1.contiguous_offset().unwrap() + 4096);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_dataset_rejected() {
        let p = tmp("dup");
        let mut f = H5File::create(&p, 1).unwrap();
        f.create_dataset("/g", "d", Dtype::U8, &[1]).unwrap();
        assert!(f.create_dataset("/g", "d", Dtype::U8, &[1]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reopen_append_timestep_preserves_old_data() {
        let p = tmp("append");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/simulation/t=0", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[1.0, 2.0]).unwrap();
            f.commit().unwrap();
        }
        {
            let mut f = H5File::open(&p).unwrap();
            let ds = f
                .create_dataset("/simulation/t=1", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[3.0, 4.0]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let d0 = f.dataset("/simulation/t=0", "x").unwrap();
        let d1 = f.dataset("/simulation/t=1", "x").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d0, 0, 2).unwrap()),
            vec![1.0, 2.0]
        );
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d1, 0, 2).unwrap()),
            vec![3.0, 4.0]
        );
        // both timestep groups visible
        assert_eq!(f.group("/simulation").unwrap().groups.len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTAFILE________________________________").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_slab_writes_from_threads() {
        let p = tmp("threads");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[64, 4])
            .unwrap();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..32).map(|i| t * 1000 + i).collect();
                    fref.write_rows(dref, t * 8, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        for t in 0..8u64 {
            assert_eq!(all[(t * 32) as usize], t * 1000);
            assert_eq!(all[(t * 32 + 31) as usize], t * 1000 + 31);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_footer_is_error_not_panic() {
        let p = tmp("trunc");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            f.ensure_group("/a/b");
            let ds = f.create_dataset("/a", "d", Dtype::F32, &[8]).unwrap();
            f.write_all_f32(&ds, &[0.0; 8]).unwrap();
            f.commit().unwrap();
        }
        // chop the footer in half: open must fail cleanly
        let len = std::fs::metadata(&p).unwrap().len();
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.set_len(len - 10).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_superblock_offset_is_error() {
        let p = tmp("corrupt");
        {
            H5File::create(&p, 1).unwrap();
        }
        // point footer_off way past EOF
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.write_all_at(&u64::MAX.to_le_bytes(), 16).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_is_error() {
        let p = tmp("zero");
        std::fs::write(&p, b"").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn data_bytes_tracks_payload() {
        let p = tmp("size");
        let mut f = H5File::create(&p, 1).unwrap();
        assert_eq!(f.data_bytes(), 0);
        f.create_dataset("/g", "d", Dtype::F32, &[100]).unwrap();
        assert_eq!(f.data_bytes(), 400);
        std::fs::remove_file(&p).ok();
    }

    // ---------------------------------------------------------------------
    // format v2: chunked + compressed storage
    // ---------------------------------------------------------------------

    /// Smooth f32 rows (compressible, like real cell data).
    fn smooth_rows(rows: usize, row_elems: usize) -> Vec<f32> {
        (0..rows * row_elems)
            .map(|i| 1.0 + (i as f32 * 1e-3).sin() * 0.25)
            .collect()
    }

    #[test]
    fn chunked_roundtrip_matches_contiguous() {
        let p = tmp("chunk_rt");
        let mut f = H5File::create(&p, 1).unwrap();
        let data = smooth_rows(37, 16); // 37 rows: 4 full chunks + short tail
        let raw = codec::f32s_to_bytes(&data);
        let dc = f
            .create_dataset("/g", "plain", Dtype::F32, &[37, 16])
            .unwrap();
        let dk = f
            .create_dataset_chunked("/g", "packed", Dtype::F32, &[37, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.write_rows(&dc, 0, &raw).unwrap();
        f.write_rows(&dk, 0, &raw).unwrap();
        f.commit().unwrap();
        // byte-compare every row range against the uncompressed layout
        for (start, rows) in [(0u64, 37u64), (0, 1), (7, 2), (8, 8), (30, 7), (36, 1)] {
            assert_eq!(
                f.read_rows(&dk, start, rows).unwrap(),
                f.read_rows(&dc, start, rows).unwrap(),
                "rows [{start}, {})",
                start + rows
            );
        }
        // and the chunked copy actually stores fewer payload bytes
        let stored = f.dataset_stored_bytes(&dk).unwrap();
        assert!(stored < dk.n_bytes(), "{stored} vs {}", dk.n_bytes());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_survives_reopen() {
        let p = tmp("chunk_reopen");
        let data = smooth_rows(20, 8);
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset_chunked("/g", "d", Dtype::F32, &[20, 8], 6, Codec::SHUFFLE_LZ)
                .unwrap();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        assert!(ds.is_chunked());
        assert_eq!(ds.n_chunks(), 4); // 6+6+6+2
        assert_eq!(ds.chunk_rows_at(3), 2);
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 20).unwrap());
        assert_eq!(back, data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_partial_write_is_read_modify_write() {
        let p = tmp("chunk_rmw");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[10, 2], 4, Codec::LZ)
            .unwrap();
        let base: Vec<u64> = (0..20).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&base)).unwrap();
        // overwrite rows 3..5 (staddles the chunk 0 / chunk 1 boundary)
        let patch: Vec<u64> = vec![900, 901, 902, 903];
        f.write_rows(&ds, 3, &codec::u64s_to_bytes(&patch)).unwrap();
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[..6], [0, 1, 2, 3, 4, 5]);
        assert_eq!(all[6..10], [900, 901, 902, 903]);
        assert_eq!(all[10..], (10u64..20).collect::<Vec<_>>()[..]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_unwritten_chunks_read_as_zeros() {
        let p = tmp("chunk_zeros");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[12, 4], 4, Codec::SHUFFLE_LZ)
            .unwrap();
        // only the middle chunk written
        f.write_rows(&ds, 4, &codec::f32s_to_bytes(&[7.0; 16])).unwrap();
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 12).unwrap());
        assert!(back[..16].iter().all(|&x| x == 0.0));
        assert!(back[16..32].iter().all(|&x| x == 7.0));
        assert!(back[32..].iter().all(|&x| x == 0.0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_checksum_detects_corruption() {
        let p = tmp("chunk_crc");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 8], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(8, 8)).unwrap();
        f.commit().unwrap();
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert!(loc.stored < loc.raw);
        // flip one byte in the middle of the stored extent
        let file = OpenOptions::new().write(true).read(true).open(&p).unwrap();
        let mut b = [0u8; 1];
        file.read_exact_at(&mut b, loc.offset + loc.stored / 2).unwrap();
        file.write_all_at(&[b[0] ^ 0xff], loc.offset + loc.stored / 2)
            .unwrap();
        drop(file);
        let f2 = H5File::open(&p).unwrap();
        let ds2 = f2.dataset("/g", "d").unwrap();
        assert!(f2.read_rows(&ds2, 0, 8).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn incompressible_chunks_stored_raw() {
        let p = tmp("chunk_incomp");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U8, &[1024], 1024, Codec::LZ)
            .unwrap();
        // xorshift noise: LZ finds nothing, extent must fall back to raw
        let mut s = 0x9E37_79B9u64;
        let noise: Vec<u8> = (0..1024)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 24) as u8
            })
            .collect();
        f.write_rows(&ds, 0, &noise).unwrap();
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert!(loc.codec.is_none());
        assert_eq!(loc.stored, loc.raw);
        assert_eq!(f.read_rows(&ds, 0, 1024).unwrap(), noise);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn adaptive_chunk_codecs_persist_across_reopen() {
        // one dataset, chunks of different character: the adaptive selector
        // stores a different pipeline per chunk, the codec byte round-trips
        // through the footer, and every chunk reads back bit-exact
        use crate::util::synth;
        let p = tmp("chunk_adaptive");
        // rows are 1024 f32 = 4 KiB; chunk = 8 rows = 32 KiB
        let smooth = synth::smooth_field(8 * 1024);
        let noisy = synth::noise_bytes(0x1234_5678_9abc_def0, 8 * 4096);
        let zeros = vec![0u8; 8 * 4096];
        let mut raw = codec::f32s_to_bytes(&smooth);
        raw.extend_from_slice(&noisy);
        raw.extend_from_slice(&zeros);
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset_chunked(
                    "/g",
                    "d",
                    Dtype::F32,
                    &[24, 1024],
                    8,
                    Codec::SHUFFLE_DELTA_LZ,
                )
                .unwrap();
            f.write_rows(&ds, 0, &raw).unwrap();
            // smooth chunk takes the entropy pipeline, the noise chunk
            // falls back to raw storage
            let l0 = f.chunk_loc(&ds, 0).unwrap().unwrap();
            assert_eq!(l0.codec, Some(Codec::SHUFFLE_DELTA_LZ_RC), "{l0:?}");
            let l1 = f.chunk_loc(&ds, 1).unwrap().unwrap();
            assert!(l1.codec.is_none(), "{l1:?}");
            assert_eq!(l1.stored, l1.raw);
            let l2 = f.chunk_loc(&ds, 2).unwrap().unwrap();
            assert!(l2.codec.is_some());
            assert!(l2.stored * 40 < l2.raw, "zeros must crush: {l2:?}");
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        let l0 = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert_eq!(
            l0.codec,
            Some(Codec::SHUFFLE_DELTA_LZ_RC),
            "per-chunk codec byte lost across reopen"
        );
        assert!(f.chunk_loc(&ds, 1).unwrap().unwrap().codec.is_none());
        assert_eq!(f.read_rows(&ds, 0, 24).unwrap(), raw);
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pre_codec_v2_chunk_byte_decodes_as_dataset_codec() {
        // a chunk written with byte 1 (the only non-zero value pre-codec-v2
        // writers ever emitted) must decode with the dataset's declared
        // codec — write one the way the old encoder did and read it back
        let p = tmp("chunk_byte_compat");
        let data = smooth_rows(8, 16);
        let raw = codec::f32s_to_bytes(&data);
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 16], 8, Codec::SHUFFLE_DELTA_LZ)
                .unwrap();
            // fixed-codec encode (the PR-1 path) + explicit dataset codec:
            // serialises as byte 1, exactly like an old file
            let (enc, ck) = codec::encode_chunk(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
            let stored = enc.unwrap();
            f.write_chunk_encoded(&ds, 0, &stored, raw.len() as u64, ck, Some(Codec::SHUFFLE_DELTA_LZ))
                .unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert_eq!(loc.codec, Some(Codec::SHUFFLE_DELTA_LZ));
        assert_eq!(f.read_rows(&ds, 0, 8).unwrap(), raw);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_chunk_writes_from_threads() {
        let p = tmp("chunk_threads");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[64, 4], 8, Codec::SHUFFLE_LZ)
            .unwrap();
        // 8 threads, each owning one whole chunk (8 rows)
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..32).map(|i| t * 1000 + i).collect();
                    fref.write_rows(dref, t * 8, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        for t in 0..8u64 {
            assert_eq!(all[(t * 32) as usize], t * 1000);
            assert_eq!(all[(t * 32 + 31) as usize], t * 1000 + 31);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_disjoint_ranges_sharing_a_chunk() {
        // two writers own disjoint row ranges that land in the SAME chunk:
        // the internal RMW lock must keep both writes
        let p = tmp("chunk_shared");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[8, 4], 8, Codec::LZ)
            .unwrap();
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..16).map(|i| t * 100 + i).collect();
                    fref.write_rows(dref, t * 4, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[15], 15);
        assert_eq!(all[16], 100);
        assert_eq!(all[31], 115);
        std::fs::remove_file(&p).ok();
    }

    // ---------------------------------------------------------------------
    // format v1 backward compatibility
    // ---------------------------------------------------------------------

    #[test]
    fn v2_reader_opens_v1_file() {
        let p = tmp("v1_compat");
        {
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
            let g = f.ensure_group("/common");
            g.attrs.insert("dt".into(), Attr::F64(0.5));
            let ds = f.create_dataset("/sim", "x", Dtype::F32, &[3]).unwrap();
            f.write_all_f32(&ds, &[1.0, 2.0, 3.0]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V1);
        assert_eq!(f.group("/common").unwrap().attrs["dt"], Attr::F64(0.5));
        let ds = f.dataset("/sim", "x").unwrap();
        assert!(!ds.is_chunked());
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 3).unwrap()),
            vec![1.0, 2.0, 3.0]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_file_refuses_chunked_datasets() {
        let p = tmp("v1_nochunk");
        let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
        assert!(f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8], 4, Codec::LZ)
            .is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_appends_keep_v1_format() {
        let p = tmp("v1_append");
        {
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
            let ds = f.create_dataset("/a", "x", Dtype::U8, &[2]).unwrap();
            f.write_rows(&ds, 0, &[1, 2]).unwrap();
            f.commit().unwrap();
        }
        {
            let mut f = H5File::open(&p).unwrap();
            assert_eq!(f.version(), FORMAT_V1);
            let ds = f.create_dataset("/b", "y", Dtype::U8, &[2]).unwrap();
            f.write_rows(&ds, 0, &[3, 4]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V1);
        assert_eq!(
            f.read_rows(&f.dataset("/a", "x").unwrap(), 0, 2).unwrap(),
            vec![1, 2]
        );
        assert_eq!(
            f.read_rows(&f.dataset("/b", "y").unwrap(), 0, 2).unwrap(),
            vec![3, 4]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_version_rejected() {
        let p = tmp("v9");
        assert!(H5File::create_versioned(&p, 1, 9).is_err());
        std::fs::remove_file(&p).ok();
    }

    // ---------------------------------------------------------------------
    // format v2.1: free-space manager, compaction, verification
    // ---------------------------------------------------------------------

    #[test]
    fn free_list_coalesces_and_best_fits() {
        let mut fl = FreeList::default();
        fl.insert(100, 50);
        fl.insert(150, 50); // touches the previous extent: one [100, 200)
        assert_eq!(fl.extents.len(), 1);
        assert_eq!(fl.total, 100);
        fl.insert(300, 20);
        // best fit: a 20-byte request is served from the 20-byte extent,
        // not carved out of the 100-byte one
        assert_eq!(fl.alloc(20, 1), Some(300));
        assert_eq!(fl.total, 100);
        // aligned fit inside the big extent, fragments preserved
        let off = fl.alloc(10, 64).unwrap();
        assert_eq!(off % 64, 0);
        assert!(off >= 100 && off + 10 <= 200);
        assert_eq!(fl.total, 90);
        // nothing big enough: grow instead
        assert_eq!(fl.alloc(1000, 1), None);
        // zero-length requests never match
        assert_eq!(fl.alloc(0, 1), None);

        // take_range: carve an exact sub-range (in-place chunk growth)
        let mut fl = FreeList::default();
        fl.insert(1000, 100);
        assert!(!fl.take_range(990, 20), "head outside the extent");
        assert!(fl.take_range(1040, 30), "middle carve");
        assert_eq!(fl.total, 70);
        assert!(!fl.take_range(1040, 10), "already taken");
        assert!(fl.take_range(1000, 40), "head carve");
        assert!(fl.take_range(1070, 30), "tail carve");
        assert_eq!(fl.total, 0);
    }

    /// The linear best-fit scan the size index replaced — kept as the
    /// reference implementation for the equivalence property below.
    fn scan_alloc(fl: &mut FreeList, nbytes: u64, align: u64) -> Option<u64> {
        if nbytes == 0 {
            return None;
        }
        let align = align.max(1);
        let mut best: Option<(u64, u64)> = None; // (len, off)
        for (&off, &len) in &fl.extents {
            let aligned = off.next_multiple_of(align);
            if aligned - off + nbytes <= len && best.map_or(true, |(bl, _)| len < bl) {
                best = Some((len, off));
            }
        }
        let (len, off) = best?;
        fl.detach(off, len);
        fl.total -= len;
        let aligned = off.next_multiple_of(align);
        fl.insert(off, aligned - off);
        fl.insert(aligned + nbytes, off + len - (aligned + nbytes));
        Some(aligned)
    }

    /// Both views must describe the same extent set at all times.
    fn assert_views_consistent(fl: &FreeList) {
        assert_eq!(fl.extents.len(), fl.by_size.len());
        let mut sum = 0u64;
        for (&off, &len) in &fl.extents {
            assert!(fl.by_size.contains(&(len, off)), "missing ({len}, {off})");
            sum += len;
        }
        assert_eq!(sum, fl.total);
    }

    #[test]
    fn prop_indexed_alloc_equivalent_to_best_fit_scan() {
        use crate::util::prop::check;
        check("freelist index ≡ scan", 0xF1EE, |rng| {
            let mut idx = FreeList::default();
            let mut refr = FreeList::default();
            // seed a few disjoint free regions
            for r in 0..(2 + rng.below(4)) {
                let off = r * 1_000_000 + rng.below(1000);
                let len = 1 + rng.below(200_000);
                idx.insert(off, len);
                refr.insert(off, len);
            }
            // interleave allocs (indexed vs reference scan), frees of
            // previously allocated blocks, and arbitrary take_ranges
            let mut live: Vec<(u64, u64)> = Vec::new();
            for _ in 0..40 {
                match rng.below(4) {
                    0 | 1 => {
                        let n = 1 + rng.below(30_000);
                        let align = [1u64, 64, 4096][rng.below(3) as usize];
                        let a = idx.alloc(n, align);
                        let b = scan_alloc(&mut refr, n, align);
                        assert_eq!(a, b, "alloc({n}, {align}) diverged");
                        if let Some(off) = a {
                            live.push((off, n));
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let (off, len) =
                                live.swap_remove(rng.below(live.len() as u64) as usize);
                            idx.insert(off, len);
                            refr.insert(off, len);
                        }
                    }
                    _ => {
                        let off = rng.below(3_000_000);
                        let len = rng.below(500);
                        assert_eq!(
                            idx.take_range(off, len),
                            refr.take_range(off, len),
                            "take_range({off}, {len}) diverged"
                        );
                    }
                }
                assert_eq!(idx.extents, refr.extents);
                assert_eq!(idx.total, refr.total);
                assert_views_consistent(&idx);
            }
        });
    }

    #[test]
    fn chunk_rewrite_recycles_freed_extents_immediately() {
        // Immediate policy: rewriting every chunk with same-size content
        // recycles the freed slots, so the file does not grow at all
        let p = tmp("reuse_now");
        let mut f = H5File::create(&p, 1).unwrap();
        f.set_reuse_policy(ReusePolicy::Immediate);
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[32, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(32, 16);
        f.write_all_f32(&ds, &data).unwrap();
        let single = std::fs::metadata(&p).unwrap().len();
        for _ in 0..8 {
            f.write_all_f32(&ds, &data).unwrap();
        }
        let after = std::fs::metadata(&p).unwrap().len();
        assert_eq!(after, single, "equal-size rewrites must recycle in place");
        let stats = f.space_stats();
        assert!(stats.reclaimed_bytes > 0);
        assert!(stats.reused_bytes > 0);
        // contents intact after all the recycling
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 32).unwrap()),
            data
        );
        f.commit().unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn after_commit_policy_delays_reuse_by_one_epoch() {
        let p = tmp("reuse_epoch");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(16, 16);
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        // epoch 1: rewrite retires the old extents, but they stay pending —
        // the committed footer still references them
        f.write_all_f32(&ds, &data).unwrap();
        let s = f.space_stats();
        assert!(s.pending_bytes > 0, "{s:?}");
        assert_eq!(s.reused_bytes, 0, "no reuse before the commit: {s:?}");
        f.commit().unwrap();
        assert!(f.space_stats().pending_bytes == 0);
        assert!(f.space_stats().free_bytes > 0);
        // epoch 2: the same rewrite now recycles epoch-1 space
        f.write_all_f32(&ds, &data).unwrap();
        assert!(f.space_stats().reused_bytes > 0);
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 16).unwrap()),
            data
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn free_list_survives_reopen() {
        let p = tmp("freelist_rt");
        let data = smooth_rows(32, 16);
        let free_committed;
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset_chunked(
                    "/g",
                    "d",
                    Dtype::F32,
                    &[32, 16],
                    8,
                    Codec::SHUFFLE_DELTA_LZ,
                )
                .unwrap();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
            f.write_all_f32(&ds, &data).unwrap(); // abandon every extent
            f.commit().unwrap(); // pending → free, recorded in the footer
            free_committed = f.space_stats().free_bytes;
            assert!(free_committed > 0);
        }
        let mut f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V21);
        assert_eq!(
            f.free_bytes(),
            free_committed,
            "free list lost or changed across reopen"
        );
        let ds = f.dataset("/g", "d").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 32).unwrap()),
            data
        );
        // a fresh writer allocates out of the persisted free space
        f.write_all_f32(&ds, &data).unwrap();
        assert!(f.space_stats().reused_bytes > 0);
        f.commit().unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_files_leak_on_rewrite_but_stay_compatible() {
        // v2 carries no free-list record: rewrites append (the pre-v2.1
        // behaviour) and a v2.1 build keeps reading and writing the file
        let p = tmp("v2_compat");
        let data = smooth_rows(8, 8);
        {
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V2).unwrap();
            let ds = f
                .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 8], 8, Codec::SHUFFLE_LZ)
                .unwrap();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
            let before = std::fs::metadata(&p).unwrap().len();
            f.write_all_f32(&ds, &data).unwrap();
            assert_eq!(f.space_stats().reclaimed_bytes, 0, "v2 must not reclaim");
            assert_eq!(f.free_bytes(), 0);
            assert!(
                std::fs::metadata(&p).unwrap().len() > before,
                "v2 rewrite must append"
            );
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V2);
        let ds = f.dataset("/g", "d").unwrap();
        assert_eq!(codec::bytes_to_f32s(&f.read_rows(&ds, 0, 8).unwrap()), data);
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn post_reopen_alloc_never_truncates_the_footer() {
        // regression: alloc used set_len(offset + nbytes), which shrank the
        // file below the committed footer when the first post-reopen
        // allocation was smaller than the footer — a concurrent reader then
        // saw a truncated footer behind a valid superblock
        let p = tmp("noshrink");
        {
            // v2: the free list is empty, so the tiny allocation below must
            // take the append path (the one that used to truncate)
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V2).unwrap();
            for i in 0..64 {
                f.ensure_group(&format!("/g{i}"));
            }
            let ds = f.create_dataset("/g0", "d", Dtype::U8, &[8]).unwrap();
            f.write_rows(&ds, 0, &[7u8; 8]).unwrap();
            f.commit().unwrap();
        }
        let len_committed = std::fs::metadata(&p).unwrap().len();
        let writer = {
            let mut f = H5File::open(&p).unwrap();
            f.create_dataset("/g1", "tiny", Dtype::U8, &[1]).unwrap();
            f
        };
        assert!(
            std::fs::metadata(&p).unwrap().len() >= len_committed,
            "the file shrank below the committed footer"
        );
        // no commit happened: a concurrent reader must still parse cleanly
        let reader = H5File::open(&p).unwrap();
        assert_eq!(
            reader
                .read_rows(&reader.dataset("/g0", "d").unwrap(), 0, 8)
                .unwrap(),
            vec![7u8; 8]
        );
        drop(writer);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn torn_superblock_update_falls_back_to_previous_commit() {
        // simulate a crash where epoch 2's footer hit disk but the
        // superblock flip did not: restore epoch 1's superblock and reopen —
        // commit appends footers (never overwrites the live one), so the
        // epoch-1 chain must read back cleanly
        let p = tmp("torn");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U64, &[4]).unwrap();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&[1, 2, 3, 4]))
            .unwrap();
        f.commit().unwrap();
        let mut sb = [0u8; SUPERBLOCK_LEN as usize];
        {
            let file = OpenOptions::new().read(true).open(&p).unwrap();
            file.read_exact_at(&mut sb, 0).unwrap();
        }
        let ds2 = f.create_dataset("/g", "e", Dtype::U64, &[2]).unwrap();
        f.write_rows(&ds2, 0, &codec::u64s_to_bytes(&[9, 9])).unwrap();
        f.commit().unwrap();
        drop(f);
        // "crash": the epoch-2 superblock update is lost
        {
            let file = OpenOptions::new().write(true).open(&p).unwrap();
            file.write_all_at(&sb, 0).unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        assert_eq!(f.read_all_u64(&ds).unwrap(), vec![1, 2, 3, 4]);
        assert!(
            f.dataset("/g", "e").is_err(),
            "the torn epoch must be invisible"
        );
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn repack_compacts_and_preserves_contents() {
        let p = tmp("repack");
        let mut f = H5File::create(&p, 1).unwrap();
        let data = smooth_rows(37, 16);
        let raw = codec::f32s_to_bytes(&data);
        let dc = f
            .create_dataset("/g", "plain", Dtype::F32, &[37, 16])
            .unwrap();
        let dk = f
            .create_dataset_chunked("/g", "packed", Dtype::F32, &[37, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.ensure_group("/g")
            .attrs
            .insert("note".into(), Attr::Str("keep me".into()));
        f.write_rows(&dc, 0, &raw).unwrap();
        f.write_rows(&dk, 0, &raw).unwrap();
        f.commit().unwrap();
        // fragment: abandon every chunk extent a few times
        for _ in 0..4 {
            f.write_rows(&dk, 0, &raw).unwrap();
            f.commit().unwrap();
        }
        let before = std::fs::metadata(&p).unwrap().len();
        let reclaimed = f.repack().unwrap();
        let after = std::fs::metadata(&p).unwrap().len();
        assert!(reclaimed > 0);
        assert!(after < before, "{after} !< {before}");
        assert_eq!(before - after, reclaimed);
        // contents and attributes preserved through the in-place swap
        let dk = f.dataset("/g", "packed").unwrap();
        let dc = f.dataset("/g", "plain").unwrap();
        assert!(dk.is_chunked());
        assert_eq!(f.read_rows(&dk, 0, 37).unwrap(), raw);
        assert_eq!(f.read_rows(&dc, 0, 37).unwrap(), raw);
        assert_eq!(
            f.group("/g").unwrap().attrs["note"],
            Attr::Str("keep me".into())
        );
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        assert_eq!(rep.leaked_bytes, 0, "{rep:?}");
        // and the repacked file reopens clean
        drop(f);
        let f = H5File::open(&p).unwrap();
        let dk = f.dataset("/g", "packed").unwrap();
        assert_eq!(f.read_rows(&dk, 0, 37).unwrap(), raw);
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn repack_streams_contiguous_datasets_larger_than_the_block() {
        // regression for the buffer-the-whole-dataset repack: a contiguous
        // dataset bigger than REPACK_BLOCK_BYTES must stream through in
        // row blocks and land bit-identical
        let p = tmp("repack_stream");
        let mut f = H5File::create(&p, 1).unwrap();
        let rows = 6144u64;
        let dc = f
            .create_dataset("/g", "big", Dtype::U64, &[rows, 64])
            .unwrap();
        assert!(
            dc.n_bytes() > 2 * REPACK_BLOCK_BYTES,
            "test dataset must exceed the streaming block"
        );
        let data: Vec<u64> = (0..rows * 64).map(|x| x.wrapping_mul(0x9E37)).collect();
        f.write_rows(&dc, 0, &codec::u64s_to_bytes(&data)).unwrap();
        // some fragmentation so repack actually moves bytes
        let dk = f
            .create_dataset_chunked("/g", "packed", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let small = smooth_rows(16, 16);
        f.write_all_f32(&dk, &small).unwrap();
        f.commit().unwrap();
        f.write_all_f32(&dk, &small).unwrap();
        f.commit().unwrap();
        f.repack().unwrap();
        let back = f.read_all_u64(&f.dataset("/g", "big").unwrap()).unwrap();
        assert_eq!(back, data);
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&f.dataset("/g", "packed").unwrap(), 0, 16).unwrap()),
            small
        );
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        assert_eq!(rep.leaked_bytes, 0, "{rep:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn verify_reports_corrupt_chunk_and_overlap() {
        let p = tmp("fsck");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 8], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(16, 8)).unwrap();
        f.commit().unwrap();
        assert!(f.verify().unwrap().ok());
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        drop(f);
        // flip one byte in the middle of chunk 0's stored extent
        {
            let file = OpenOptions::new().read(true).write(true).open(&p).unwrap();
            let mut b = [0u8; 1];
            file.read_exact_at(&mut b, loc.offset + loc.stored / 2).unwrap();
            file.write_all_at(&[b[0] ^ 0xff], loc.offset + loc.stored / 2)
                .unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        let rep = f.verify().unwrap();
        assert!(!rep.ok());
        assert!(
            rep.errors.iter().any(|e| e.contains("chunk 0")),
            "{:?}",
            rep.errors
        );
        // structural damage: point chunk 1 into chunk 0's extent
        f.poke_chunk_offset(&ds, 1, loc.offset);
        let rep = f.verify().unwrap();
        assert!(
            rep.errors.iter().any(|e| e.contains("overlap")),
            "{:?}",
            rep.errors
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn verify_accounts_every_byte() {
        let p = tmp("fsck_bytes");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(16, 16);
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        f.write_all_f32(&ds, &data).unwrap(); // retire the first extents
        f.commit().unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        assert_eq!(rep.n_datasets, 1);
        assert_eq!(rep.n_chunks, 2);
        assert!(rep.free_bytes > 0);
        // live + meta + free + leaked is exactly the file
        assert_eq!(
            rep.live_bytes + rep.meta_bytes + rep.free_bytes + rep.leaked_bytes,
            rep.data_end
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_cache_is_byte_budgeted_lru() {
        // multi-chunk interleaved reads of one dataset must not thrash: the
        // old cache held a single chunk per dataset, so alternating between
        // two chunks re-inflated both on every access
        let p = tmp("lru");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[32, 8], 8, Codec::SHUFFLE_LZ)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(32, 8)).unwrap();
        // touch chunks 0 and 1 alternately (a window query straddling a
        // chunk boundary): both stay resident, and the hit/miss split
        // shows the repeats were served from memory
        for _ in 0..4 {
            f.read_rows(&ds, 7, 2).unwrap(); // rows 7..9 → chunks 0 and 1
        }
        assert!(f.cached_chunks() >= 2, "straddle thrashes the cache");
        let rs = f.read_stats();
        assert_eq!(rs.cache_misses, 2, "{rs:?}");
        assert_eq!(rs.cache_hits, 6, "{rs:?}");
        assert!(rs.read_bytes > 0);
        // the byte budget bounds the resident set when walking many
        // chunks: 64 decoded chunks of 128 B against a 512 B budget
        let big = f
            .create_dataset_chunked("/g", "big", Dtype::F32, &[256, 8], 4, Codec::LZ)
            .unwrap();
        f.write_all_f32(&big, &smooth_rows(256, 8)).unwrap();
        f.set_chunk_cache_budget(512);
        f.read_rows(&big, 0, 256).unwrap(); // 64 chunks
        assert!(f.cached_bytes() <= 512, "{} B resident", f.cached_bytes());
        assert!(f.cached_chunks() >= 1, "budget fits chunks but none stayed");
        // budget 0 disables caching entirely (epoch-pin tests read through
        // it to prove on-disk bytes, not cached copies)
        f.set_chunk_cache_budget(0);
        assert_eq!(f.cached_chunks(), 0, "set_budget(0) must evict all");
        f.read_rows(&big, 0, 4).unwrap();
        assert_eq!(f.cached_chunks(), 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn epoch_pin_parks_retired_extents_until_drop() {
        // the SWMR primitive behind the SnapshotReader session: while a
        // pin is alive, extents retired by rewrites park in the
        // generation-tagged queue instead of becoming allocatable, the
        // byte partition stays exact, and dropping the pin releases them
        let p = tmp("pin");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(16, 16);
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        let pin = f.pin_epoch();
        f.write_all_f32(&ds, &data).unwrap(); // retire the pinned extents
        f.commit().unwrap(); // unreferenced now, but the pin parks them
        let s1 = f.space_stats();
        assert!(s1.pinned_bytes > 0, "{s1:?}");
        // a second rewrite epoch parks more (and, per verify's overlap
        // walk, never allocates into the parked bytes)
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        let s2 = f.space_stats();
        assert!(s2.pinned_bytes > s1.pinned_bytes, "{s2:?}");
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        assert_eq!(
            rep.live_bytes + rep.meta_bytes + rep.free_bytes + rep.leaked_bytes,
            rep.data_end,
            "pinned extents lost their partition home"
        );
        // the data still reads back while pinned, and after release
        assert_eq!(codec::bytes_to_f32s(&f.read_rows(&ds, 0, 16).unwrap()), data);
        drop(pin);
        let s3 = f.space_stats();
        assert_eq!(s3.pinned_bytes, 0, "{s3:?}");
        assert!(s3.free_bytes >= s2.pinned_bytes, "{s3:?} vs {s2:?}");
        // the released space is really allocatable again
        let reused_before = s3.reused_bytes;
        f.write_all_f32(&ds, &data).unwrap();
        assert!(f.space_stats().reused_bytes > reused_before);
        f.commit().unwrap();
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn overlapping_epoch_pins_release_in_order() {
        // two sessions pinned at different epochs: dropping the older one
        // alone releases nothing tagged at or after the younger pin
        let p = tmp("pin2");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(8, 16);
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        let old_pin = f.pin_epoch();
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap(); // generation A: tagged at old_pin's epoch
        let young_pin = f.pin_epoch();
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap(); // generation B: tagged at young_pin's epoch
        assert!(old_pin.epoch() < young_pin.epoch());
        let both = f.space_stats().pinned_bytes;
        drop(old_pin);
        // generation A releases, generation B stays for the younger pin
        let after_old = f.space_stats().pinned_bytes;
        assert!(after_old > 0 && after_old < both, "{after_old} of {both}");
        drop(young_pin);
        assert_eq!(f.space_stats().pinned_bytes, 0);
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn verify_flags_partition_overflow() {
        // a parked ("pinned-free") extent overlapping live data means some
        // byte is accounted twice — the saturating subtraction used to
        // flatten that into leaked_bytes = 0 and a green report
        let p = tmp("overflow");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(16, 16)).unwrap();
        f.commit().unwrap();
        assert!(f.verify().unwrap().ok());
        // fake a pin over-accounting: park bytes that are also live
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        f.poke_parked_extent(1, loc.offset, loc.stored);
        let rep = f.verify().unwrap();
        assert!(!rep.ok(), "double-counted bytes passed verify");
        assert!(
            rep.errors.iter().any(|e| e.contains("partition exceeds data end")),
            "{:?}",
            rep.errors
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shared_cache_serves_across_handles_at_one_epoch() {
        // two handles on one file, attached to one process-wide cache at
        // the same epoch: the second handle's reads are pure cache hits —
        // zero physical bytes read through it
        let p = tmp("shared");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let data = smooth_rows(16, 16);
        f.write_all_f32(&ds, &data).unwrap();
        f.commit().unwrap();
        drop(f);

        let cache = SharedChunkCache::new(DEFAULT_CHUNK_CACHE_BYTES);
        let mut a = H5File::open(&p).unwrap();
        a.attach_shared_cache(&cache, 0);
        let mut b = H5File::open(&p).unwrap();
        b.attach_shared_cache(&cache, 0);

        let dsa = a.dataset("/g", "d").unwrap();
        assert_eq!(codec::bytes_to_f32s(&a.read_rows(&dsa, 0, 16).unwrap()), data);
        assert!(a.read_stats().read_bytes > 0);
        let dsb = b.dataset("/g", "d").unwrap();
        assert_eq!(codec::bytes_to_f32s(&b.read_rows(&dsb, 0, 16).unwrap()), data);
        let rb = b.read_stats();
        assert_eq!(rb.read_bytes, 0, "second handle re-read bytes: {rb:?}");
        assert!(rb.cache_hits >= 1, "{rb:?}");
        assert_eq!(rb.cache_misses, 0, "{rb:?}");
        let s = cache.stats();
        assert!(s.hits >= 1 && s.misses >= 1, "{s:?}");
        assert_eq!(s.loaded_bytes, s.resident_bytes, "{s:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shared_cache_epoch_keys_isolate_entries() {
        // the same chunk attached at two different epochs must occupy two
        // keys: an old pinned session may legitimately see different bytes
        // than a fresh one, so entries never cross epochs
        let p = tmp("shared_epochs");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 16], 8, Codec::SHUFFLE_LZ)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(8, 16)).unwrap();
        f.commit().unwrap();
        drop(f);

        let cache = SharedChunkCache::new(DEFAULT_CHUNK_CACHE_BYTES);
        let mut a = H5File::open(&p).unwrap();
        a.attach_shared_cache(&cache, 0);
        let mut b = H5File::open(&p).unwrap();
        b.attach_shared_cache(&cache, 1);
        let dsa = a.dataset("/g", "d").unwrap();
        let dsb = b.dataset("/g", "d").unwrap();
        a.read_rows(&dsa, 0, 8).unwrap();
        b.read_rows(&dsb, 0, 8).unwrap();
        let s = cache.stats();
        assert_eq!(s.misses, 2, "epoch keys leaked across: {s:?}");
        assert_eq!(s.hits, 0, "{s:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn shared_cache_coalesces_concurrent_misses() {
        // single-flight: N concurrent misses on one key run the loader
        // exactly once; the waiters block on the leader's slot and are
        // counted as coalesced
        use std::sync::atomic::AtomicUsize;
        let cache = SharedChunkCache::new(1 << 20);
        let key = SharedKey { file: 1, epoch: 0, ds: 1, chunk: 0 };
        let loads = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let cache = Arc::clone(&cache);
            let loads = Arc::clone(&loads);
            handles.push(std::thread::spawn(move || {
                let (data, _) = cache
                    .get_or_load(key, || {
                        loads.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        Ok(vec![7u8; 128])
                    })
                    .unwrap();
                assert_eq!(data.len(), 128);
            }));
            // stagger so the first thread wins the slot before the rest miss
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(loads.load(Ordering::SeqCst), 1, "coalescing decoded twice");
        let s = cache.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert_eq!(s.hits + s.misses, 4, "{s:?}");
        assert!(s.coalesced >= 1, "no waiter coalesced: {s:?}");
        // a failed leader must not wedge the slot: the next caller retries
        let bad = SharedKey { file: 1, epoch: 0, ds: 2, chunk: 0 };
        assert!(cache.get_or_load(bad, || bail!("io error")).is_err());
        let (ok, _) = cache.get_or_load(bad, || Ok(vec![1u8; 8])).unwrap();
        assert_eq!(ok.len(), 8);
    }

    #[test]
    fn shared_cache_respects_global_budget() {
        let cache = SharedChunkCache::new(1024);
        for i in 0..64u64 {
            let key = SharedKey { file: 1, epoch: 0, ds: 1, chunk: i };
            cache.get_or_load(key, || Ok(vec![0u8; 128])).unwrap();
        }
        let s = cache.stats();
        assert!(s.resident_bytes <= 1024, "over budget: {s:?}");
        assert!(s.evictions > 0, "{s:?}");
        assert_eq!(s.misses, 64, "{s:?}");
        // an entry larger than the whole budget is served but never kept
        let big = SharedKey { file: 1, epoch: 0, ds: 2, chunk: 0 };
        cache.get_or_load(big, || Ok(vec![0u8; 4096])).unwrap();
        assert!(cache.stats().resident_bytes <= 1024);
        // shrinking the budget evicts down to it
        cache.set_budget(256);
        assert!(cache.stats().resident_bytes <= 256, "{:?}", cache.stats());
    }

    #[test]
    fn shared_cache_write_invalidates_current_epoch_entry() {
        // a writer handle attached to the shared cache drops its own epoch
        // key on every chunk write, so a subsequent read through the cache
        // sees the new bytes, not the cached pre-write decode
        let p = tmp("shared_inval");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 16], 8, Codec::SHUFFLE_LZ)
            .unwrap();
        let v1 = smooth_rows(8, 16);
        f.write_all_f32(&ds, &v1).unwrap();
        f.commit().unwrap();
        let cache = SharedChunkCache::new(DEFAULT_CHUNK_CACHE_BYTES);
        f.attach_shared_cache(&cache, 0);
        assert_eq!(codec::bytes_to_f32s(&f.read_rows(&ds, 0, 8).unwrap()), v1);
        let v2: Vec<f32> = v1.iter().map(|x| x + 1.0).collect();
        f.write_all_f32(&ds, &v2).unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 8).unwrap()),
            v2,
            "shared cache served stale pre-write bytes"
        );
        std::fs::remove_file(&p).ok();
    }

    /// Run the same mixed workload (contiguous + chunked datasets, partial
    /// rewrites, attrs, multiple commits) against one backing and drop the
    /// handle.
    fn backend_workload(p: &PathBuf, backing: Backing) {
        let mut f = H5File::create_backed(p, 64, backing).unwrap();
        assert_eq!(f.backing(), backing);
        let dc = f.create_dataset("/g", "cont", Dtype::F32, &[16, 8]).unwrap();
        let dk = f
            .create_dataset_chunked("/g", "chunk", Dtype::F32, &[32, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        f.write_all_f32(&dc, &smooth_rows(16, 8)).unwrap();
        f.write_all_f32(&dk, &smooth_rows(32, 16)).unwrap();
        f.ensure_group("/g").attrs.insert("step".into(), Attr::I64(1));
        f.commit().unwrap();
        // rewrite retires extents, second commit recycles them
        let bumped: Vec<f32> = smooth_rows(32, 16).iter().map(|x| x + 1.0).collect();
        f.write_all_f32(&dk, &bumped).unwrap();
        f.write_rows(&dc, 4, &codec::f32s_to_bytes(&vec![9.0f32; 2 * 8]))
            .unwrap();
        f.ensure_group("/g").attrs.insert("step".into(), Attr::I64(2));
        f.commit().unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{backing:?}: {:?}", rep.errors);
    }

    #[test]
    fn paged_image_matches_direct_file_bit_exact() {
        // acceptance: the same op sequence on both backends leaves
        // byte-identical files once the paged image has fully flushed
        // (drop issues the final barrier and joins the flusher)
        let pd = tmp("bitexact_direct");
        let pp = tmp("bitexact_paged");
        backend_workload(&pd, Backing::Direct);
        backend_workload(&pp, Backing::Paged);
        let direct = std::fs::read(&pd).unwrap();
        let paged = std::fs::read(&pp).unwrap();
        assert_eq!(direct.len(), paged.len(), "file sizes diverge");
        assert!(direct == paged, "backends produced different bytes");
        std::fs::remove_file(&pd).ok();
        std::fs::remove_file(&pp).ok();
    }

    #[test]
    fn paged_backend_roundtrip_verify_pins_and_repack() {
        let p = tmp("paged_rt");
        backend_workload(&p, Backing::Paged);
        // reopen paged: reads fault pages in from disk on demand
        let mut f = H5File::open_backed(&p, Backing::Paged).unwrap();
        assert_eq!(f.backing(), Backing::Paged);
        let dk = f.dataset("/g", "chunk").unwrap();
        let bumped: Vec<f32> = smooth_rows(32, 16).iter().map(|x| x + 1.0).collect();
        assert_eq!(codec::bytes_to_f32s(&f.read_rows(&dk, 0, 32).unwrap()), bumped);
        assert_eq!(f.group("/g").unwrap().attrs["step"], Attr::I64(2));
        // SWMR primitive holds identically: pinned extents park across a
        // rewrite and release when the pin drops
        let pin = f.pin_epoch();
        f.write_all_f32(&dk, &smooth_rows(32, 16)).unwrap();
        f.commit().unwrap();
        assert!(f.space_stats().pinned_bytes > 0, "{:?}", f.space_stats());
        drop(pin);
        f.write_all_f32(&dk, &smooth_rows(32, 16)).unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        // flusher accounting: every commit issues two barriers, all durable
        // after wait_durable, with no backlog left
        f.wait_durable().unwrap();
        let stats = f.flush_stats();
        assert_eq!(stats.barriers_issued, stats.barriers_durable);
        assert!(stats.barriers_durable >= 4, "{stats:?}");
        // the post-commit rewrite above is still un-barriered image state
        assert!(stats.dirty_bytes > 0, "{stats:?}");
        assert!(stats.dirty_pages > 0, "{stats:?}");
        // repack stays on the paged backing and preserves contents
        f.commit().unwrap();
        f.repack().unwrap();
        assert_eq!(f.backing(), Backing::Paged);
        let dk = f.dataset("/g", "chunk").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&dk, 0, 32).unwrap()),
            smooth_rows(32, 16)
        );
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn paged_flush_fault_surfaces_and_direct_declines() {
        let pd = tmp("fault_direct");
        let f = H5File::create_backed(&pd, 1, Backing::Direct).unwrap();
        assert!(!f.inject_flush_fault(0), "direct has no flusher");
        drop(f);
        std::fs::remove_file(&pd).ok();

        let pp = tmp("fault_paged");
        let mut f = H5File::create_backed(&pp, 1, Backing::Paged).unwrap();
        f.wait_durable().unwrap();
        assert!(f.inject_flush_fault(f.flush_stats().flushed_bytes));
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[64]).unwrap();
        f.write_rows(&ds, 0, &[3u8; 64]).unwrap();
        // the commit's own barriers queue behind the fault; either the
        // commit itself or wait_durable must surface the dead flusher
        let r = f.commit().and_then(|_| f.wait_durable());
        assert!(r.is_err(), "flusher death went unnoticed");
        std::fs::remove_file(&pp).ok();
    }

    #[test]
    fn contiguous_write_aside_survives_torn_flush_bit_exact() {
        // PR-7 caveat closed: a contiguous rewrite next epoch goes to a
        // fresh extent, so a flush torn mid-rewrite can no longer damage
        // the recovered epoch's payload
        let p = tmp("contig_aside");
        let epoch1 = smooth_rows(16, 8);
        let epoch2: Vec<f32> = epoch1.iter().map(|x| x + 10.0).collect();
        {
            let mut f = H5File::create_backed(&p, 1, Backing::Paged).unwrap();
            let ds = f.create_dataset("/g", "d", Dtype::F32, &[16, 8]).unwrap();
            f.write_all_f32(&ds, &epoch1).unwrap();
            f.commit().unwrap();
            f.wait_durable().unwrap();
            // kill the flusher a few bytes into the next epoch's batches:
            // the rewrite tears mid-extent on disk
            f.inject_flush_fault(f.flush_stats().flushed_bytes + 48);
            f.write_all_f32(&ds, &epoch2).unwrap();
            let _ = f.commit(); // may already surface the dead flusher
            // the image itself is consistent: reads see the new epoch
            assert_eq!(
                codec::bytes_to_f32s(&f.read_rows(&ds, 0, 16).unwrap()),
                epoch2
            );
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 16).unwrap()),
            epoch1,
            "torn flush must recover epoch 1's contiguous payload bit-exact"
        );
        assert!(f.verify().unwrap().ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn contiguous_write_aside_merges_and_keeps_pinned_readers_stable() {
        let p = tmp("contig_pin");
        let mut f = H5File::create(&p, 4096).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U64, &[10, 3]).unwrap();
        let v1: Vec<u64> = (0..30).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&v1)).unwrap();
        f.commit().unwrap();
        // a reader session pins the epoch through its own handle
        let pin = f.pin_epoch();
        let r = H5File::open(&p).unwrap();
        let rds = r.dataset("/g", "d").unwrap();
        // rewriting rows [5,10) next epoch relocates the extent, carrying
        // the untouched head rows over
        let patch: Vec<u64> = (100..115).collect();
        f.write_rows(&ds, 5, &codec::u64s_to_bytes(&patch)).unwrap();
        let merged = codec::bytes_to_u64s(&f.read_rows(&ds, 0, 10).unwrap());
        assert_eq!(&merged[..15], &v1[..15]);
        assert_eq!(&merged[15..], &patch[..]);
        // the tree offset is the dataset's stable identity across the move
        assert_eq!(
            ds.contiguous_offset(),
            f.dataset("/g", "d").unwrap().contiguous_offset()
        );
        f.commit().unwrap();
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        // the pinned reader keeps reading epoch-1 bytes: the superseded
        // extent parked instead of becoming allocatable
        assert_eq!(codec::bytes_to_u64s(&r.read_rows(&rds, 0, 10).unwrap()), v1);
        assert!(f.space_stats().pinned_bytes > 0, "{:?}", f.space_stats());
        drop(pin);
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&v1)).unwrap();
        f.commit().unwrap();
        assert!(f.verify().unwrap().ok());
        // a fresh open resolves the footer's (relocated) offset normally
        let f2 = H5File::open(&p).unwrap();
        let ds2 = f2.dataset("/g", "d").unwrap();
        assert_eq!(codec::bytes_to_u64s(&f2.read_rows(&ds2, 0, 10).unwrap()), v1);
        assert_eq!(ds2.contiguous_offset().unwrap() % 4096, 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn footer_reuses_free_holes_bounded_growth() {
        // satellite regression: contiguous-only files have no free-list
        // consumer except the footer itself, so commit churn used to grow
        // the file by ~footer_len per commit. With two-pass hole placement
        // the retired footer's hole is recycled and growth stays bounded.
        let p = tmp("footer_holes");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::F32, &[8, 8]).unwrap();
        let data = smooth_rows(8, 8);
        let mut lens = Vec::new();
        for step in 0..20u32 {
            f.write_all_f32(&ds, &data).unwrap();
            f.ensure_group("/g")
                .attrs
                .insert("step".into(), Attr::I64(step as i64));
            f.commit().unwrap();
            lens.push(std::fs::metadata(&p).unwrap().len());
        }
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        let footer_len = rep.meta_bytes - SUPERBLOCK_LEN;
        // early commits append (the free list starts empty and holes must
        // first accumulate); from then on footers cycle through the same
        // holes. 15 append-only commits would add ~15 footer lengths.
        let growth = lens[19] - lens[4];
        assert!(
            growth < 3 * footer_len,
            "footer churn still grows the file: {growth} bytes over commits 5..20 \
             (footer_len {footer_len}, lens {lens:?})"
        );
        assert!(f.space_stats().reused_bytes > 0, "no hole was ever reused");
        std::fs::remove_file(&p).ok();
    }
}
