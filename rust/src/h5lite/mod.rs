//! **h5lite** — a from-scratch, self-describing hierarchical file format.
//!
//! The image has no libhdf5, so the substrate the paper builds on (§3:
//! groups, datasets, attributes, hyperslabs, contiguous storage, alignment)
//! is implemented here directly. The format keeps HDF5's data model:
//!
//! * a tree of **groups** starting at a root group, each holding child
//!   groups, **datasets** (n-dimensional typed arrays) and **attributes**;
//! * a **storage model** that lays every dataset out as a header-described
//!   linear array of raw little-endian bytes, optionally aligned to the
//!   file system's block size (paper §5.2);
//! * **self-description**: a superblock with magic/version/endian tag and a
//!   metadata footer that fully describes the tree, so a reader needs no
//!   external schema;
//! * **hyperslab** I/O: row-range reads/writes against a dataset's first
//!   dimension, the access pattern of the paper's kernel (one contiguous
//!   row block per rank — disjointness is what makes disabling file locks
//!   safe).
//!
//! ## On-disk layout
//!
//! ```text
//! [superblock 40 B] [data region …grows…] [metadata footer]
//! superblock: magic "MPH5LITE" | version u32 | endian u32 = 0x01020304
//!           | footer_off u64 | footer_len u64 | alignment u32
//! ```
//!
//! The footer is rewritten at the current end of data on every
//! [`H5File::commit`]; the superblock is then updated in place. This mirrors
//! HDF5's metadata-cache flush and makes a committed file readable at any
//! time (the offline sliding window reads snapshots while the run
//! continues). Dataset payload writes go through [`std::os::unix::fs::FileExt`]
//! positional I/O, so concurrent writers (the collective-buffering
//! aggregators) need no shared cursor and no locking.

pub mod codec;

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use codec::{Dec, Enc};

const MAGIC: &[u8; 8] = b"MPH5LITE";
const VERSION: u32 = 1;
const ENDIAN_TAG: u32 = 0x0102_0304;
const SUPERBLOCK_LEN: u64 = 40;

/// Element type of a dataset (subset of HDF5's type system used here).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dtype {
    F32,
    F64,
    U64,
    U8,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
            Dtype::U64 => 8,
            Dtype::U8 => 1,
        }
    }

    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::U64 => 2,
            Dtype::U8 => 3,
        }
    }

    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F64,
            2 => Dtype::U64,
            3 => Dtype::U8,
            _ => bail!("h5lite: unknown dtype code {c}"),
        })
    }
}

/// Attribute value (attached to groups, as in HDF5).
#[derive(Clone, PartialEq, Debug)]
pub enum Attr {
    F64(f64),
    I64(i64),
    Str(String),
    F64Vec(Vec<f64>),
}

/// A dataset: typed n-dimensional array stored contiguously at `offset`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dtype: Dtype,
    /// Shape; the first dimension is the row (hyperslab) dimension.
    pub shape: Vec<u64>,
    /// Absolute file offset of the payload.
    pub offset: u64,
}

impl Dataset {
    pub fn n_elems(&self) -> u64 {
        self.shape.iter().product()
    }

    pub fn n_bytes(&self) -> u64 {
        self.n_elems() * self.dtype.size() as u64
    }

    /// Elements per row (product of all dims after the first).
    pub fn row_elems(&self) -> u64 {
        self.shape.iter().skip(1).product()
    }

    pub fn row_bytes(&self) -> u64 {
        self.row_elems() * self.dtype.size() as u64
    }
}

/// A group: named attributes, child groups and datasets (BTreeMap for a
/// stable, deterministic iteration order in listings and the footer).
#[derive(Clone, Debug, Default)]
pub struct Group {
    pub attrs: BTreeMap<String, Attr>,
    pub groups: BTreeMap<String, Group>,
    pub datasets: BTreeMap<String, Dataset>,
}

impl Group {
    fn encode(&self, e: &mut Enc) {
        e.u32(self.attrs.len() as u32);
        for (name, a) in &self.attrs {
            e.str(name);
            match a {
                Attr::F64(v) => {
                    e.u8(0);
                    e.f64(*v);
                }
                Attr::I64(v) => {
                    e.u8(1);
                    e.i64(*v);
                }
                Attr::Str(v) => {
                    e.u8(2);
                    e.str(v);
                }
                Attr::F64Vec(v) => {
                    e.u8(3);
                    e.f64s(v);
                }
            }
        }
        e.u32(self.datasets.len() as u32);
        for (name, d) in &self.datasets {
            e.str(name);
            e.u8(d.dtype.code());
            e.u64s(&d.shape);
            e.u64(d.offset);
        }
        e.u32(self.groups.len() as u32);
        for (name, g) in &self.groups {
            e.str(name);
            g.encode(e);
        }
    }

    fn decode(d: &mut Dec) -> Result<Group> {
        let mut g = Group::default();
        let n_attrs = d.u32()?;
        for _ in 0..n_attrs {
            let name = d.str()?;
            let attr = match d.u8()? {
                0 => Attr::F64(d.f64()?),
                1 => Attr::I64(d.i64()?),
                2 => Attr::Str(d.str()?),
                3 => Attr::F64Vec(d.f64s()?),
                c => bail!("h5lite: unknown attr code {c}"),
            };
            g.attrs.insert(name, attr);
        }
        let n_ds = d.u32()?;
        for _ in 0..n_ds {
            let name = d.str()?;
            let dtype = Dtype::from_code(d.u8()?)?;
            let shape = d.u64s()?;
            let offset = d.u64()?;
            g.datasets.insert(
                name,
                Dataset {
                    dtype,
                    shape,
                    offset,
                },
            );
        }
        let n_groups = d.u32()?;
        for _ in 0..n_groups {
            let name = d.str()?;
            g.groups.insert(name, Group::decode(d)?);
        }
        Ok(g)
    }
}

/// An h5lite file handle.
///
/// Creation/structure mutation requires `&mut self` (matching Parallel
/// HDF5's rule that groups and datasets are created *collectively*); slab
/// reads/writes take `&self` and may run concurrently from many threads
/// (each rank/aggregator owns a disjoint row range).
pub struct H5File {
    file: File,
    pub path: PathBuf,
    pub root: Group,
    /// Next free data offset (end of data region).
    data_end: u64,
    /// Alignment for dataset payload starts (paper §5.2; 1 = none).
    pub alignment: u64,
}

impl H5File {
    /// Create a new file (truncating any existing one). `alignment` aligns
    /// every dataset payload to that many bytes (use the file system block
    /// size; 1 disables).
    pub fn create<P: AsRef<Path>>(path: P, alignment: u64) -> Result<H5File> {
        assert!(alignment >= 1);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("h5lite: create {:?}", path.as_ref()))?;
        let mut f = H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root: Group::default(),
            data_end: SUPERBLOCK_LEN,
            alignment,
        };
        f.commit()?;
        Ok(f)
    }

    /// Open an existing file (read + write).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<H5File> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("h5lite: open {:?}", path.as_ref()))?;
        let mut sb = [0u8; SUPERBLOCK_LEN as usize];
        file.read_exact(&mut sb)
            .context("h5lite: short superblock")?;
        if &sb[0..8] != MAGIC {
            bail!("h5lite: bad magic in {:?}", path.as_ref());
        }
        let mut d = Dec::new(&sb[8..]);
        let version = d.u32()?;
        if version != VERSION {
            bail!("h5lite: unsupported version {version}");
        }
        let endian = d.u32()?;
        if endian != ENDIAN_TAG {
            bail!("h5lite: endianness tag mismatch (cross-endian file?)");
        }
        let footer_off = d.u64()?;
        let footer_len = d.u64()?;
        let alignment = d.u32()? as u64;
        let mut footer = vec![0u8; footer_len as usize];
        file.seek(SeekFrom::Start(footer_off))?;
        file.read_exact(&mut footer)
            .context("h5lite: short footer")?;
        let mut fd = Dec::new(&footer);
        let root = Group::decode(&mut fd)?;
        Ok(H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root,
            data_end: footer_off,
            alignment,
        })
    }

    /// Flush metadata: write the footer at the end of the data region and
    /// update the superblock. Readers opening the file afterwards see a
    /// consistent snapshot.
    pub fn commit(&mut self) -> Result<()> {
        let mut e = Enc::new();
        self.root.encode(&mut e);
        let footer_off = self.data_end;
        self.file.seek(SeekFrom::Start(footer_off))?;
        self.file.write_all(&e.buf)?;
        // superblock
        let mut sb = Vec::with_capacity(SUPERBLOCK_LEN as usize);
        sb.extend_from_slice(MAGIC);
        let mut se = Enc::new();
        se.u32(VERSION);
        se.u32(ENDIAN_TAG);
        se.u64(footer_off);
        se.u64(e.buf.len() as u64);
        se.u32(self.alignment as u32);
        sb.extend_from_slice(&se.buf);
        sb.resize(SUPERBLOCK_LEN as usize, 0);
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&sb)?;
        self.file.flush()?;
        Ok(())
    }

    /// Resolve a `/`-separated group path, creating missing groups.
    pub fn ensure_group(&mut self, path: &str) -> &mut Group {
        let mut g = &mut self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g.groups.entry(part.to_string()).or_default();
        }
        g
    }

    /// Resolve a group path read-only.
    pub fn group(&self, path: &str) -> Result<&Group> {
        let mut g = &self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g
                .groups
                .get(part)
                .ok_or_else(|| anyhow!("h5lite: no group '{part}' in '{path}'"))?;
        }
        Ok(g)
    }

    /// Create a dataset under `group_path`, reserving (aligned) contiguous
    /// space for the full shape. Like Parallel HDF5, creation is collective:
    /// the caller must know the global shape; individual ranks then write
    /// their hyperslabs independently.
    pub fn create_dataset(
        &mut self,
        group_path: &str,
        name: &str,
        dtype: Dtype,
        shape: &[u64],
    ) -> Result<Dataset> {
        let offset = self.data_end.next_multiple_of(self.alignment);
        let ds = Dataset {
            dtype,
            shape: shape.to_vec(),
            offset,
        };
        let nbytes = ds.n_bytes();
        // reserve by extending the file (sparse where the OS allows)
        self.file.set_len(offset + nbytes)?;
        self.data_end = offset + nbytes;
        let g = self.ensure_group(group_path);
        if g.datasets.contains_key(name) {
            bail!("h5lite: dataset '{group_path}/{name}' already exists");
        }
        g.datasets.insert(name.to_string(), ds.clone());
        Ok(ds)
    }

    /// Look up a dataset by group path + name.
    pub fn dataset(&self, group_path: &str, name: &str) -> Result<Dataset> {
        self.group(group_path)?
            .datasets
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("h5lite: no dataset '{name}' in '{group_path}'"))
    }

    /// Write rows of raw bytes starting at `row_start` (hyperslab along the
    /// first dimension). Concurrent-safe for disjoint ranges.
    pub fn write_rows(&self, ds: &Dataset, row_start: u64, data: &[u8]) -> Result<()> {
        let rb = ds.row_bytes();
        if data.len() as u64 % rb != 0 {
            bail!("h5lite: write not a whole number of rows");
        }
        let rows = data.len() as u64 / rb;
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        self.file
            .write_all_at(data, ds.offset + row_start * rb)
            .context("h5lite: slab write")?;
        Ok(())
    }

    /// Read `rows` rows starting at `row_start` as raw bytes.
    pub fn read_rows(&self, ds: &Dataset, row_start: u64, rows: u64) -> Result<Vec<u8>> {
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        let rb = ds.row_bytes();
        let mut buf = vec![0u8; (rows * rb) as usize];
        self.file
            .read_exact_at(&mut buf, ds.offset + row_start * rb)
            .context("h5lite: slab read")?;
        Ok(buf)
    }

    /// Convenience: write a full `f32` dataset in one call.
    pub fn write_all_f32(&self, ds: &Dataset, data: &[f32]) -> Result<()> {
        if data.len() as u64 != ds.n_elems() {
            bail!("h5lite: length mismatch");
        }
        self.write_rows(ds, 0, &codec::f32s_to_bytes(data))
    }

    /// Convenience: read a full `u64` dataset.
    pub fn read_all_u64(&self, ds: &Dataset) -> Result<Vec<u64>> {
        Ok(codec::bytes_to_u64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Convenience: read a full `f64` dataset.
    pub fn read_all_f64(&self, ds: &Dataset) -> Result<Vec<f64>> {
        Ok(codec::bytes_to_f64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Current physical size of the data region (metadata excluded) — the
    /// quantity the paper reports as "checkpoint size".
    pub fn data_bytes(&self) -> u64 {
        self.data_end - SUPERBLOCK_LEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("h5lite_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn create_open_roundtrip_empty() {
        let p = tmp("empty");
        {
            H5File::create(&p, 1).unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert!(f.root.groups.is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn groups_attrs_roundtrip() {
        let p = tmp("attrs");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let g = f.ensure_group("/common");
            g.attrs.insert("dt".into(), Attr::F64(0.01));
            g.attrs.insert("scheme".into(), Attr::Str("chorin".into()));
            g.attrs
                .insert("spacings".into(), Attr::F64Vec(vec![0.1, 0.05]));
            g.attrs.insert("steps".into(), Attr::I64(500));
            f.ensure_group("/simulation/t=0.000000");
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let g = f.group("/common").unwrap();
        assert_eq!(g.attrs["dt"], Attr::F64(0.01));
        assert_eq!(g.attrs["scheme"], Attr::Str("chorin".into()));
        assert_eq!(g.attrs["spacings"], Attr::F64Vec(vec![0.1, 0.05]));
        assert_eq!(g.attrs["steps"], Attr::I64(500));
        assert!(f.group("/simulation/t=0.000000").is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dataset_write_read_full() {
        let p = tmp("full");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/sim", "cells", Dtype::F32, &[4, 8])
                .unwrap();
            let data: Vec<f32> = (0..32).map(|x| x as f32 * 0.5).collect();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/sim", "cells").unwrap();
        assert_eq!(ds.shape, vec![4, 8]);
        assert_eq!(ds.dtype, Dtype::F32);
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 4).unwrap());
        assert_eq!(back[5], 2.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_disjoint_writes() {
        let p = tmp("slab");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[10, 3])
            .unwrap();
        // two "ranks" write rows [0,5) and [5,10)
        let a: Vec<u64> = (0..15).collect();
        let b: Vec<u64> = (100..115).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&a)).unwrap();
        f.write_rows(&ds, 5, &codec::u64s_to_bytes(&b)).unwrap();
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[14], 14);
        assert_eq!(all[15], 100);
        assert_eq!(all[29], 114);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_bounds_checked() {
        let p = tmp("bounds");
        let f0 = {
            let mut f = H5File::create(&p, 1).unwrap();
            f.create_dataset("/g", "d", Dtype::U8, &[4, 2]).unwrap();
            f
        };
        let ds = f0.dataset("/g", "d").unwrap();
        assert!(f0.write_rows(&ds, 3, &[0u8; 4]).is_err()); // 2 rows at 3 > 4
        assert!(f0.read_rows(&ds, 0, 5).is_err());
        assert!(f0.write_rows(&ds, 0, &[0u8; 3]).is_err()); // partial row
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn alignment_respected() {
        let p = tmp("align");
        let mut f = H5File::create(&p, 4096).unwrap();
        let d1 = f.create_dataset("/g", "a", Dtype::U8, &[10]).unwrap();
        let d2 = f.create_dataset("/g", "b", Dtype::U8, &[10]).unwrap();
        assert_eq!(d1.offset % 4096, 0);
        assert_eq!(d2.offset % 4096, 0);
        assert!(d2.offset >= d1.offset + 4096);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_dataset_rejected() {
        let p = tmp("dup");
        let mut f = H5File::create(&p, 1).unwrap();
        f.create_dataset("/g", "d", Dtype::U8, &[1]).unwrap();
        assert!(f.create_dataset("/g", "d", Dtype::U8, &[1]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reopen_append_timestep_preserves_old_data() {
        let p = tmp("append");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/simulation/t=0", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[1.0, 2.0]).unwrap();
            f.commit().unwrap();
        }
        {
            let mut f = H5File::open(&p).unwrap();
            let ds = f
                .create_dataset("/simulation/t=1", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[3.0, 4.0]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let d0 = f.dataset("/simulation/t=0", "x").unwrap();
        let d1 = f.dataset("/simulation/t=1", "x").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d0, 0, 2).unwrap()),
            vec![1.0, 2.0]
        );
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d1, 0, 2).unwrap()),
            vec![3.0, 4.0]
        );
        // both timestep groups visible
        assert_eq!(f.group("/simulation").unwrap().groups.len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTAFILE________________________________").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_slab_writes_from_threads() {
        let p = tmp("threads");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[64, 4])
            .unwrap();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..32).map(|i| t * 1000 + i).collect();
                    fref.write_rows(dref, t * 8, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        for t in 0..8u64 {
            assert_eq!(all[(t * 32) as usize], t * 1000);
            assert_eq!(all[(t * 32 + 31) as usize], t * 1000 + 31);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_footer_is_error_not_panic() {
        let p = tmp("trunc");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            f.ensure_group("/a/b");
            let ds = f.create_dataset("/a", "d", Dtype::F32, &[8]).unwrap();
            f.write_all_f32(&ds, &[0.0; 8]).unwrap();
            f.commit().unwrap();
        }
        // chop the footer in half: open must fail cleanly
        let len = std::fs::metadata(&p).unwrap().len();
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.set_len(len - 10).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_superblock_offset_is_error() {
        let p = tmp("corrupt");
        {
            H5File::create(&p, 1).unwrap();
        }
        // point footer_off way past EOF
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.write_all_at(&u64::MAX.to_le_bytes(), 16).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_is_error() {
        let p = tmp("zero");
        std::fs::write(&p, b"").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn data_bytes_tracks_payload() {
        let p = tmp("size");
        let mut f = H5File::create(&p, 1).unwrap();
        assert_eq!(f.data_bytes(), 0);
        f.create_dataset("/g", "d", Dtype::F32, &[100]).unwrap();
        assert_eq!(f.data_bytes(), 400);
        std::fs::remove_file(&p).ok();
    }
}
