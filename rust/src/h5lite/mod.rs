//! **h5lite** — a from-scratch, self-describing hierarchical file format.
//!
//! The image has no libhdf5, so the substrate the paper builds on (§3:
//! groups, datasets, attributes, hyperslabs, contiguous storage, alignment)
//! is implemented here directly. The format keeps HDF5's data model:
//!
//! * a tree of **groups** starting at a root group, each holding child
//!   groups, **datasets** (n-dimensional typed arrays) and **attributes**;
//! * a **storage model** with two dataset layouts: *contiguous* (one
//!   header-described linear array of raw little-endian bytes, optionally
//!   aligned to the file system's block size, paper §5.2) and — since
//!   format v2 — *chunked* (fixed row-count chunks, each stored as an
//!   independently compressed extent, mirroring HDF5's chunked storage +
//!   filter pipeline);
//! * **self-description**: a superblock with magic/version/endian tag and a
//!   metadata footer that fully describes the tree, so a reader needs no
//!   external schema;
//! * **hyperslab** I/O: row-range reads/writes against a dataset's first
//!   dimension, the access pattern of the paper's kernel (one contiguous
//!   row block per rank — disjointness is what makes disabling file locks
//!   safe). Chunked datasets decompress transparently on [`H5File::read_rows`].
//!
//! ## On-disk layout (format v2)
//!
//! ```text
//! [superblock 40 B] [data region …grows…] [metadata footer]
//! superblock: magic "MPH5LITE" | version u32 (1|2) | endian u32 = 0x01020304
//!           | footer_off u64 | footer_len u64 | alignment u32
//!
//! data region:   contiguous payloads (aligned) and compressed chunk
//!                extents (packed back to back), in allocation order
//!
//! footer (per group, recursive):
//!   attrs:    n, then (name, tag u8, value)*
//!   datasets: n, then (name, dtype u8, shape u64s, layout)*
//!     layout v1:          offset u64                      (contiguous only)
//!     layout v2 tag 0:    offset u64                      (contiguous)
//!     layout v2 tag 1:    chunk_rows u64 | codec u8 | n_chunks u64
//!                         | n_present u32
//!                         | (chunk_no u64, offset u64, stored u64,
//!                            raw u64, checksum u32, codec_applied u8)*
//!   groups:   n, then (name, group)*                      (recursive)
//! ```
//!
//! A v2 reader opens v1 files (every dataset decodes as contiguous); a v1
//! file refuses chunked dataset creation. Chunk extents record whether the
//! codec was actually applied (HDF5's per-chunk filter mask): incompressible
//! chunks are stored raw rather than expanded. Rewriting a chunk allocates
//! a fresh extent and abandons the old one — the same garbage HDF5 accrues
//! until `h5repack`; checkpoint streams are append-only so this never
//! triggers on the hot path.
//!
//! The footer is rewritten at the current end of data on every
//! [`H5File::commit`]; the superblock is then updated in place. This mirrors
//! HDF5's metadata-cache flush and makes a committed file readable at any
//! time (the offline sliding window reads snapshots while the run
//! continues). Dataset payload writes go through [`std::os::unix::fs::FileExt`]
//! positional I/O, so concurrent writers (the collective-buffering
//! aggregators) need no shared cursor and no locking.

pub mod codec;

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use codec::{Codec, Dec, Enc};

const MAGIC: &[u8; 8] = b"MPH5LITE";
/// Original contiguous-only format.
pub const FORMAT_V1: u32 = 1;
/// Chunked + compressed dataset storage.
pub const FORMAT_V2: u32 = 2;
/// Default format for newly created files.
pub const VERSION: u32 = FORMAT_V2;
const ENDIAN_TAG: u32 = 0x0102_0304;
const SUPERBLOCK_LEN: u64 = 40;

/// Element type of a dataset (subset of HDF5's type system used here).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dtype {
    F32,
    F64,
    U64,
    U8,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
            Dtype::U64 => 8,
            Dtype::U8 => 1,
        }
    }

    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::U64 => 2,
            Dtype::U8 => 3,
        }
    }

    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F64,
            2 => Dtype::U64,
            3 => Dtype::U8,
            _ => bail!("h5lite: unknown dtype code {c}"),
        })
    }
}

/// Attribute value (attached to groups, as in HDF5).
#[derive(Clone, PartialEq, Debug)]
pub enum Attr {
    F64(f64),
    I64(i64),
    Str(String),
    F64Vec(Vec<f64>),
}

/// Physical storage layout of a dataset.
#[derive(Clone, PartialEq, Debug)]
pub enum Layout {
    /// One linear reservation at `offset` (format v1's only layout).
    Contiguous { offset: u64 },
    /// Fixed `chunk_rows`-row chunks, each an independently compressed
    /// extent located through the file's chunk registry (key `id`).
    Chunked {
        chunk_rows: u64,
        codec: Codec,
        id: u64,
    },
}

/// Location of one written chunk in the data region.
#[derive(Clone, Copy, Debug)]
pub struct ChunkLoc {
    /// Absolute file offset of the stored extent.
    pub offset: u64,
    /// Stored (possibly compressed) byte count.
    pub stored: u64,
    /// Raw (decoded) byte count.
    pub raw: u64,
    /// FNV-1a checksum of the raw bytes, verified on read.
    pub checksum: u32,
    /// Whether the dataset codec was applied (false = stored raw because
    /// the chunk was incompressible — HDF5's per-chunk filter mask).
    pub codec_applied: bool,
}

/// Per-dataset chunk index: entry `i` locates chunk `i`, `None` = never
/// written (reads return zeros, matching HDF5 fill-value semantics).
struct ChunkTable {
    entries: Vec<Option<ChunkLoc>>,
}

type ChunkRegistry = HashMap<u64, ChunkTable>;

/// A dataset: typed n-dimensional array with a contiguous or chunked layout.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dtype: Dtype,
    /// Shape; the first dimension is the row (hyperslab) dimension.
    pub shape: Vec<u64>,
    pub layout: Layout,
}

impl Dataset {
    pub fn n_elems(&self) -> u64 {
        self.shape.iter().product()
    }

    pub fn n_bytes(&self) -> u64 {
        self.n_elems() * self.dtype.size() as u64
    }

    /// Elements per row (product of all dims after the first).
    pub fn row_elems(&self) -> u64 {
        self.shape.iter().skip(1).product()
    }

    pub fn row_bytes(&self) -> u64 {
        self.row_elems() * self.dtype.size() as u64
    }

    pub fn is_chunked(&self) -> bool {
        matches!(self.layout, Layout::Chunked { .. })
    }

    /// `(chunk_rows, codec, registry id)` for chunked datasets.
    pub fn chunk_meta(&self) -> Option<(u64, Codec, u64)> {
        match self.layout {
            Layout::Chunked {
                chunk_rows,
                codec,
                id,
            } => Some((chunk_rows, codec, id)),
            Layout::Contiguous { .. } => None,
        }
    }

    /// Payload offset of a contiguous dataset.
    pub fn contiguous_offset(&self) -> Option<u64> {
        match self.layout {
            Layout::Contiguous { offset } => Some(offset),
            Layout::Chunked { .. } => None,
        }
    }

    /// Number of chunks (0 for contiguous datasets).
    pub fn n_chunks(&self) -> u64 {
        match self.layout {
            Layout::Chunked { chunk_rows, .. } => self.shape[0].div_ceil(chunk_rows),
            Layout::Contiguous { .. } => 0,
        }
    }

    /// Rows in chunk `chunk_no` (the last chunk may be short).
    pub fn chunk_rows_at(&self, chunk_no: u64) -> u64 {
        match self.layout {
            Layout::Chunked { chunk_rows, .. } => {
                chunk_rows.min(self.shape[0].saturating_sub(chunk_no * chunk_rows))
            }
            Layout::Contiguous { .. } => 0,
        }
    }

    /// Walk the row range `[row_start, row_start + rows)` chunk by chunk,
    /// yielding `(chunk_no, row offset within the chunk, rows taken)` —
    /// the one place the chunk-boundary arithmetic lives, shared by the
    /// writer, the reader and the pario chunk bucketing. Empty for
    /// contiguous datasets and for ranges beyond the dataset extent
    /// (callers bounds-check first; this just refuses to spin).
    pub fn chunk_spans(&self, row_start: u64, rows: u64) -> impl Iterator<Item = (u64, u64, u64)> {
        let chunk_rows = match self.layout {
            Layout::Chunked { chunk_rows, .. } => chunk_rows,
            Layout::Contiguous { .. } => 0,
        };
        let shape0 = self.shape.first().copied().unwrap_or(0);
        let end = row_start + rows;
        let mut row = row_start;
        std::iter::from_fn(move || {
            if chunk_rows == 0 || row >= end {
                return None;
            }
            let chunk_no = row / chunk_rows;
            let chunk_first = chunk_no * chunk_rows;
            let rows_here = chunk_rows.min(shape0.saturating_sub(chunk_first));
            let chunk_end = chunk_first + rows_here;
            if chunk_end <= row {
                return None; // out of range: refuse to loop forever
            }
            let take = chunk_end.min(end) - row;
            let item = (chunk_no, row - chunk_first, take);
            row += take;
            Some(item)
        })
    }
}

/// A group: named attributes, child groups and datasets (BTreeMap for a
/// stable, deterministic iteration order in listings and the footer).
#[derive(Clone, Debug, Default)]
pub struct Group {
    pub attrs: BTreeMap<String, Attr>,
    pub groups: BTreeMap<String, Group>,
    pub datasets: BTreeMap<String, Dataset>,
}

impl Group {
    fn encode(&self, e: &mut Enc, version: u32, reg: &ChunkRegistry) -> Result<()> {
        e.u32(self.attrs.len() as u32);
        for (name, a) in &self.attrs {
            e.str(name);
            match a {
                Attr::F64(v) => {
                    e.u8(0);
                    e.f64(*v);
                }
                Attr::I64(v) => {
                    e.u8(1);
                    e.i64(*v);
                }
                Attr::Str(v) => {
                    e.u8(2);
                    e.str(v);
                }
                Attr::F64Vec(v) => {
                    e.u8(3);
                    e.f64s(v);
                }
            }
        }
        e.u32(self.datasets.len() as u32);
        for (name, d) in &self.datasets {
            e.str(name);
            e.u8(d.dtype.code());
            e.u64s(&d.shape);
            match (&d.layout, version) {
                (Layout::Contiguous { offset }, FORMAT_V1) => e.u64(*offset),
                (Layout::Chunked { .. }, FORMAT_V1) => {
                    bail!("h5lite: dataset '{name}' is chunked; format v1 cannot store it")
                }
                (Layout::Contiguous { offset }, _) => {
                    e.u8(0);
                    e.u64(*offset);
                }
                (
                    Layout::Chunked {
                        chunk_rows,
                        codec,
                        id,
                    },
                    _,
                ) => {
                    e.u8(1);
                    e.u64(*chunk_rows);
                    e.u8(codec.code());
                    let table = reg
                        .get(id)
                        .ok_or_else(|| anyhow!("h5lite: chunk table missing for '{name}'"))?;
                    e.u64(table.entries.len() as u64);
                    let present: Vec<(u64, ChunkLoc)> = table
                        .entries
                        .iter()
                        .enumerate()
                        .filter_map(|(i, l)| l.map(|loc| (i as u64, loc)))
                        .collect();
                    e.u32(present.len() as u32);
                    for (i, loc) in present {
                        e.u64(i);
                        e.u64(loc.offset);
                        e.u64(loc.stored);
                        e.u64(loc.raw);
                        e.u32(loc.checksum);
                        e.u8(loc.codec_applied as u8);
                    }
                }
            }
        }
        e.u32(self.groups.len() as u32);
        for (name, g) in &self.groups {
            e.str(name);
            g.encode(e, version, reg)?;
        }
        Ok(())
    }

    fn decode(
        d: &mut Dec,
        version: u32,
        reg: &mut ChunkRegistry,
        next_id: &mut u64,
    ) -> Result<Group> {
        let mut g = Group::default();
        let n_attrs = d.u32()?;
        for _ in 0..n_attrs {
            let name = d.str()?;
            let attr = match d.u8()? {
                0 => Attr::F64(d.f64()?),
                1 => Attr::I64(d.i64()?),
                2 => Attr::Str(d.str()?),
                3 => Attr::F64Vec(d.f64s()?),
                c => bail!("h5lite: unknown attr code {c}"),
            };
            g.attrs.insert(name, attr);
        }
        let n_ds = d.u32()?;
        for _ in 0..n_ds {
            let name = d.str()?;
            let dtype = Dtype::from_code(d.u8()?)?;
            let shape = d.u64s()?;
            let layout = if version == FORMAT_V1 {
                Layout::Contiguous { offset: d.u64()? }
            } else {
                match d.u8()? {
                    0 => Layout::Contiguous { offset: d.u64()? },
                    1 => {
                        let chunk_rows = d.u64()?;
                        let codec = Codec::from_code(d.u8()?)?;
                        let n_chunks = d.u64()?;
                        if chunk_rows == 0 {
                            bail!("h5lite: dataset '{name}' has zero chunk_rows");
                        }
                        let rows = shape.first().copied().unwrap_or(0);
                        if n_chunks != rows.div_ceil(chunk_rows) {
                            bail!(
                                "h5lite: dataset '{name}' chunk count {n_chunks} \
                                 inconsistent with {rows} rows / {chunk_rows}"
                            );
                        }
                        let mut entries: Vec<Option<ChunkLoc>> = vec![None; n_chunks as usize];
                        let n_present = d.u32()?;
                        for _ in 0..n_present {
                            let i = d.u64()? as usize;
                            if i >= entries.len() {
                                bail!("h5lite: chunk index {i} out of range in '{name}'");
                            }
                            entries[i] = Some(ChunkLoc {
                                offset: d.u64()?,
                                stored: d.u64()?,
                                raw: d.u64()?,
                                checksum: d.u32()?,
                                codec_applied: d.u8()? != 0,
                            });
                        }
                        let id = *next_id;
                        *next_id += 1;
                        reg.insert(id, ChunkTable { entries });
                        Layout::Chunked {
                            chunk_rows,
                            codec,
                            id,
                        }
                    }
                    t => bail!("h5lite: unknown layout tag {t}"),
                }
            };
            g.datasets.insert(
                name,
                Dataset {
                    dtype,
                    shape,
                    layout,
                },
            );
        }
        let n_groups = d.u32()?;
        for _ in 0..n_groups {
            let name = d.str()?;
            g.groups.insert(name, Group::decode(d, version, reg, next_id)?);
        }
        Ok(g)
    }
}

/// One-deep-per-dataset decoded-chunk cache, keyed by dataset id: the
/// offline sliding window and the snapshot restore read rows one at a
/// time, interleaving the three cell-data datasets — a single shared slot
/// would thrash on the interleave and decompress every chunk once per row
/// instead of once. Capped at [`CHUNK_CACHE_DATASETS`] entries (epoch
/// clear on overflow) so a long-lived reader walking many timesteps
/// doesn't retain one decoded chunk per dataset forever.
type ChunkCache = HashMap<u64, (u64, Arc<Vec<u8>>)>;

/// Max datasets with a live cached chunk before the cache is cleared.
const CHUNK_CACHE_DATASETS: usize = 8;

/// An h5lite file handle.
///
/// Creation/structure mutation requires `&mut self` (matching Parallel
/// HDF5's rule that groups and datasets are created *collectively*); slab
/// reads/writes take `&self` and may run concurrently from many threads
/// (each rank/aggregator owns a disjoint row range, and the chunk
/// allocator/index are internally locked).
pub struct H5File {
    file: File,
    pub path: PathBuf,
    pub root: Group,
    /// Next free data offset (end of data region).
    data_end: Mutex<u64>,
    /// Alignment for contiguous dataset payload starts (paper §5.2;
    /// 1 = none). Compressed chunk extents are packed unaligned.
    pub alignment: u64,
    version: u32,
    chunks: Mutex<ChunkRegistry>,
    next_ds_id: AtomicU64,
    cache: Mutex<ChunkCache>,
    /// Bumped on every chunk-extent write; readers snapshot it before
    /// loading an extent and only populate the cache if it is unchanged
    /// after decoding, so a write racing a reader of the same chunk can
    /// never leave pre-write bytes cached (the returned slice itself is
    /// safe — disjoint-range readers only consume rows the writer did not
    /// touch).
    cache_gen: AtomicU64,
    /// Serialises read-modify-write row writes on chunked datasets: two
    /// disjoint row ranges can share a chunk, and the RMW (read, patch,
    /// re-encode, swap extent) is not atomic per chunk. Chunk-granular
    /// writers ([`H5File::write_chunk_encoded`], used by the aggregators)
    /// bypass this and stay fully parallel.
    rmw: Mutex<()>,
}

impl H5File {
    /// Create a new file (truncating any existing one) in the default
    /// format. `alignment` aligns every contiguous dataset payload to that
    /// many bytes (use the file system block size; 1 disables).
    pub fn create<P: AsRef<Path>>(path: P, alignment: u64) -> Result<H5File> {
        H5File::create_versioned(path, alignment, VERSION)
    }

    /// Create a new file in an explicit format version (v1 = contiguous
    /// only, for compatibility tests and old readers; v2 = chunked +
    /// compressed storage available).
    pub fn create_versioned<P: AsRef<Path>>(
        path: P,
        alignment: u64,
        version: u32,
    ) -> Result<H5File> {
        assert!(alignment >= 1);
        if !(FORMAT_V1..=FORMAT_V2).contains(&version) {
            bail!("h5lite: cannot create format v{version}");
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("h5lite: create {:?}", path.as_ref()))?;
        let mut f = H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root: Group::default(),
            data_end: Mutex::new(SUPERBLOCK_LEN),
            alignment,
            version,
            chunks: Mutex::new(HashMap::new()),
            next_ds_id: AtomicU64::new(1),
            cache: Mutex::new(HashMap::new()),
            cache_gen: AtomicU64::new(0),
            rmw: Mutex::new(()),
        };
        f.commit()?;
        Ok(f)
    }

    /// Open an existing file (read + write). Accepts format v1 and v2.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<H5File> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("h5lite: open {:?}", path.as_ref()))?;
        let mut sb = [0u8; SUPERBLOCK_LEN as usize];
        file.read_exact(&mut sb)
            .context("h5lite: short superblock")?;
        if &sb[0..8] != MAGIC {
            bail!("h5lite: bad magic in {:?}", path.as_ref());
        }
        let mut d = Dec::new(&sb[8..]);
        let version = d.u32()?;
        if !(FORMAT_V1..=FORMAT_V2).contains(&version) {
            bail!("h5lite: unsupported version {version}");
        }
        let endian = d.u32()?;
        if endian != ENDIAN_TAG {
            bail!("h5lite: endianness tag mismatch (cross-endian file?)");
        }
        let footer_off = d.u64()?;
        let footer_len = d.u64()?;
        let alignment = d.u32()? as u64;
        let mut footer = vec![0u8; footer_len as usize];
        file.seek(SeekFrom::Start(footer_off))?;
        file.read_exact(&mut footer)
            .context("h5lite: short footer")?;
        let mut fd = Dec::new(&footer);
        let mut reg = HashMap::new();
        let mut next_id = 1u64;
        let root = Group::decode(&mut fd, version, &mut reg, &mut next_id)?;
        Ok(H5File {
            file,
            path: path.as_ref().to_path_buf(),
            root,
            data_end: Mutex::new(footer_off),
            alignment,
            version,
            chunks: Mutex::new(reg),
            next_ds_id: AtomicU64::new(next_id),
            cache: Mutex::new(HashMap::new()),
            cache_gen: AtomicU64::new(0),
            rmw: Mutex::new(()),
        })
    }

    /// On-disk format version of this file.
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Flush metadata: write the footer at the end of the data region and
    /// update the superblock. Readers opening the file afterwards see a
    /// consistent snapshot.
    pub fn commit(&mut self) -> Result<()> {
        let mut e = Enc::new();
        {
            let reg = self.chunks.lock().unwrap();
            self.root.encode(&mut e, self.version, &reg)?;
        }
        let footer_off = *self.data_end.lock().unwrap();
        self.file.seek(SeekFrom::Start(footer_off))?;
        self.file.write_all(&e.buf)?;
        // superblock
        let mut sb = Vec::with_capacity(SUPERBLOCK_LEN as usize);
        sb.extend_from_slice(MAGIC);
        let mut se = Enc::new();
        se.u32(self.version);
        se.u32(ENDIAN_TAG);
        se.u64(footer_off);
        se.u64(e.buf.len() as u64);
        se.u32(self.alignment as u32);
        sb.extend_from_slice(&se.buf);
        sb.resize(SUPERBLOCK_LEN as usize, 0);
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&sb)?;
        self.file.flush()?;
        Ok(())
    }

    /// Resolve a `/`-separated group path, creating missing groups.
    pub fn ensure_group(&mut self, path: &str) -> &mut Group {
        let mut g = &mut self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g.groups.entry(part.to_string()).or_default();
        }
        g
    }

    /// Resolve a group path read-only.
    pub fn group(&self, path: &str) -> Result<&Group> {
        let mut g = &self.root;
        for part in path.split('/').filter(|p| !p.is_empty()) {
            g = g
                .groups
                .get(part)
                .ok_or_else(|| anyhow!("h5lite: no group '{part}' in '{path}'"))?;
        }
        Ok(g)
    }

    /// Reserve `nbytes` of data-region space aligned to `align`, extending
    /// the file. Thread-safe (the chunk writers allocate concurrently).
    fn alloc(&self, nbytes: u64, align: u64) -> Result<u64> {
        let mut end = self.data_end.lock().unwrap();
        let offset = end.next_multiple_of(align.max(1));
        self.file.set_len(offset + nbytes)?;
        *end = offset + nbytes;
        Ok(offset)
    }

    /// Create a contiguous dataset under `group_path`, reserving (aligned)
    /// space for the full shape. Like Parallel HDF5, creation is collective:
    /// the caller must know the global shape; individual ranks then write
    /// their hyperslabs independently.
    pub fn create_dataset(
        &mut self,
        group_path: &str,
        name: &str,
        dtype: Dtype,
        shape: &[u64],
    ) -> Result<Dataset> {
        if self.group(group_path).map_or(false, |g| g.datasets.contains_key(name)) {
            bail!("h5lite: dataset '{group_path}/{name}' already exists");
        }
        let ds = Dataset {
            dtype,
            shape: shape.to_vec(),
            layout: Layout::Contiguous { offset: 0 },
        };
        let offset = self.alloc(ds.n_bytes(), self.alignment)?;
        let ds = Dataset {
            layout: Layout::Contiguous { offset },
            ..ds
        };
        self.ensure_group(group_path)
            .datasets
            .insert(name.to_string(), ds.clone());
        Ok(ds)
    }

    /// Create a chunked dataset (format v2): rows are grouped into
    /// `chunk_rows`-row chunks, each stored as an independent extent
    /// encoded with `codec`. No space is reserved up front — extents are
    /// allocated as chunks are written.
    pub fn create_dataset_chunked(
        &mut self,
        group_path: &str,
        name: &str,
        dtype: Dtype,
        shape: &[u64],
        chunk_rows: u64,
        codec: Codec,
    ) -> Result<Dataset> {
        if self.version < FORMAT_V2 {
            bail!("h5lite: chunked datasets need format v2 (file is v{})", self.version);
        }
        if chunk_rows == 0 {
            bail!("h5lite: chunk_rows must be >= 1");
        }
        if shape.is_empty() {
            bail!("h5lite: chunked dataset needs at least one dimension");
        }
        if self.group(group_path).map_or(false, |g| g.datasets.contains_key(name)) {
            bail!("h5lite: dataset '{group_path}/{name}' already exists");
        }
        let id = self.next_ds_id.fetch_add(1, Ordering::Relaxed);
        let n_chunks = shape[0].div_ceil(chunk_rows);
        self.chunks.lock().unwrap().insert(
            id,
            ChunkTable {
                entries: vec![None; n_chunks as usize],
            },
        );
        let ds = Dataset {
            dtype,
            shape: shape.to_vec(),
            layout: Layout::Chunked {
                chunk_rows,
                codec,
                id,
            },
        };
        self.ensure_group(group_path)
            .datasets
            .insert(name.to_string(), ds.clone());
        Ok(ds)
    }

    /// Look up a dataset by group path + name.
    pub fn dataset(&self, group_path: &str, name: &str) -> Result<Dataset> {
        self.group(group_path)?
            .datasets
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("h5lite: no dataset '{name}' in '{group_path}'"))
    }

    /// Write rows of raw bytes starting at `row_start` (hyperslab along the
    /// first dimension). Concurrent-safe for disjoint ranges: contiguous
    /// writes are positional pwrites; chunked writes read-modify-write the
    /// touched chunks under an internal per-file lock (disjoint row ranges
    /// may share a chunk, so the RMW must serialise — the collective path
    /// stays parallel by writing whole chunks via
    /// [`H5File::write_chunk_encoded`] instead).
    pub fn write_rows(&self, ds: &Dataset, row_start: u64, data: &[u8]) -> Result<()> {
        let rb = ds.row_bytes();
        if data.len() as u64 % rb != 0 {
            bail!("h5lite: write not a whole number of rows");
        }
        let rows = data.len() as u64 / rb;
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        match ds.layout {
            Layout::Contiguous { offset } => self
                .file
                .write_all_at(data, offset + row_start * rb)
                .context("h5lite: slab write"),
            Layout::Chunked { .. } => self.write_rows_chunked(ds, row_start, data),
        }
    }

    fn write_rows_chunked(&self, ds: &Dataset, row_start: u64, data: &[u8]) -> Result<()> {
        let rb = ds.row_bytes();
        let (_, codec, _) = ds.chunk_meta().unwrap();
        let rows = data.len() as u64 / rb;
        let mut done = 0u64;
        for (chunk_no, row_in_chunk, take) in ds.chunk_spans(row_start, rows) {
            let src = &data[(done * rb) as usize..((done + take) * rb) as usize];
            if row_in_chunk == 0 && take == ds.chunk_rows_at(chunk_no) {
                // whole chunk replaced: encode straight from the caller's
                // buffer, no lock — disjoint-range writers can never pair a
                // whole-chunk write with another write of the same chunk,
                // so threaded whole-chunk callers compress in parallel
                self.encode_and_write_chunk(ds, chunk_no, src, codec)?;
            } else {
                // partial: read-modify-write against existing content;
                // serialised because two disjoint row ranges can share this
                // chunk and the read→patch→re-encode→swap is not atomic
                let _rmw = self.rmw.lock().unwrap();
                let mut raw = self.read_chunk_raw(ds, chunk_no)?.as_ref().clone();
                let off = (row_in_chunk * rb) as usize;
                raw[off..off + src.len()].copy_from_slice(src);
                self.encode_and_write_chunk(ds, chunk_no, &raw, codec)?;
            }
            done += take;
        }
        Ok(())
    }

    fn encode_and_write_chunk(
        &self,
        ds: &Dataset,
        chunk_no: u64,
        raw: &[u8],
        codec: Codec,
    ) -> Result<()> {
        let (enc, checksum) = codec::encode_chunk(codec, raw, ds.dtype.size());
        let (stored, applied): (&[u8], bool) = match &enc {
            Some(e) => (e, true),
            None => (raw, false),
        };
        self.write_chunk_encoded(ds, chunk_no, stored, raw.len() as u64, checksum, applied)
    }

    /// Store one already-encoded chunk extent and record it in the chunk
    /// index. Used by the collective-buffering aggregators, which run the
    /// codec on their own threads during the fill phase; `codec_applied =
    /// false` stores the raw bytes (incompressible chunk).
    pub fn write_chunk_encoded(
        &self,
        ds: &Dataset,
        chunk_no: u64,
        stored: &[u8],
        raw_len: u64,
        checksum: u32,
        codec_applied: bool,
    ) -> Result<()> {
        let (_, _, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: write_chunk_encoded on contiguous dataset"))?;
        if chunk_no >= ds.n_chunks() {
            bail!("h5lite: chunk {chunk_no} out of range ({})", ds.n_chunks());
        }
        let expect_raw = ds.chunk_rows_at(chunk_no) * ds.row_bytes();
        if raw_len != expect_raw {
            bail!("h5lite: chunk {chunk_no} raw length {raw_len}, expected {expect_raw}");
        }
        let offset = self.alloc(stored.len() as u64, 1)?;
        self.file
            .write_all_at(stored, offset)
            .context("h5lite: chunk extent write")?;
        {
            let mut reg = self.chunks.lock().unwrap();
            let table = reg
                .get_mut(&id)
                .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
            table.entries[chunk_no as usize] = Some(ChunkLoc {
                offset,
                stored: stored.len() as u64,
                raw: raw_len,
                checksum,
                codec_applied,
            });
        }
        // bump BEFORE invalidating: a reader that passes its generation
        // check inserted before this point, so the removal below cleans it
        // up; a reader checking after this point skips its insert. The
        // reverse order would leave a window (after removal, before bump)
        // where a stale insert survives.
        self.cache_gen.fetch_add(1, Ordering::Release);
        {
            let mut cache = self.cache.lock().unwrap();
            if cache.get(&id).map_or(false, |&(no, _)| no == chunk_no) {
                cache.remove(&id);
            }
        }
        Ok(())
    }

    /// Chunk index entry for `chunk_no` (`None` = not yet written).
    pub fn chunk_loc(&self, ds: &Dataset, chunk_no: u64) -> Result<Option<ChunkLoc>> {
        let (_, _, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: chunk_loc on contiguous dataset"))?;
        let reg = self.chunks.lock().unwrap();
        let table = reg
            .get(&id)
            .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
        table
            .entries
            .get(chunk_no as usize)
            .copied()
            .ok_or_else(|| anyhow!("h5lite: chunk {chunk_no} out of range"))
    }

    /// Read and decode one whole chunk (zeros if never written). Cached
    /// one-deep per file for row-at-a-time readers.
    pub fn read_chunk_raw(&self, ds: &Dataset, chunk_no: u64) -> Result<Arc<Vec<u8>>> {
        let (_, codec, id) = ds
            .chunk_meta()
            .ok_or_else(|| anyhow!("h5lite: read_chunk_raw on contiguous dataset"))?;
        {
            let cache = self.cache.lock().unwrap();
            if let Some((no, data)) = cache.get(&id) {
                if *no == chunk_no {
                    return Ok(Arc::clone(data));
                }
            }
        }
        let gen0 = self.cache_gen.load(Ordering::Acquire);
        let loc = self.chunk_loc(ds, chunk_no)?;
        let expect_raw = (ds.chunk_rows_at(chunk_no) * ds.row_bytes()) as usize;
        let raw = match loc {
            None => Arc::new(vec![0u8; expect_raw]),
            Some(loc) => {
                let mut stored = vec![0u8; loc.stored as usize];
                self.file
                    .read_exact_at(&mut stored, loc.offset)
                    .context("h5lite: chunk extent read")?;
                let raw = if loc.codec_applied {
                    codec.decode(&stored, ds.dtype.size(), loc.raw as usize)?
                } else {
                    if stored.len() as u64 != loc.raw {
                        bail!("h5lite: raw-stored chunk length mismatch");
                    }
                    stored
                };
                if raw.len() != expect_raw {
                    bail!(
                        "h5lite: chunk {chunk_no} decoded to {} bytes, expected {expect_raw}",
                        raw.len()
                    );
                }
                if codec::checksum32(&raw) != loc.checksum {
                    bail!("h5lite: chunk {chunk_no} checksum mismatch (corrupt extent?)");
                }
                Arc::new(raw)
            }
        };
        // Only cache if no write landed while we were decoding — a racing
        // write of this chunk would otherwise leave pre-write bytes cached.
        // The generation check runs under the cache lock: the writer bumps
        // the generation *before* taking this lock to invalidate, so either
        // we insert first and its removal cleans us up, or we see the bump
        // and skip.
        {
            let mut cache = self.cache.lock().unwrap();
            if self.cache_gen.load(Ordering::Acquire) == gen0 {
                if !cache.contains_key(&id) && cache.len() >= CHUNK_CACHE_DATASETS {
                    cache.clear(); // epoch eviction: bound long-lived readers
                }
                cache.insert(id, (chunk_no, Arc::clone(&raw)));
            }
        }
        Ok(raw)
    }

    /// Read `rows` rows starting at `row_start` as raw bytes; chunked
    /// datasets decompress transparently.
    pub fn read_rows(&self, ds: &Dataset, row_start: u64, rows: u64) -> Result<Vec<u8>> {
        if row_start + rows > ds.shape[0] {
            bail!(
                "h5lite: hyperslab [{row_start}, {}) exceeds {} rows",
                row_start + rows,
                ds.shape[0]
            );
        }
        let rb = ds.row_bytes();
        match ds.layout {
            Layout::Contiguous { offset } => {
                let mut buf = vec![0u8; (rows * rb) as usize];
                self.file
                    .read_exact_at(&mut buf, offset + row_start * rb)
                    .context("h5lite: slab read")?;
                Ok(buf)
            }
            Layout::Chunked { .. } => {
                let mut out = Vec::with_capacity((rows * rb) as usize);
                for (chunk_no, row_in_chunk, take) in ds.chunk_spans(row_start, rows) {
                    let raw = self.read_chunk_raw(ds, chunk_no)?;
                    let off = (row_in_chunk * rb) as usize;
                    out.extend_from_slice(&raw[off..off + (take * rb) as usize]);
                }
                Ok(out)
            }
        }
    }

    /// Physical payload bytes a dataset occupies on disk: the reservation
    /// for contiguous layouts, the sum of stored extents for chunked ones
    /// (the compression win the fig8 bench reports).
    pub fn dataset_stored_bytes(&self, ds: &Dataset) -> Result<u64> {
        match ds.layout {
            Layout::Contiguous { .. } => Ok(ds.n_bytes()),
            Layout::Chunked { id, .. } => {
                let reg = self.chunks.lock().unwrap();
                let table = reg
                    .get(&id)
                    .ok_or_else(|| anyhow!("h5lite: chunk table missing (id {id})"))?;
                Ok(table
                    .entries
                    .iter()
                    .flatten()
                    .map(|l| l.stored)
                    .sum())
            }
        }
    }

    /// Convenience: write a full `f32` dataset in one call.
    pub fn write_all_f32(&self, ds: &Dataset, data: &[f32]) -> Result<()> {
        if data.len() as u64 != ds.n_elems() {
            bail!("h5lite: length mismatch");
        }
        self.write_rows(ds, 0, &codec::f32s_to_bytes(data))
    }

    /// Convenience: read a full `u64` dataset.
    pub fn read_all_u64(&self, ds: &Dataset) -> Result<Vec<u64>> {
        Ok(codec::bytes_to_u64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Convenience: read a full `f64` dataset.
    pub fn read_all_f64(&self, ds: &Dataset) -> Result<Vec<f64>> {
        Ok(codec::bytes_to_f64s(&self.read_rows(ds, 0, ds.shape[0])?))
    }

    /// Current physical size of the data region (metadata excluded) — the
    /// quantity the paper reports as "checkpoint size".
    pub fn data_bytes(&self) -> u64 {
        *self.data_end.lock().unwrap() - SUPERBLOCK_LEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("h5lite_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn create_open_roundtrip_empty() {
        let p = tmp("empty");
        {
            H5File::create(&p, 1).unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert!(f.root.groups.is_empty());
        assert_eq!(f.version(), FORMAT_V2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn groups_attrs_roundtrip() {
        let p = tmp("attrs");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let g = f.ensure_group("/common");
            g.attrs.insert("dt".into(), Attr::F64(0.01));
            g.attrs.insert("scheme".into(), Attr::Str("chorin".into()));
            g.attrs
                .insert("spacings".into(), Attr::F64Vec(vec![0.1, 0.05]));
            g.attrs.insert("steps".into(), Attr::I64(500));
            f.ensure_group("/simulation/t=0.000000");
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let g = f.group("/common").unwrap();
        assert_eq!(g.attrs["dt"], Attr::F64(0.01));
        assert_eq!(g.attrs["scheme"], Attr::Str("chorin".into()));
        assert_eq!(g.attrs["spacings"], Attr::F64Vec(vec![0.1, 0.05]));
        assert_eq!(g.attrs["steps"], Attr::I64(500));
        assert!(f.group("/simulation/t=0.000000").is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dataset_write_read_full() {
        let p = tmp("full");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/sim", "cells", Dtype::F32, &[4, 8])
                .unwrap();
            let data: Vec<f32> = (0..32).map(|x| x as f32 * 0.5).collect();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/sim", "cells").unwrap();
        assert_eq!(ds.shape, vec![4, 8]);
        assert_eq!(ds.dtype, Dtype::F32);
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 4).unwrap());
        assert_eq!(back[5], 2.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_disjoint_writes() {
        let p = tmp("slab");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[10, 3])
            .unwrap();
        // two "ranks" write rows [0,5) and [5,10)
        let a: Vec<u64> = (0..15).collect();
        let b: Vec<u64> = (100..115).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&a)).unwrap();
        f.write_rows(&ds, 5, &codec::u64s_to_bytes(&b)).unwrap();
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[14], 14);
        assert_eq!(all[15], 100);
        assert_eq!(all[29], 114);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hyperslab_bounds_checked() {
        let p = tmp("bounds");
        let f0 = {
            let mut f = H5File::create(&p, 1).unwrap();
            f.create_dataset("/g", "d", Dtype::U8, &[4, 2]).unwrap();
            f
        };
        let ds = f0.dataset("/g", "d").unwrap();
        assert!(f0.write_rows(&ds, 3, &[0u8; 4]).is_err()); // 2 rows at 3 > 4
        assert!(f0.read_rows(&ds, 0, 5).is_err());
        assert!(f0.write_rows(&ds, 0, &[0u8; 3]).is_err()); // partial row
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn alignment_respected() {
        let p = tmp("align");
        let mut f = H5File::create(&p, 4096).unwrap();
        let d1 = f.create_dataset("/g", "a", Dtype::U8, &[10]).unwrap();
        let d2 = f.create_dataset("/g", "b", Dtype::U8, &[10]).unwrap();
        assert_eq!(d1.contiguous_offset().unwrap() % 4096, 0);
        assert_eq!(d2.contiguous_offset().unwrap() % 4096, 0);
        assert!(d2.contiguous_offset().unwrap() >= d1.contiguous_offset().unwrap() + 4096);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_dataset_rejected() {
        let p = tmp("dup");
        let mut f = H5File::create(&p, 1).unwrap();
        f.create_dataset("/g", "d", Dtype::U8, &[1]).unwrap();
        assert!(f.create_dataset("/g", "d", Dtype::U8, &[1]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reopen_append_timestep_preserves_old_data() {
        let p = tmp("append");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset("/simulation/t=0", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[1.0, 2.0]).unwrap();
            f.commit().unwrap();
        }
        {
            let mut f = H5File::open(&p).unwrap();
            let ds = f
                .create_dataset("/simulation/t=1", "x", Dtype::F32, &[2])
                .unwrap();
            f.write_all_f32(&ds, &[3.0, 4.0]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let d0 = f.dataset("/simulation/t=0", "x").unwrap();
        let d1 = f.dataset("/simulation/t=1", "x").unwrap();
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d0, 0, 2).unwrap()),
            vec![1.0, 2.0]
        );
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&d1, 0, 2).unwrap()),
            vec![3.0, 4.0]
        );
        // both timestep groups visible
        assert_eq!(f.group("/simulation").unwrap().groups.len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTAFILE________________________________").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_slab_writes_from_threads() {
        let p = tmp("threads");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset("/g", "d", Dtype::U64, &[64, 4])
            .unwrap();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..32).map(|i| t * 1000 + i).collect();
                    fref.write_rows(dref, t * 8, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        for t in 0..8u64 {
            assert_eq!(all[(t * 32) as usize], t * 1000);
            assert_eq!(all[(t * 32 + 31) as usize], t * 1000 + 31);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_footer_is_error_not_panic() {
        let p = tmp("trunc");
        {
            let mut f = H5File::create(&p, 1).unwrap();
            f.ensure_group("/a/b");
            let ds = f.create_dataset("/a", "d", Dtype::F32, &[8]).unwrap();
            f.write_all_f32(&ds, &[0.0; 8]).unwrap();
            f.commit().unwrap();
        }
        // chop the footer in half: open must fail cleanly
        let len = std::fs::metadata(&p).unwrap().len();
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.set_len(len - 10).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_superblock_offset_is_error() {
        let p = tmp("corrupt");
        {
            H5File::create(&p, 1).unwrap();
        }
        // point footer_off way past EOF
        let file = OpenOptions::new().write(true).open(&p).unwrap();
        file.write_all_at(&u64::MAX.to_le_bytes(), 16).unwrap();
        drop(file);
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_is_error() {
        let p = tmp("zero");
        std::fs::write(&p, b"").unwrap();
        assert!(H5File::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn data_bytes_tracks_payload() {
        let p = tmp("size");
        let mut f = H5File::create(&p, 1).unwrap();
        assert_eq!(f.data_bytes(), 0);
        f.create_dataset("/g", "d", Dtype::F32, &[100]).unwrap();
        assert_eq!(f.data_bytes(), 400);
        std::fs::remove_file(&p).ok();
    }

    // ---------------------------------------------------------------------
    // format v2: chunked + compressed storage
    // ---------------------------------------------------------------------

    /// Smooth f32 rows (compressible, like real cell data).
    fn smooth_rows(rows: usize, row_elems: usize) -> Vec<f32> {
        (0..rows * row_elems)
            .map(|i| 1.0 + (i as f32 * 1e-3).sin() * 0.25)
            .collect()
    }

    #[test]
    fn chunked_roundtrip_matches_contiguous() {
        let p = tmp("chunk_rt");
        let mut f = H5File::create(&p, 1).unwrap();
        let data = smooth_rows(37, 16); // 37 rows: 4 full chunks + short tail
        let raw = codec::f32s_to_bytes(&data);
        let dc = f
            .create_dataset("/g", "plain", Dtype::F32, &[37, 16])
            .unwrap();
        let dk = f
            .create_dataset_chunked("/g", "packed", Dtype::F32, &[37, 16], 8, Codec::ShuffleDeltaLz)
            .unwrap();
        f.write_rows(&dc, 0, &raw).unwrap();
        f.write_rows(&dk, 0, &raw).unwrap();
        f.commit().unwrap();
        // byte-compare every row range against the uncompressed layout
        for (start, rows) in [(0u64, 37u64), (0, 1), (7, 2), (8, 8), (30, 7), (36, 1)] {
            assert_eq!(
                f.read_rows(&dk, start, rows).unwrap(),
                f.read_rows(&dc, start, rows).unwrap(),
                "rows [{start}, {})",
                start + rows
            );
        }
        // and the chunked copy actually stores fewer payload bytes
        let stored = f.dataset_stored_bytes(&dk).unwrap();
        assert!(stored < dk.n_bytes(), "{stored} vs {}", dk.n_bytes());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_survives_reopen() {
        let p = tmp("chunk_reopen");
        let data = smooth_rows(20, 8);
        {
            let mut f = H5File::create(&p, 1).unwrap();
            let ds = f
                .create_dataset_chunked("/g", "d", Dtype::F32, &[20, 8], 6, Codec::ShuffleLz)
                .unwrap();
            f.write_all_f32(&ds, &data).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let ds = f.dataset("/g", "d").unwrap();
        assert!(ds.is_chunked());
        assert_eq!(ds.n_chunks(), 4); // 6+6+6+2
        assert_eq!(ds.chunk_rows_at(3), 2);
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 20).unwrap());
        assert_eq!(back, data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_partial_write_is_read_modify_write() {
        let p = tmp("chunk_rmw");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[10, 2], 4, Codec::Lz)
            .unwrap();
        let base: Vec<u64> = (0..20).collect();
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&base)).unwrap();
        // overwrite rows 3..5 (staddles the chunk 0 / chunk 1 boundary)
        let patch: Vec<u64> = vec![900, 901, 902, 903];
        f.write_rows(&ds, 3, &codec::u64s_to_bytes(&patch)).unwrap();
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[..6], [0, 1, 2, 3, 4, 5]);
        assert_eq!(all[6..10], [900, 901, 902, 903]);
        assert_eq!(all[10..], (10u64..20).collect::<Vec<_>>()[..]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_unwritten_chunks_read_as_zeros() {
        let p = tmp("chunk_zeros");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[12, 4], 4, Codec::ShuffleLz)
            .unwrap();
        // only the middle chunk written
        f.write_rows(&ds, 4, &codec::f32s_to_bytes(&[7.0; 16])).unwrap();
        let back = codec::bytes_to_f32s(&f.read_rows(&ds, 0, 12).unwrap());
        assert!(back[..16].iter().all(|&x| x == 0.0));
        assert!(back[16..32].iter().all(|&x| x == 7.0));
        assert!(back[32..].iter().all(|&x| x == 0.0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_checksum_detects_corruption() {
        let p = tmp("chunk_crc");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8, 8], 8, Codec::ShuffleDeltaLz)
            .unwrap();
        f.write_all_f32(&ds, &smooth_rows(8, 8)).unwrap();
        f.commit().unwrap();
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert!(loc.stored < loc.raw);
        // flip one byte in the middle of the stored extent
        let file = OpenOptions::new().write(true).read(true).open(&p).unwrap();
        let mut b = [0u8; 1];
        file.read_exact_at(&mut b, loc.offset + loc.stored / 2).unwrap();
        file.write_all_at(&[b[0] ^ 0xff], loc.offset + loc.stored / 2)
            .unwrap();
        drop(file);
        let f2 = H5File::open(&p).unwrap();
        let ds2 = f2.dataset("/g", "d").unwrap();
        assert!(f2.read_rows(&ds2, 0, 8).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn incompressible_chunks_stored_raw() {
        let p = tmp("chunk_incomp");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U8, &[1024], 1024, Codec::Lz)
            .unwrap();
        // xorshift noise: LZ finds nothing, extent must fall back to raw
        let mut s = 0x9E37_79B9u64;
        let noise: Vec<u8> = (0..1024)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 24) as u8
            })
            .collect();
        f.write_rows(&ds, 0, &noise).unwrap();
        let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
        assert!(!loc.codec_applied);
        assert_eq!(loc.stored, loc.raw);
        assert_eq!(f.read_rows(&ds, 0, 1024).unwrap(), noise);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_chunk_writes_from_threads() {
        let p = tmp("chunk_threads");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[64, 4], 8, Codec::ShuffleLz)
            .unwrap();
        // 8 threads, each owning one whole chunk (8 rows)
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..32).map(|i| t * 1000 + i).collect();
                    fref.write_rows(dref, t * 8, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        for t in 0..8u64 {
            assert_eq!(all[(t * 32) as usize], t * 1000);
            assert_eq!(all[(t * 32 + 31) as usize], t * 1000 + 31);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn concurrent_disjoint_ranges_sharing_a_chunk() {
        // two writers own disjoint row ranges that land in the SAME chunk:
        // the internal RMW lock must keep both writes
        let p = tmp("chunk_shared");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[8, 4], 8, Codec::Lz)
            .unwrap();
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let fref = &f;
                let dref = &ds;
                s.spawn(move || {
                    let rows: Vec<u64> = (0..16).map(|i| t * 100 + i).collect();
                    fref.write_rows(dref, t * 4, &codec::u64s_to_bytes(&rows))
                        .unwrap();
                });
            }
        });
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[15], 15);
        assert_eq!(all[16], 100);
        assert_eq!(all[31], 115);
        std::fs::remove_file(&p).ok();
    }

    // ---------------------------------------------------------------------
    // format v1 backward compatibility
    // ---------------------------------------------------------------------

    #[test]
    fn v2_reader_opens_v1_file() {
        let p = tmp("v1_compat");
        {
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
            let g = f.ensure_group("/common");
            g.attrs.insert("dt".into(), Attr::F64(0.5));
            let ds = f.create_dataset("/sim", "x", Dtype::F32, &[3]).unwrap();
            f.write_all_f32(&ds, &[1.0, 2.0, 3.0]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V1);
        assert_eq!(f.group("/common").unwrap().attrs["dt"], Attr::F64(0.5));
        let ds = f.dataset("/sim", "x").unwrap();
        assert!(!ds.is_chunked());
        assert_eq!(
            codec::bytes_to_f32s(&f.read_rows(&ds, 0, 3).unwrap()),
            vec![1.0, 2.0, 3.0]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_file_refuses_chunked_datasets() {
        let p = tmp("v1_nochunk");
        let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
        assert!(f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[8], 4, Codec::Lz)
            .is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_appends_keep_v1_format() {
        let p = tmp("v1_append");
        {
            let mut f = H5File::create_versioned(&p, 1, FORMAT_V1).unwrap();
            let ds = f.create_dataset("/a", "x", Dtype::U8, &[2]).unwrap();
            f.write_rows(&ds, 0, &[1, 2]).unwrap();
            f.commit().unwrap();
        }
        {
            let mut f = H5File::open(&p).unwrap();
            assert_eq!(f.version(), FORMAT_V1);
            let ds = f.create_dataset("/b", "y", Dtype::U8, &[2]).unwrap();
            f.write_rows(&ds, 0, &[3, 4]).unwrap();
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), FORMAT_V1);
        assert_eq!(
            f.read_rows(&f.dataset("/a", "x").unwrap(), 0, 2).unwrap(),
            vec![1, 2]
        );
        assert_eq!(
            f.read_rows(&f.dataset("/b", "y").unwrap(), 0, 2).unwrap(),
            vec![3, 4]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_version_rejected() {
        let p = tmp("v9");
        assert!(H5File::create_versioned(&p, 1, 9).is_err());
        std::fs::remove_file(&p).ok();
    }
}
