//! Little-endian binary encoding helpers for the h5lite metadata footer.
//!
//! Everything is explicitly little-endian with an endianness tag in the
//! superblock, mirroring HDF5's self-describing storage model: a file
//! written here can be decoded on any architecture.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.f64(*v);
        }
    }

    pub fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.u64(*v);
        }
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "h5lite: truncated metadata (need {} bytes at {}, have {})",
                n,
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Convert `f32` slice views to/from raw little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn f64s_to_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f64s(b: &[u8]) -> Vec<f64> {
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEADBEEF);
        e.u64(1 << 40);
        e.i64(-42);
        e.f64(3.5);
        e.str("hello/world");
        e.f64s(&[1.0, 2.0]);
        e.u64s(&[9, 8, 7]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.i64().unwrap(), -42);
        assert_eq!(d.f64().unwrap(), 3.5);
        assert_eq!(d.str().unwrap(), "hello/world");
        assert_eq!(d.f64s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(d.u64s().unwrap(), vec![9, 8, 7]);
        assert!(d.done());
    }

    #[test]
    fn dec_truncation_is_error() {
        let mut e = Enc::new();
        e.u32(5);
        let mut d = Dec::new(&e.buf);
        assert!(d.u64().is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&v)), v);
    }

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, u64::MAX, 1 << 63];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)), v);
    }

    #[test]
    fn f64_bytes_roundtrip() {
        let v = vec![0.25f64, -1e300];
        assert_eq!(bytes_to_f64s(&f64s_to_bytes(&v)), v);
    }
}
