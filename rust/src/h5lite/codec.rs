//! Little-endian binary encoding helpers for the h5lite metadata footer.
//!
//! Everything is explicitly little-endian with an endianness tag in the
//! superblock, mirroring HDF5's self-describing storage model: a file
//! written here can be decoded on any architecture.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.f64(*v);
        }
    }

    pub fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.u64(*v);
        }
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "h5lite: truncated metadata (need {} bytes at {}, have {})",
                n,
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Convert `f32` slice views to/from raw little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn f64s_to_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f64s(b: &[u8]) -> Vec<f64> {
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

// ---------------------------------------------------------------------------
// chunk compression (format v2)
// ---------------------------------------------------------------------------
//
// The per-chunk filter pipeline of the v2 chunked layout, mirroring HDF5's
// filter stack (shuffle → deflate). Three building blocks:
//
// * **LZ** — a byte-oriented LZ77 with a 64 KiB window. Token stream:
//   a control byte `c < 0x80` introduces a literal run of `c + 1` bytes;
//   `c >= 0x80` is a match of length `(c & 0x7f) + 4` (4..=131) followed by a
//   little-endian u16 distance (1..=65535). Overlapping copies are legal
//   (RLE through distance < length).
// * **shuffle** — HDF5's byte shuffle: transpose an array of n-byte elements
//   into n byte planes, so the slowly-varying high bytes of f32/f64/u64
//   values become long near-constant runs.
// * **delta** — byte-wise wrapping first difference applied after the
//   shuffle; near-constant planes become runs of zeros, which LZ collapses.

/// Per-chunk codec of a v2 chunked dataset (stored in the metadata footer).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Codec {
    /// No transformation: chunk extents hold raw little-endian bytes.
    Raw,
    /// LZ byte compression only.
    Lz,
    /// Byte shuffle (by element size), then LZ.
    ShuffleLz,
    /// Byte shuffle, byte-wise delta, then LZ — the default for the heavy
    /// f32 cell-data datasets.
    ShuffleDeltaLz,
}

impl Codec {
    pub fn code(self) -> u8 {
        match self {
            Codec::Raw => 0,
            Codec::Lz => 1,
            Codec::ShuffleLz => 2,
            Codec::ShuffleDeltaLz => 3,
        }
    }

    pub fn from_code(c: u8) -> Result<Codec> {
        Ok(match c {
            0 => Codec::Raw,
            1 => Codec::Lz,
            2 => Codec::ShuffleLz,
            3 => Codec::ShuffleDeltaLz,
            _ => bail!("h5lite: unknown codec code {c}"),
        })
    }

    /// Apply the filter pipeline to one raw chunk. `elem_size` is the
    /// dataset's element width (the shuffle stride).
    pub fn encode(self, raw: &[u8], elem_size: usize) -> Vec<u8> {
        match self {
            Codec::Raw => raw.to_vec(),
            Codec::Lz => lz_compress(raw),
            Codec::ShuffleLz => lz_compress(&shuffle(raw, elem_size)),
            Codec::ShuffleDeltaLz => {
                let mut s = shuffle(raw, elem_size);
                delta_encode(&mut s);
                lz_compress(&s)
            }
        }
    }

    /// Invert [`Codec::encode`]. `raw_len` is the expected decoded length
    /// (known from the chunk index); a mismatch is a hard error.
    pub fn decode(self, stored: &[u8], elem_size: usize, raw_len: usize) -> Result<Vec<u8>> {
        let out = match self {
            Codec::Raw => stored.to_vec(),
            Codec::Lz => lz_decompress(stored, raw_len)?,
            Codec::ShuffleLz => unshuffle(&lz_decompress(stored, raw_len)?, elem_size),
            Codec::ShuffleDeltaLz => {
                let mut s = lz_decompress(stored, raw_len)?;
                delta_decode(&mut s);
                unshuffle(&s, elem_size)
            }
        };
        if out.len() != raw_len {
            bail!(
                "h5lite: chunk decoded to {} bytes, expected {raw_len}",
                out.len()
            );
        }
        Ok(out)
    }
}

/// Run the codec over one raw chunk and decide what to store: `Some(enc)`
/// when the codec actually shrinks it, `None` when the raw bytes go to
/// disk unfiltered (HDF5's per-chunk filter mask), plus the checksum of
/// the raw bytes. Both chunk writers — [`crate::h5lite::H5File`]'s
/// read-modify-write path and the pario aggregators — must share this so
/// the store-smaller-of / checksum-over-raw format invariants cannot
/// drift apart.
pub fn encode_chunk(codec: Codec, raw: &[u8], elem_size: usize) -> (Option<Vec<u8>>, u32) {
    let enc = codec.encode(raw, elem_size);
    let checksum = checksum32(raw);
    if enc.len() < raw.len() {
        (Some(enc), checksum)
    } else {
        (None, checksum)
    }
}

/// FNV-1a 32-bit checksum over a raw chunk (stored in the chunk index;
/// verified on every chunk read).
pub fn checksum32(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// HDF5-style byte shuffle: `[e0b0 e0b1 .. | e1b0 e1b1 ..]` becomes
/// `[e0b0 e1b0 .. | e0b1 e1b1 ..]`. A trailing partial element (never
/// produced by whole-row chunks) is appended unshuffled.
pub fn shuffle(data: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 || data.len() < elem_size {
        return data.to_vec();
    }
    let n = data.len() / elem_size;
    let body = n * elem_size;
    let mut out = Vec::with_capacity(data.len());
    for plane in 0..elem_size {
        for e in 0..n {
            out.push(data[e * elem_size + plane]);
        }
    }
    out.extend_from_slice(&data[body..]);
    out
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 || data.len() < elem_size {
        return data.to_vec();
    }
    let n = data.len() / elem_size;
    let body = n * elem_size;
    let mut out = vec![0u8; data.len()];
    for plane in 0..elem_size {
        for e in 0..n {
            out[e * elem_size + plane] = data[plane * n + e];
        }
    }
    out[body..].copy_from_slice(&data[body..]);
    out
}

/// In-place byte-wise wrapping first difference.
pub fn delta_encode(data: &mut [u8]) {
    let mut prev = 0u8;
    for b in data.iter_mut() {
        let cur = *b;
        *b = cur.wrapping_sub(prev);
        prev = cur;
    }
}

/// Inverse of [`delta_encode`].
pub fn delta_decode(data: &mut [u8]) {
    let mut prev = 0u8;
    for b in data.iter_mut() {
        prev = prev.wrapping_add(*b);
        *b = prev;
    }
}

const LZ_MIN_MATCH: usize = 4;
const LZ_MAX_MATCH: usize = 0x7f + LZ_MIN_MATCH;
const LZ_MAX_DIST: usize = 0xffff;
const LZ_HASH_BITS: u32 = 15;

#[inline]
fn lz_hash(data: &[u8], pos: usize) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(2654435761) >> (32 - LZ_HASH_BITS)) as usize
}

/// Compress `data` with the LZ token stream described in the module docs.
/// Worst case (incompressible input) expands by `len / 128 + 1` control
/// bytes — the chunk writer stores whichever of raw/compressed is smaller.
pub fn lz_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    let mut table = vec![0u32; 1 << LZ_HASH_BITS]; // position + 1; 0 = empty
    let mut lit_start = 0usize;
    let mut pos = 0usize;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut s = from;
        while s < to {
            let run = (to - s).min(128);
            out.push((run - 1) as u8);
            out.extend_from_slice(&data[s..s + run]);
            s += run;
        }
    };

    while pos + LZ_MIN_MATCH <= data.len() {
        let h = lz_hash(data, pos);
        let cand = table[h] as usize;
        table[h] = (pos + 1) as u32;
        let mut match_len = 0usize;
        if cand > 0 {
            let cpos = cand - 1;
            let dist = pos - cpos;
            if dist >= 1 && dist <= LZ_MAX_DIST {
                let max = (data.len() - pos).min(LZ_MAX_MATCH);
                let mut l = 0usize;
                while l < max && data[cpos + l] == data[pos + l] {
                    l += 1;
                }
                if l >= LZ_MIN_MATCH {
                    match_len = l;
                }
            }
        }
        if match_len > 0 {
            flush_literals(&mut out, lit_start, pos);
            let dist = pos - (cand - 1);
            out.push(0x80 | (match_len - LZ_MIN_MATCH) as u8);
            out.extend_from_slice(&(dist as u16).to_le_bytes());
            // seed the table through the matched region (sparse: every
            // other position keeps the encoder O(n) on repetitive input)
            let end = pos + match_len;
            let mut p = pos + 1;
            while p + LZ_MIN_MATCH <= data.len() && p < end {
                table[lz_hash(data, p)] = (p + 1) as u32;
                p += 2;
            }
            pos = end;
            lit_start = pos;
        } else {
            pos += 1;
        }
    }
    flush_literals(&mut out, lit_start, data.len());
    out
}

/// Decompress an LZ token stream into exactly `raw_len` bytes.
pub fn lz_decompress(comp: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while pos < comp.len() {
        let ctrl = comp[pos];
        pos += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            if pos + run > comp.len() {
                bail!("h5lite: truncated LZ literal run");
            }
            out.extend_from_slice(&comp[pos..pos + run]);
            pos += run;
        } else {
            let len = (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
            if pos + 2 > comp.len() {
                bail!("h5lite: truncated LZ match token");
            }
            let dist = u16::from_le_bytes([comp[pos], comp[pos + 1]]) as usize;
            pos += 2;
            if dist == 0 || dist > out.len() {
                bail!("h5lite: LZ match distance {dist} out of range");
            }
            let start = out.len() - dist;
            for i in 0..len {
                let b = out[start + i];
                out.push(b); // overlapping copies are byte-by-byte
            }
        }
        if out.len() > raw_len {
            bail!("h5lite: LZ stream overruns chunk ({} > {raw_len})", out.len());
        }
    }
    if out.len() != raw_len {
        bail!("h5lite: LZ stream yielded {} of {raw_len} bytes", out.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEADBEEF);
        e.u64(1 << 40);
        e.i64(-42);
        e.f64(3.5);
        e.str("hello/world");
        e.f64s(&[1.0, 2.0]);
        e.u64s(&[9, 8, 7]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.i64().unwrap(), -42);
        assert_eq!(d.f64().unwrap(), 3.5);
        assert_eq!(d.str().unwrap(), "hello/world");
        assert_eq!(d.f64s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(d.u64s().unwrap(), vec![9, 8, 7]);
        assert!(d.done());
    }

    #[test]
    fn dec_truncation_is_error() {
        let mut e = Enc::new();
        e.u32(5);
        let mut d = Dec::new(&e.buf);
        assert!(d.u64().is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&v)), v);
    }

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, u64::MAX, 1 << 63];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)), v);
    }

    #[test]
    fn f64_bytes_roundtrip() {
        let v = vec![0.25f64, -1e300];
        assert_eq!(bytes_to_f64s(&f64s_to_bytes(&v)), v);
    }

    fn xorshift_bytes(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 24) as u8
            })
            .collect()
    }

    #[test]
    fn lz_roundtrip_random_and_empty() {
        for n in [0usize, 1, 3, 4, 5, 127, 128, 129, 4096] {
            let data = xorshift_bytes(n as u64 + 7, n);
            let comp = lz_compress(&data);
            assert_eq!(lz_decompress(&comp, n).unwrap(), data, "n={n}");
        }
    }

    #[test]
    fn lz_crushes_repetitive_input() {
        // matches cap at 131 bytes / 3-byte token → ~43:1 on constant input
        let data = vec![42u8; 100_000];
        let comp = lz_compress(&data);
        assert!(comp.len() < data.len() / 40, "{} bytes", comp.len());
        assert_eq!(lz_decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn lz_overlapping_match_is_rle() {
        // "abcabcabc..." compresses via distance-3 overlapping matches
        let data: Vec<u8> = (0..3000).map(|i| b"abc"[i % 3]).collect();
        let comp = lz_compress(&data);
        assert!(comp.len() < 200, "{} bytes", comp.len());
        assert_eq!(lz_decompress(&comp, data.len()).unwrap(), data);
    }

    #[test]
    fn lz_rejects_corrupt_streams() {
        let data = xorshift_bytes(9, 256);
        let comp = lz_compress(&data);
        assert!(lz_decompress(&comp, 255).is_err()); // wrong raw_len
        assert!(lz_decompress(&comp[..comp.len() - 1], 256).is_err()); // truncated
        assert!(lz_decompress(&[0x85, 0xff, 0xff], 100).is_err()); // bad distance
    }

    #[test]
    fn shuffle_roundtrip_all_elem_sizes() {
        for es in [1usize, 2, 4, 8] {
            let data = xorshift_bytes(es as u64, 64 * es);
            assert_eq!(unshuffle(&shuffle(&data, es), es), data, "es={es}");
        }
    }

    #[test]
    fn shuffle_groups_byte_planes() {
        // elements 0x0100, 0x0200: low bytes first plane, high bytes second
        let data = [0x00, 0x01, 0x00, 0x02];
        assert_eq!(shuffle(&data, 2), vec![0x00, 0x00, 0x01, 0x02]);
    }

    #[test]
    fn delta_roundtrip() {
        let mut data = xorshift_bytes(3, 513);
        let orig = data.clone();
        delta_encode(&mut data);
        assert_ne!(data, orig);
        delta_decode(&mut data);
        assert_eq!(data, orig);
    }

    #[test]
    fn codec_roundtrip_every_variant() {
        let floats: Vec<f32> = (0..2048).map(|i| (i as f32 * 0.001).sin()).collect();
        let raw = f32s_to_bytes(&floats);
        for codec in [
            Codec::Raw,
            Codec::Lz,
            Codec::ShuffleLz,
            Codec::ShuffleDeltaLz,
        ] {
            let enc = codec.encode(&raw, 4);
            let dec = codec.decode(&enc, 4, raw.len()).unwrap();
            assert_eq!(dec, raw, "{codec:?}");
        }
    }

    #[test]
    fn shuffle_delta_lz_beats_plain_lz_on_smooth_f32() {
        // smooth field data: exponent bytes nearly constant → shuffle+delta
        // exposes runs plain byte-LZ cannot see
        let floats: Vec<f32> = (0..8192).map(|i| 1.0 + (i as f32 * 1e-4)).collect();
        let raw = f32s_to_bytes(&floats);
        let plain = Codec::Lz.encode(&raw, 4);
        let sdl = Codec::ShuffleDeltaLz.encode(&raw, 4);
        assert!(
            sdl.len() < plain.len() && sdl.len() * 2 < raw.len(),
            "sdl {} plain {} raw {}",
            sdl.len(),
            plain.len(),
            raw.len()
        );
    }

    #[test]
    fn encode_chunk_filter_mask_semantics() {
        // compressible → Some(smaller); incompressible → None; checksum is
        // always over the raw bytes
        let smooth = f32s_to_bytes(&(0..1024).map(|i| 1.0 + i as f32 * 1e-4).collect::<Vec<_>>());
        let (enc, ck) = encode_chunk(Codec::ShuffleDeltaLz, &smooth, 4);
        assert!(enc.as_ref().unwrap().len() < smooth.len());
        assert_eq!(ck, checksum32(&smooth));
        let noise = xorshift_bytes(5, 1024);
        let (enc, ck) = encode_chunk(Codec::Lz, &noise, 1);
        assert!(enc.is_none());
        assert_eq!(ck, checksum32(&noise));
    }

    #[test]
    fn checksum_distinguishes_buffers() {
        let a = checksum32(b"hello");
        let b = checksum32(b"hellp");
        assert_ne!(a, b);
        assert_eq!(checksum32(b""), 0x811c_9dc5);
    }

    #[test]
    fn codec_codes_roundtrip() {
        for codec in [
            Codec::Raw,
            Codec::Lz,
            Codec::ShuffleLz,
            Codec::ShuffleDeltaLz,
        ] {
            assert_eq!(Codec::from_code(codec.code()).unwrap(), codec);
        }
        assert!(Codec::from_code(99).is_err());
    }
}
