//! Little-endian binary encoding helpers for the h5lite metadata footer,
//! plus the chunk compression pipeline (codec v2).
//!
//! Everything is explicitly little-endian with an endianness tag in the
//! superblock, mirroring HDF5's self-describing storage model: a file
//! written here can be decoded on any architecture.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.f64(*v);
        }
    }

    pub fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.u64(*v);
        }
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "h5lite: truncated metadata (need {} bytes at {}, have {})",
                n,
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Convert `f32` slice views to/from raw little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn u64s_to_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn f64s_to_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f64s(b: &[u8]) -> Vec<f64> {
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

// ---------------------------------------------------------------------------
// chunk compression (format v2, codec v2 pipeline)
// ---------------------------------------------------------------------------
//
// The per-chunk filter pipeline of the v2 chunked layout, mirroring HDF5's
// filter stack (shuffle → deflate) with a zstd-class two-stage compressor:
//
// * **LZ** — a byte-oriented LZ77 with a 64 KiB window. Token stream:
//   a control byte `c < 0x80` introduces a literal run of `c + 1` bytes;
//   `c >= 0x80` is a match of length `(c & 0x7f) + 4` (4..=131) followed by a
//   little-endian u16 distance (1..=65535). Overlapping copies are legal
//   (RLE through distance < length). Since codec v2 the encoder is a
//   greedy **hash-chain matcher** ([`lz_compress_chain`], depth
//   [`LZ_CHAIN_DEPTH`], one-step-lazy): it emits the *same* token stream as
//   the original single-candidate encoder ([`lz_compress`], kept as the
//   calibration baseline), so every pre-codec-v2 file decodes unchanged.
// * **shuffle** — HDF5's byte shuffle: transpose an array of n-byte elements
//   into n byte planes, so the slowly-varying high bytes of f32/f64/u64
//   values become long near-constant runs.
// * **delta** — byte-wise wrapping first difference applied after the
//   shuffle; near-constant planes become runs of zeros, which LZ collapses.
// * **entropy** — an optional second stage over the LZ token stream, with
//   two selectable backends behind one frame header:
//   - **range coder** ([`Entropy::RangeCoder`]) — an adaptive binary range
//     coder (LZMA-style, 11-bit probabilities) with separate order-0
//     bit-tree models for control bytes, distance bytes and literals
//     (literals additionally contexted on the previous literal's top
//     [`LIT_PREV_BITS`] bits — the zstd-style literal/length/offset stream
//     split). Best ratio; per-bit adaptive updates make it the most
//     expensive stage per byte.
//   - **tANS** ([`Entropy::Tans`]) — a static table-driven asymmetric
//     numeral system (FSE-style) over the same four token streams, traded
//     for decode speed: one table lookup plus a bulk bit read per symbol
//     instead of eight adaptive binary decisions per byte. See the frame
//     layout below.
//   Either way, byte planes whose post-filter Shannon entropy is ≥ 7.2
//   bits (the incompressible low-mantissa planes of turbulent f32 fields)
//   **bypass** the coder into a raw side buffer, so neither backend
//   wastes time (or expands) on white noise.
//
// ## Entropy frame layout
//
// ```text
// [lz_len u32] [plane_mask u8] [side_len u32] [side bytes…] [payload…]
// ```
//
// `lz_len` is the size of the LZ token stream the entropy stage
// reproduces; `plane_mask` bit `p` set means literals whose reconstructed
// position falls in byte plane `p` live verbatim in the side buffer; the
// payload is the backend's output over everything else (the chunk's codec
// byte says which backend). The decoder walks tokens, pulling each
// literal from the side buffer or the coder as the mask dictates, then
// runs the normal LZ + filter inversion.
//
// ## tANS payload layout
//
// ```text
// [x0 u16] [x1 u16] [stream0 table] … [stream3 table] [bitstream…]
// ```
//
// The four streams are ctrl, dist-lo, dist-hi, literal (in that order).
// `x0`/`x1` are the encoder's final states minus `L` — the decoder's
// *start* states for the two interleaved decode lanes (coded symbols
// alternate lanes by their coded-symbol index). Each table section is one
// flag byte: `0` = stream absent, `2` = stream stored **raw** (its
// symbols ride the bitstream as plain 8-bit values — chosen whenever the
// table plus coded bits would cost more, e.g. the near-uniform dist-lo
// stream), `1` = coded, followed by a 32-byte symbol-presence bitmap and
// the packed 12-bit `frequency - 1` values of the present symbols
// (normalized to sum exactly `L` = 4096). The bitstream is MSB-first;
// symbols were encoded in reverse so the decoder reads strictly forward.
// Decoding must return both lanes to the encoder's start state (0) — a
// cheap whole-frame integrity check on top of the chunk checksum.

/// Byte-level pre-filter of a chunk pipeline (applied before the LZ core).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Filter {
    /// No pre-filter: the LZ core sees the raw little-endian bytes.
    None,
    /// HDF5-style byte shuffle by element size.
    Shuffle,
    /// Byte shuffle, then byte-wise wrapping delta — the default for the
    /// heavy f32 cell-data datasets.
    ShuffleDelta,
}

/// Entropy stage of a chunk pipeline (applied after the LZ core).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Entropy {
    /// No entropy stage: the LZ token stream is stored as-is.
    None,
    /// Adaptive binary range coder (LZMA-style). Best ratio, slowest.
    RangeCoder,
    /// Static table-driven ANS. Slightly worse ratio, much faster decode.
    Tans,
}

/// Per-chunk codec of a v2 chunked dataset (stored in the metadata
/// footer): either `Raw` (no pipeline at all) or a composable
/// `filter → LZ → entropy` pipeline descriptor. The legacy flat names
/// survive as associated constants ([`CodecSpec::LZ`],
/// [`CodecSpec::SHUFFLE_DELTA_LZ_RC`], …) so call sites read like the old
/// enum while tests and sweeps can iterate the two axes independently.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CodecSpec {
    /// No transformation: chunk extents hold raw little-endian bytes.
    Raw,
    /// The `filter → LZ core → entropy` pipeline.
    Pipe { filter: Filter, entropy: Entropy },
}

/// The historical name for the per-chunk codec descriptor; everything
/// downstream (chunk index, dataset layout, machine model) uses it.
pub type Codec = CodecSpec;

/// All codec variants in `code()` order, for sweeps in tests and benches.
pub const ALL_CODECS: [Codec; 10] = [
    CodecSpec::Raw,
    CodecSpec::LZ,
    CodecSpec::SHUFFLE_LZ,
    CodecSpec::SHUFFLE_DELTA_LZ,
    CodecSpec::LZ_RC,
    CodecSpec::SHUFFLE_LZ_RC,
    CodecSpec::SHUFFLE_DELTA_LZ_RC,
    CodecSpec::LZ_TANS,
    CodecSpec::SHUFFLE_LZ_TANS,
    CodecSpec::SHUFFLE_DELTA_LZ_TANS,
];

impl CodecSpec {
    /// LZ byte compression only (legacy `Lz`, code 1).
    pub const LZ: Codec = CodecSpec::Pipe {
        filter: Filter::None,
        entropy: Entropy::None,
    };
    /// Byte shuffle, then LZ (legacy `ShuffleLz`, code 2).
    pub const SHUFFLE_LZ: Codec = CodecSpec::Pipe {
        filter: Filter::Shuffle,
        entropy: Entropy::None,
    };
    /// Shuffle, delta, then LZ (legacy `ShuffleDeltaLz`, code 3).
    pub const SHUFFLE_DELTA_LZ: Codec = CodecSpec::Pipe {
        filter: Filter::ShuffleDelta,
        entropy: Entropy::None,
    };
    /// LZ, then the range coder (legacy `LzEntropy`, code 4).
    pub const LZ_RC: Codec = CodecSpec::Pipe {
        filter: Filter::None,
        entropy: Entropy::RangeCoder,
    };
    /// Shuffle, LZ, range coder (legacy `ShuffleLzEntropy`, code 5).
    pub const SHUFFLE_LZ_RC: Codec = CodecSpec::Pipe {
        filter: Filter::Shuffle,
        entropy: Entropy::RangeCoder,
    };
    /// Shuffle, delta, LZ, range coder (legacy `ShuffleDeltaLzEntropy`,
    /// code 6) — the best-ratio pipeline for cell data.
    pub const SHUFFLE_DELTA_LZ_RC: Codec = CodecSpec::Pipe {
        filter: Filter::ShuffleDelta,
        entropy: Entropy::RangeCoder,
    };
    /// LZ, then the tANS stage (code 7).
    pub const LZ_TANS: Codec = CodecSpec::Pipe {
        filter: Filter::None,
        entropy: Entropy::Tans,
    };
    /// Shuffle, LZ, tANS (code 8).
    pub const SHUFFLE_LZ_TANS: Codec = CodecSpec::Pipe {
        filter: Filter::Shuffle,
        entropy: Entropy::Tans,
    };
    /// Shuffle, delta, LZ, tANS (code 9) — what the adaptive selector
    /// stores for cell-data chunks where the tANS frame lands within
    /// [`TANS_PREFER_PCT`] of the range coder's.
    pub const SHUFFLE_DELTA_LZ_TANS: Codec = CodecSpec::Pipe {
        filter: Filter::ShuffleDelta,
        entropy: Entropy::Tans,
    };

    /// The byte stored in the metadata footer. Values 0–6 are
    /// bit-compatible with the pre-tANS flat enum; 7–9 are the tANS
    /// family.
    pub fn code(self) -> u8 {
        match self {
            CodecSpec::Raw => 0,
            CodecSpec::Pipe { filter, entropy } => {
                let f = match filter {
                    Filter::None => 0,
                    Filter::Shuffle => 1,
                    Filter::ShuffleDelta => 2,
                };
                let e = match entropy {
                    Entropy::None => 0,
                    Entropy::RangeCoder => 1,
                    Entropy::Tans => 2,
                };
                1 + f + 3 * e
            }
        }
    }

    pub fn from_code(c: u8) -> Result<Codec> {
        if c == 0 {
            return Ok(CodecSpec::Raw);
        }
        if c > 9 {
            bail!("h5lite: unknown codec code {c}");
        }
        let filter = match (c - 1) % 3 {
            0 => Filter::None,
            1 => Filter::Shuffle,
            _ => Filter::ShuffleDelta,
        };
        let entropy = match (c - 1) / 3 {
            0 => Entropy::None,
            1 => Entropy::RangeCoder,
            _ => Entropy::Tans,
        };
        Ok(CodecSpec::Pipe { filter, entropy })
    }

    /// Short stable label for benches and reports.
    pub fn name(self) -> &'static str {
        match self {
            CodecSpec::Raw => "raw",
            CodecSpec::Pipe { filter, entropy } => match (filter, entropy) {
                (Filter::None, Entropy::None) => "lz",
                (Filter::Shuffle, Entropy::None) => "shuffle+lz",
                (Filter::ShuffleDelta, Entropy::None) => "shuffle+delta+lz",
                (Filter::None, Entropy::RangeCoder) => "lz+rc",
                (Filter::Shuffle, Entropy::RangeCoder) => "shuffle+lz+rc",
                (Filter::ShuffleDelta, Entropy::RangeCoder) => "shuffle+delta+lz+rc",
                (Filter::None, Entropy::Tans) => "lz+tans",
                (Filter::Shuffle, Entropy::Tans) => "shuffle+lz+tans",
                (Filter::ShuffleDelta, Entropy::Tans) => "shuffle+delta+lz+tans",
            },
        }
    }

    /// This pipeline's pre-filter (`Raw` has no pipeline: `Filter::None`).
    pub fn filter_stage(self) -> Filter {
        match self {
            CodecSpec::Raw => Filter::None,
            CodecSpec::Pipe { filter, .. } => filter,
        }
    }

    /// This pipeline's entropy backend (`Raw` has none).
    pub fn entropy(self) -> Entropy {
        match self {
            CodecSpec::Raw => Entropy::None,
            CodecSpec::Pipe { entropy, .. } => entropy,
        }
    }

    /// Does this pipeline end in an entropy stage (either backend)?
    pub fn has_entropy(self) -> bool {
        self.entropy() != Entropy::None
    }

    /// The same filter family with the given entropy backend (`Raw` has no
    /// token stream to entropy-code and maps to itself).
    pub fn with_entropy(self, entropy: Entropy) -> Codec {
        match self {
            CodecSpec::Raw => CodecSpec::Raw,
            CodecSpec::Pipe { filter, .. } => CodecSpec::Pipe { filter, entropy },
        }
    }

    /// The same filter family without the entropy stage.
    pub fn without_entropy(self) -> Codec {
        self.with_entropy(Entropy::None)
    }

    /// Apply this pipeline's byte filters (shuffle / delta) only.
    fn filter(self, raw: &[u8], elem_size: usize) -> Vec<u8> {
        match self.filter_stage() {
            Filter::None => raw.to_vec(),
            Filter::Shuffle => shuffle(raw, elem_size),
            Filter::ShuffleDelta => {
                let mut s = shuffle(raw, elem_size);
                delta_encode(&mut s);
                s
            }
        }
    }

    /// Invert [`CodecSpec::filter`].
    fn unfilter(self, mut filtered: Vec<u8>, elem_size: usize) -> Vec<u8> {
        match self.filter_stage() {
            Filter::None => filtered,
            Filter::Shuffle => unshuffle(&filtered, elem_size),
            Filter::ShuffleDelta => {
                delta_decode(&mut filtered);
                unshuffle(&filtered, elem_size)
            }
        }
    }

    /// Apply the filter pipeline to one raw chunk. `elem_size` is the
    /// dataset's element width (the shuffle stride).
    pub fn encode(self, raw: &[u8], elem_size: usize) -> Vec<u8> {
        if self == CodecSpec::Raw {
            return raw.to_vec();
        }
        let filtered = self.filter(raw, elem_size);
        let lz = lz_compress_chain(&filtered, LZ_CHAIN_DEPTH);
        match self.entropy() {
            Entropy::None => lz,
            Entropy::RangeCoder => {
                let mask = bypass_mask(&filtered, elem_size, raw.len());
                entropy_encode_tokens(&lz, elem_size, raw.len(), mask)
            }
            Entropy::Tans => {
                let mask = bypass_mask(&filtered, elem_size, raw.len());
                tans_encode_tokens(&lz, elem_size, raw.len(), mask)
            }
        }
    }

    /// Invert [`CodecSpec::encode`]. `raw_len` is the expected decoded
    /// length (known from the chunk index); a mismatch is a hard error.
    pub fn decode(self, stored: &[u8], elem_size: usize, raw_len: usize) -> Result<Vec<u8>> {
        let out = if self == CodecSpec::Raw {
            stored.to_vec()
        } else {
            let lz_stream;
            let tokens = match self.entropy() {
                Entropy::None => stored,
                Entropy::RangeCoder => {
                    lz_stream = entropy_decode_tokens(stored, elem_size, raw_len)?;
                    &lz_stream[..]
                }
                Entropy::Tans => {
                    lz_stream = tans_decode_tokens(stored, elem_size, raw_len)?;
                    &lz_stream[..]
                }
            };
            // the filters are length-preserving, so the filtered buffer the
            // LZ stream reproduces is exactly raw_len bytes
            let filtered = lz_decompress(tokens, raw_len)?;
            self.unfilter(filtered, elem_size)
        };
        if out.len() != raw_len {
            bail!(
                "h5lite: chunk decoded to {} bytes, expected {raw_len}",
                out.len()
            );
        }
        Ok(out)
    }
}

/// Run the codec over one raw chunk and decide what to store: `Some(enc)`
/// when the codec actually shrinks it, `None` when the raw bytes go to
/// disk unfiltered (HDF5's per-chunk filter mask), plus the checksum of
/// the raw bytes. The fixed-codec helper behind
/// [`encode_chunk_adaptive`] — kept public for calibration baselines and
/// sweeps that must pin one variant.
pub fn encode_chunk(codec: Codec, raw: &[u8], elem_size: usize) -> (Option<Vec<u8>>, u32) {
    let enc = codec.encode(raw, elem_size);
    let checksum = checksum32(raw);
    if enc.len() < raw.len() {
        (Some(enc), checksum)
    } else {
        (None, checksum)
    }
}

/// Outcome of the adaptive per-chunk encoder: what to store (`None` = the
/// raw bytes), which codec produced it (`None` = stored raw — HDF5's
/// per-chunk filter mask, recorded in the chunk index), and the checksum
/// over the raw bytes.
pub struct ChunkEncoding {
    pub stored: Option<Vec<u8>>,
    pub codec: Option<Codec>,
    pub checksum: u32,
}

impl ChunkEncoding {
    /// The bytes that hit the disk for this chunk.
    pub fn stored_or<'a>(&'a self, raw: &'a [u8]) -> &'a [u8] {
        self.stored.as_deref().unwrap_or(raw)
    }
}

/// Adaptive per-chunk codec selection (codec v2): run `base`'s filters and
/// the hash-chain LZ once, then decide between `Store` (raw bytes), the
/// LZ stream, the LZ + range-coder frame and the LZ + tANS frame. Each
/// entropy backend is gated by a cheap cost estimate before its real
/// encoding pass: the range coder runs a **trial** over the first
/// [`TRIAL_RC_INPUT`] coder-input bytes and extrapolates, while tANS —
/// whose frame size is a near-exact function of the token histograms —
/// is predicted from one histogram walk. Incompressible chunks never pay
/// a full entropy stage. When both backends win over the LZ stream, tANS
/// is preferred while its frame stays within [`TANS_PREFER_PCT`] percent
/// of the range coder's: decode speed counts double now that the fan-out
/// server amortises decodes across many clients. Both chunk writers —
/// [`crate::h5lite::H5File`]'s read-modify-write path and the pario
/// aggregators — share this, so the store-smaller-of / checksum-over-raw /
/// per-chunk-codec-byte format invariants cannot drift apart.
pub fn encode_chunk_adaptive(base: Codec, raw: &[u8], elem_size: usize) -> ChunkEncoding {
    let checksum = checksum32(raw);
    if base == Codec::Raw || raw.is_empty() {
        return ChunkEncoding {
            stored: None,
            codec: None,
            checksum,
        };
    }
    let lz_codec = base.without_entropy();
    let filtered = lz_codec.filter(raw, elem_size);
    let lz = lz_compress_chain(&filtered, LZ_CHAIN_DEPTH);
    let best_len = raw.len().min(lz.len());
    let mask = bypass_mask(&filtered, elem_size, raw.len());
    let (rc_total, side_total) = rc_input_total(&lz, elem_size, raw.len(), mask);
    // range-coder candidate: predict the frame size from a bounded prefix
    // run, then encode for real only when the trial promises a win
    let mut rc_frame: Option<Vec<u8>> = None;
    if rc_total > 0 && rc_total <= TRIAL_RC_INPUT {
        // the whole stream fits the trial budget: code it once and use the
        // result directly — same acceptance gate as the extrapolated path
        // (predicted == exact frame size here), no second encoding pass
        let (rc, side, _) = entropy_encode_inner(&lz, elem_size, raw.len(), mask, None);
        let frame_len = ENTROPY_HEADER_LEN + side.len() + rc.len();
        if frame_len < best_len * 99 / 100 {
            rc_frame = Some(entropy_frame(lz.len(), mask, &side, &rc));
        }
    } else if rc_total > 0 {
        let (trial_out, trial_in) =
            entropy_trial(&lz, elem_size, raw.len(), mask, TRIAL_RC_INPUT);
        if trial_in > 0 {
            let predicted =
                ENTROPY_HEADER_LEN + side_total + trial_out * rc_total / trial_in;
            if predicted < best_len * 99 / 100 {
                let frame = entropy_encode_tokens(&lz, elem_size, raw.len(), mask);
                if frame.len() < best_len {
                    rc_frame = Some(frame);
                }
            }
        }
    }
    // tANS candidate: the histogram walk prices tables and payload almost
    // exactly, so the real encoding pass runs only on predicted winners
    let mut tans_frame: Option<Vec<u8>> = None;
    if rc_total > 0 {
        let predicted = tans_predict_len(&lz, elem_size, raw.len(), mask);
        if predicted < best_len * 99 / 100 {
            let frame = tans_encode_tokens(&lz, elem_size, raw.len(), mask);
            if frame.len() < best_len {
                tans_frame = Some(frame);
            }
        }
    }
    let entropy_pick = match (rc_frame, tans_frame) {
        (Some(rc), Some(tans)) => {
            if tans.len() * 100 <= rc.len() * (100 + TANS_PREFER_PCT) {
                Some((tans, Entropy::Tans))
            } else {
                Some((rc, Entropy::RangeCoder))
            }
        }
        (Some(rc), None) => Some((rc, Entropy::RangeCoder)),
        (None, Some(tans)) => Some((tans, Entropy::Tans)),
        (None, None) => None,
    };
    if let Some((frame, backend)) = entropy_pick {
        return ChunkEncoding {
            stored: Some(frame),
            codec: Some(lz_codec.with_entropy(backend)),
            checksum,
        };
    }
    if lz.len() < raw.len() {
        ChunkEncoding {
            stored: Some(lz),
            codec: Some(lz_codec),
            checksum,
        }
    } else {
        ChunkEncoding {
            stored: None,
            codec: None,
            checksum,
        }
    }
}

/// Encode the per-chunk codec byte of the chunk index: `0` = stored raw,
/// `1` = the dataset's declared codec (the only non-zero value pre-codec-v2
/// files carry), `2 + code` = an explicitly recorded codec (what the
/// adaptive selector writes when it picks a different pipeline than the
/// dataset declares — spans `2..=11` now that codes 7–9 are the tANS
/// family).
pub fn chunk_codec_to_byte(ds_codec: Codec, applied: Option<Codec>) -> u8 {
    match applied {
        None => 0,
        Some(c) if c == ds_codec => 1,
        Some(c) => 2 + c.code(),
    }
}

/// Invert [`chunk_codec_to_byte`].
pub fn chunk_codec_from_byte(ds_codec: Codec, b: u8) -> Result<Option<Codec>> {
    Ok(match b {
        0 => None,
        1 => Some(ds_codec),
        b => Some(Codec::from_code(b - 2)?),
    })
}

/// FNV-1a 32-bit checksum over a raw chunk (stored in the chunk index;
/// verified on every chunk read).
pub fn checksum32(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// HDF5-style byte shuffle: `[e0b0 e0b1 .. | e1b0 e1b1 ..]` becomes
/// `[e0b0 e1b0 .. | e0b1 e1b1 ..]`. A trailing partial element (never
/// produced by whole-row chunks) is appended unshuffled.
pub fn shuffle(data: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 || data.len() < elem_size {
        return data.to_vec();
    }
    let n = data.len() / elem_size;
    let body = n * elem_size;
    let mut out = Vec::with_capacity(data.len());
    for plane in 0..elem_size {
        for e in 0..n {
            out.push(data[e * elem_size + plane]);
        }
    }
    out.extend_from_slice(&data[body..]);
    out
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], elem_size: usize) -> Vec<u8> {
    if elem_size <= 1 || data.len() < elem_size {
        return data.to_vec();
    }
    let n = data.len() / elem_size;
    let body = n * elem_size;
    let mut out = vec![0u8; data.len()];
    for plane in 0..elem_size {
        for e in 0..n {
            out[e * elem_size + plane] = data[plane * n + e];
        }
    }
    out[body..].copy_from_slice(&data[body..]);
    out
}

/// In-place byte-wise wrapping first difference.
pub fn delta_encode(data: &mut [u8]) {
    let mut prev = 0u8;
    for b in data.iter_mut() {
        let cur = *b;
        *b = cur.wrapping_sub(prev);
        prev = cur;
    }
}

/// Inverse of [`delta_encode`].
pub fn delta_decode(data: &mut [u8]) {
    let mut prev = 0u8;
    for b in data.iter_mut() {
        prev = prev.wrapping_add(*b);
        *b = prev;
    }
}

const LZ_MIN_MATCH: usize = 4;
const LZ_MAX_MATCH: usize = 0x7f + LZ_MIN_MATCH;
const LZ_MAX_DIST: usize = 0xffff;
const LZ_HASH_BITS: u32 = 15;

/// Hash-chain candidates examined per position by the codec-v2 match
/// finder (the lazy peek at the next position runs a second walk).
pub const LZ_CHAIN_DEPTH: usize = 16;

#[inline]
fn lz_hash(data: &[u8], pos: usize) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(2654435761) >> (32 - LZ_HASH_BITS)) as usize
}

fn lz_flush_literals(out: &mut Vec<u8>, data: &[u8], from: usize, to: usize) {
    let mut s = from;
    while s < to {
        let run = (to - s).min(128);
        out.push((run - 1) as u8);
        out.extend_from_slice(&data[s..s + run]);
        s += run;
    }
}

/// Compress `data` with the LZ token stream described in the module docs,
/// single hash-table candidate per position — the PR-1 encoder, kept
/// verbatim as the calibration baseline the codec-v2 benches compare
/// against. Worst case (incompressible input) expands by `len / 128 + 1`
/// control bytes — the chunk writer stores whichever of raw/compressed is
/// smaller.
pub fn lz_compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    let mut table = vec![0u32; 1 << LZ_HASH_BITS]; // position + 1; 0 = empty
    let mut lit_start = 0usize;
    let mut pos = 0usize;

    while pos + LZ_MIN_MATCH <= data.len() {
        let h = lz_hash(data, pos);
        let cand = table[h] as usize;
        table[h] = (pos + 1) as u32;
        let mut match_len = 0usize;
        if cand > 0 {
            let cpos = cand - 1;
            let dist = pos - cpos;
            if dist >= 1 && dist <= LZ_MAX_DIST {
                let max = (data.len() - pos).min(LZ_MAX_MATCH);
                let mut l = 0usize;
                while l < max && data[cpos + l] == data[pos + l] {
                    l += 1;
                }
                if l >= LZ_MIN_MATCH {
                    match_len = l;
                }
            }
        }
        if match_len > 0 {
            lz_flush_literals(&mut out, data, lit_start, pos);
            let dist = pos - (cand - 1);
            out.push(0x80 | (match_len - LZ_MIN_MATCH) as u8);
            out.extend_from_slice(&(dist as u16).to_le_bytes());
            // seed the table through the matched region (sparse: every
            // other position keeps the encoder O(n) on repetitive input)
            let end = pos + match_len;
            let mut p = pos + 1;
            while p + LZ_MIN_MATCH <= data.len() && p < end {
                table[lz_hash(data, p)] = (p + 1) as u32;
                p += 2;
            }
            pos = end;
            lit_start = pos;
        } else {
            pos += 1;
        }
    }
    lz_flush_literals(&mut out, data, lit_start, data.len());
    out
}

/// Hash-chain state of [`lz_compress_chain`]: `head[hash]` is the most
/// recent position + 1 with that hash, `prev[pos]` the previous one.
struct LzChain {
    head: Vec<u32>,
    prev: Vec<u32>,
}

impl LzChain {
    fn new(n: usize) -> LzChain {
        LzChain {
            head: vec![0u32; 1 << LZ_HASH_BITS],
            prev: vec![0u32; n],
        }
    }

    #[inline]
    fn insert(&mut self, data: &[u8], p: usize) {
        let h = lz_hash(data, p);
        self.prev[p] = self.head[h];
        self.head[h] = (p + 1) as u32;
    }

    /// Longest match for `p` among up to `depth` chain candidates inside
    /// the window; nearest distance wins ties (the chain is ordered most
    /// recent first and only a strictly longer match displaces the best).
    fn find(&self, data: &[u8], p: usize, depth: usize) -> (usize, usize) {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        let mut cand = self.head[lz_hash(data, p)] as usize;
        let mut tries = depth;
        let max = (data.len() - p).min(LZ_MAX_MATCH);
        while cand > 0 && tries > 0 {
            let cpos = cand - 1;
            let dist = p - cpos;
            if dist > LZ_MAX_DIST {
                break; // older candidates are only farther away
            }
            let mut l = 0usize;
            while l < max && data[cpos + l] == data[p + l] {
                l += 1;
            }
            if l > best_len {
                best_len = l;
                best_dist = dist;
                if l >= max {
                    break;
                }
            }
            cand = self.prev[cpos] as usize;
            tries -= 1;
        }
        (best_len, best_dist)
    }
}

/// The codec-v2 match finder: hash-chain search (up to `depth` candidates
/// per position, 64 KiB window) with a one-step-lazy heuristic — when the
/// next position holds a strictly longer match, the current byte joins the
/// literal run instead. Emits exactly the token stream [`lz_decompress`]
/// reads, so files written by [`lz_compress`] and by this encoder are
/// indistinguishable to every reader.
pub fn lz_compress_chain(data: &[u8], depth: usize) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    let mut chain = LzChain::new(n);
    let mut lit_start = 0usize;
    let mut pos = 0usize;
    // match the lazy peek already found for the current position — the
    // chain state is identical (nothing was inserted in between), so on a
    // deferral the next iteration reuses it instead of re-walking
    let mut pending: Option<(usize, usize)> = None;
    while pos + LZ_MIN_MATCH <= n {
        let (blen, bdist) = match pending.take() {
            Some(found) => found,
            None => chain.find(data, pos, depth),
        };
        chain.insert(data, pos);
        if blen < LZ_MIN_MATCH {
            pos += 1;
            continue;
        }
        if blen < LZ_MAX_MATCH && pos + 1 + LZ_MIN_MATCH <= n {
            let peek = chain.find(data, pos + 1, depth);
            if peek.0 > blen {
                pending = Some(peek);
                pos += 1; // lazy: defer, the longer match starts next byte
                continue;
            }
        }
        lz_flush_literals(&mut out, data, lit_start, pos);
        out.push(0x80 | (blen - LZ_MIN_MATCH) as u8);
        out.extend_from_slice(&(bdist as u16).to_le_bytes());
        let end = pos + blen;
        let mut p = pos + 1;
        while p < end && p + LZ_MIN_MATCH <= n {
            chain.insert(data, p);
            p += 1;
        }
        pos = end;
        lit_start = pos;
    }
    lz_flush_literals(&mut out, data, lit_start, n);
    out
}

/// Decompress an LZ token stream into exactly `raw_len` bytes.
pub fn lz_decompress(comp: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut pos = 0usize;
    while pos < comp.len() {
        let ctrl = comp[pos];
        pos += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            if pos + run > comp.len() {
                bail!("h5lite: truncated LZ literal run");
            }
            out.extend_from_slice(&comp[pos..pos + run]);
            pos += run;
        } else {
            let len = (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
            if pos + 2 > comp.len() {
                bail!("h5lite: truncated LZ match token");
            }
            let dist = u16::from_le_bytes([comp[pos], comp[pos + 1]]) as usize;
            pos += 2;
            if dist == 0 || dist > out.len() {
                bail!("h5lite: LZ match distance {dist} out of range");
            }
            let start = out.len() - dist;
            for i in 0..len {
                let b = out[start + i];
                out.push(b); // overlapping copies are byte-by-byte
            }
        }
        if out.len() > raw_len {
            bail!("h5lite: LZ stream overruns chunk ({} > {raw_len})", out.len());
        }
    }
    if out.len() != raw_len {
        bail!("h5lite: LZ stream yielded {} of {raw_len} bytes", out.len());
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// entropy stage: adaptive binary range coder over the LZ token stream
// ---------------------------------------------------------------------------

const RC_TOP: u32 = 1 << 24;
const PROB_BITS: u32 = 11;
const PROB_INIT: u16 = 1 << (PROB_BITS - 1);
const PROB_MOVE: u32 = 5;
/// Previous-literal context bits of the literal model.
const LIT_PREV_BITS: u32 = 3;
/// A byte plane bypasses the range coder when its post-filter Shannon
/// entropy estimate reaches this many bits per byte (white noise is 8.0;
/// structured planes of fluid fields sit well below 7).
const BYPASS_ENTROPY_BITS: f64 = 7.2;
/// Coder-input bytes the adaptive trial runs before extrapolating.
const TRIAL_RC_INPUT: usize = 4096;
/// `lz_len u32 | plane_mask u8 | side_len u32`.
const ENTROPY_HEADER_LEN: usize = 9;

/// LZMA-style carry-aware range encoder.
struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RangeEncoder {
    fn new() -> RangeEncoder {
        RangeEncoder {
            low: 0,
            range: u32::MAX,
            cache: 0,
            cache_size: 1,
            out: Vec::new(),
        }
    }

    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut b = self.cache;
            loop {
                self.out.push(b.wrapping_add(carry));
                b = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (((self.low as u32) << 8) as u64) & 0xFFFF_FFFF;
    }

    #[inline]
    fn encode_bit(&mut self, prob: &mut u16, bit: u32) {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        if bit == 0 {
            self.range = bound;
            *prob += ((1u16 << PROB_BITS) - *prob) >> PROB_MOVE;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            *prob -= *prob >> PROB_MOVE;
        }
        while self.range < RC_TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

/// Matching range decoder; refuses to read past the stream end.
struct RangeDecoder<'a> {
    buf: &'a [u8],
    pos: usize,
    range: u32,
    code: u32,
}

impl<'a> RangeDecoder<'a> {
    fn new(buf: &'a [u8]) -> Result<RangeDecoder<'a>> {
        let mut d = RangeDecoder {
            buf,
            pos: 0,
            range: u32::MAX,
            code: 0,
        };
        for _ in 0..5 {
            let b = d.next_byte()?;
            d.code = (d.code << 8) | b as u32;
        }
        Ok(d)
    }

    #[inline]
    fn next_byte(&mut self) -> Result<u8> {
        if self.pos >= self.buf.len() {
            bail!("h5lite: truncated range-coder stream");
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(b)
    }

    #[inline]
    fn decode_bit(&mut self, prob: &mut u16) -> Result<u32> {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        let bit = if self.code < bound {
            self.range = bound;
            *prob += ((1u16 << PROB_BITS) - *prob) >> PROB_MOVE;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            *prob -= *prob >> PROB_MOVE;
            1
        };
        while self.range < RC_TOP {
            self.range <<= 8;
            let b = self.next_byte()?;
            self.code = (self.code << 8) | b as u32;
        }
        Ok(bit)
    }
}

/// Adaptive bit-tree models of the token streams: control bytes, distance
/// bytes, and literals contexted on the previous literal's top bits.
struct TokenModels {
    ctrl: [u16; 256],
    dlo: [u16; 256],
    dhi: [u16; 256],
    lit: [[u16; 256]; 1 << LIT_PREV_BITS],
}

impl TokenModels {
    fn new() -> TokenModels {
        TokenModels {
            ctrl: [PROB_INIT; 256],
            dlo: [PROB_INIT; 256],
            dhi: [PROB_INIT; 256],
            lit: [[PROB_INIT; 256]; 1 << LIT_PREV_BITS],
        }
    }
}

#[inline]
fn rc_encode_byte(enc: &mut RangeEncoder, probs: &mut [u16; 256], b: u8) {
    let mut ctx = 1usize;
    for i in (0..8).rev() {
        let bit = ((b >> i) & 1) as u32;
        enc.encode_bit(&mut probs[ctx], bit);
        ctx = (ctx << 1) | bit as usize;
    }
}

#[inline]
fn rc_decode_byte(dec: &mut RangeDecoder, probs: &mut [u16; 256]) -> Result<u8> {
    let mut ctx = 1usize;
    for _ in 0..8 {
        let bit = dec.decode_bit(&mut probs[ctx])?;
        ctx = (ctx << 1) | bit as usize;
    }
    Ok((ctx & 0xFF) as u8)
}

/// Byte plane of position `pos` in a shuffled buffer of `raw_len` bytes
/// with `elem_size`-byte elements (the trailing unshuffled partial element
/// folds into the last plane).
#[inline]
fn plane_of(pos: usize, plane_n: usize, es: usize) -> usize {
    (pos / plane_n).min(es - 1)
}

/// Per-plane bypass mask: bit `p` set means plane `p`'s post-filter bytes
/// are high-entropy (≥ [`BYPASS_ENTROPY_BITS`] bits by Shannon estimate)
/// and go to the raw side buffer instead of the range coder.
pub fn bypass_mask(filtered: &[u8], elem_size: usize, raw_len: usize) -> u8 {
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut hists = vec![[0u32; 256]; es];
    for (pos, &b) in filtered.iter().enumerate() {
        hists[plane_of(pos, plane_n, es)][b as usize] += 1;
    }
    let mut mask = 0u8;
    for (p, h) in hists.iter().enumerate() {
        let n: u64 = h.iter().map(|&c| c as u64).sum();
        if n == 0 {
            continue;
        }
        let mut e = 0.0f64;
        for &c in h.iter() {
            if c > 0 {
                let pr = c as f64 / n as f64;
                e -= pr * pr.log2();
            }
        }
        if e >= BYPASS_ENTROPY_BITS {
            mask |= 1 << p;
        }
    }
    mask
}

/// Exact coder-input and side-buffer byte counts of the full token stream
/// under `mask` — the cheap walk the adaptive trial extrapolates over.
fn rc_input_total(lz: &[u8], elem_size: usize, raw_len: usize, mask: u8) -> (usize, usize) {
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut pos = 0usize;
    let mut out_pos = 0usize;
    let mut rc_in = 0usize;
    let mut side = 0usize;
    while pos < lz.len() {
        let ctrl = lz[pos];
        rc_in += 1;
        pos += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            for _ in 0..run {
                if (mask >> plane_of(out_pos, plane_n, es)) & 1 == 1 {
                    side += 1;
                } else {
                    rc_in += 1;
                }
                out_pos += 1;
            }
            pos += run;
        } else {
            rc_in += 2;
            pos += 2;
            out_pos += (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
        }
    }
    (rc_in, side)
}

/// Range-code the token stream (shared by the full encoder and the trial:
/// `trial_limit` stops after that many coder-input bytes). Returns
/// `(rc bytes, side bytes, coder-input bytes consumed)`.
fn entropy_encode_inner(
    lz: &[u8],
    elem_size: usize,
    raw_len: usize,
    mask: u8,
    trial_limit: Option<usize>,
) -> (Vec<u8>, Vec<u8>, usize) {
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut enc = RangeEncoder::new();
    let mut models = TokenModels::new();
    let mut side = Vec::new();
    let mut pos = 0usize;
    let mut out_pos = 0usize;
    let mut prev_lit = 0u8;
    let mut rc_in = 0usize;
    while pos < lz.len() {
        if let Some(limit) = trial_limit {
            if rc_in >= limit {
                break;
            }
        }
        let ctrl = lz[pos];
        rc_encode_byte(&mut enc, &mut models.ctrl, ctrl);
        rc_in += 1;
        pos += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            for &b in &lz[pos..pos + run] {
                if (mask >> plane_of(out_pos, plane_n, es)) & 1 == 1 {
                    side.push(b);
                } else {
                    let m = (prev_lit >> (8 - LIT_PREV_BITS)) as usize;
                    rc_encode_byte(&mut enc, &mut models.lit[m], b);
                    prev_lit = b;
                    rc_in += 1;
                }
                out_pos += 1;
            }
            pos += run;
        } else {
            rc_encode_byte(&mut enc, &mut models.dlo, lz[pos]);
            rc_encode_byte(&mut enc, &mut models.dhi, lz[pos + 1]);
            rc_in += 2;
            pos += 2;
            out_pos += (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
        }
    }
    (enc.finish(), side, rc_in)
}

/// Assemble the entropy frame from its parts (see the module docs for the
/// layout).
fn entropy_frame(lz_len: usize, mask: u8, side: &[u8], rc: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(ENTROPY_HEADER_LEN + side.len() + rc.len());
    out.extend_from_slice(&(lz_len as u32).to_le_bytes());
    out.push(mask);
    out.extend_from_slice(&(side.len() as u32).to_le_bytes());
    out.extend_from_slice(side);
    out.extend_from_slice(rc);
    out
}

/// Full entropy frame over a token stream.
pub fn entropy_encode_tokens(lz: &[u8], elem_size: usize, raw_len: usize, mask: u8) -> Vec<u8> {
    let (rc, side, _) = entropy_encode_inner(lz, elem_size, raw_len, mask, None);
    entropy_frame(lz.len(), mask, &side, &rc)
}

/// Trial run of the range coder over the first `limit` coder-input bytes:
/// returns `(rc output bytes, coder-input bytes consumed)`.
fn entropy_trial(
    lz: &[u8],
    elem_size: usize,
    raw_len: usize,
    mask: u8,
    limit: usize,
) -> (usize, usize) {
    let (rc, _, rc_in) = entropy_encode_inner(lz, elem_size, raw_len, mask, Some(limit));
    (rc.len(), rc_in)
}

/// Invert [`entropy_encode_tokens`]: reproduce the LZ token stream from an
/// entropy frame. Robust against corrupt frames — every length is bounds-
/// checked and the range decoder refuses to read past its stream.
pub fn entropy_decode_tokens(frame: &[u8], elem_size: usize, raw_len: usize) -> Result<Vec<u8>> {
    if frame.len() < ENTROPY_HEADER_LEN {
        bail!("h5lite: entropy frame shorter than its header");
    }
    let lz_len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
    let mask = frame[4];
    let side_len = u32::from_le_bytes(frame[5..9].try_into().unwrap()) as usize;
    // the LZ stream can exceed raw_len only by the literal-run control
    // bytes — anything bigger is corruption, not a chunk
    if lz_len > raw_len + raw_len / 128 + 16 {
        bail!("h5lite: entropy frame claims an implausible token stream ({lz_len} bytes)");
    }
    if ENTROPY_HEADER_LEN + side_len > frame.len() {
        bail!("h5lite: entropy frame side buffer out of bounds");
    }
    let side = &frame[ENTROPY_HEADER_LEN..ENTROPY_HEADER_LEN + side_len];
    let mut dec = RangeDecoder::new(&frame[ENTROPY_HEADER_LEN + side_len..])?;
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut models = TokenModels::new();
    let mut out = Vec::with_capacity(lz_len);
    let mut out_pos = 0usize;
    let mut prev_lit = 0u8;
    let mut sp = 0usize;
    while out.len() < lz_len {
        let ctrl = rc_decode_byte(&mut dec, &mut models.ctrl)?;
        out.push(ctrl);
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            if out.len() + run > lz_len {
                bail!("h5lite: entropy frame literal run overruns the token stream");
            }
            for _ in 0..run {
                let b = if (mask >> plane_of(out_pos, plane_n, es)) & 1 == 1 {
                    if sp >= side.len() {
                        bail!("h5lite: entropy frame side buffer underrun");
                    }
                    let b = side[sp];
                    sp += 1;
                    b
                } else {
                    let m = (prev_lit >> (8 - LIT_PREV_BITS)) as usize;
                    let b = rc_decode_byte(&mut dec, &mut models.lit[m])?;
                    prev_lit = b;
                    b
                };
                out.push(b);
                out_pos += 1;
            }
        } else {
            if out.len() + 2 > lz_len {
                bail!("h5lite: entropy frame match token overruns the token stream");
            }
            out.push(rc_decode_byte(&mut dec, &mut models.dlo)?);
            out.push(rc_decode_byte(&mut dec, &mut models.dhi)?);
            out_pos += (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
        }
    }
    if sp != side.len() {
        bail!("h5lite: entropy frame side buffer has {} stray bytes", side.len() - sp);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// entropy stage: static tANS (table-driven asymmetric numeral systems)
// ---------------------------------------------------------------------------

/// tANS table precision: normalized frequencies sum to `1 << TANS_R`.
const TANS_R: u32 = 12;
/// Number of tANS states (and decode-table entries) per stream table.
const TANS_L: usize = 1 << TANS_R;
/// Symbol spread step: `(L >> 1) + (L >> 3) + 3`, odd and so coprime with
/// the power-of-two `L` — one pass over `0..L` visits every slot once.
const TANS_STEP: usize = (TANS_L >> 1) + (TANS_L >> 3) + 3;
/// Stream-section flags of the tANS payload.
const TANS_STREAM_ABSENT: u8 = 0;
const TANS_STREAM_CODED: u8 = 1;
const TANS_STREAM_RAW: u8 = 2;
/// The adaptive selector prefers the tANS frame while it is within this
/// many percent of the range coder's — decode speed counts double on the
/// fan-out read path, so a small stored-ratio give-back is a good trade.
const TANS_PREFER_PCT: usize = 3;
/// Token streams of the tANS payload, in table order.
const TANS_STREAMS: usize = 4;
const TS_CTRL: usize = 0;
const TS_DLO: usize = 1;
const TS_DHI: usize = 2;
const TS_LIT: usize = 3;

/// MSB-first bit writer of the tANS payload (tables and bitstream).
struct TansBitWriter {
    out: Vec<u8>,
    acc: u64,
    n: u32,
}

impl TansBitWriter {
    fn new() -> TansBitWriter {
        TansBitWriter {
            out: Vec::new(),
            acc: 0,
            n: 0,
        }
    }

    #[inline]
    fn write(&mut self, value: u32, bits: u32) {
        self.acc = (self.acc << bits) | (value as u64 & ((1u64 << bits) - 1));
        self.n += bits;
        while self.n >= 8 {
            self.n -= 8;
            self.out.push((self.acc >> self.n) as u8);
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.n > 0 {
            self.out.push((self.acc << (8 - self.n)) as u8);
        }
        self.out
    }
}

/// Matching MSB-first bit reader; refuses to read past the stream end.
struct TansBitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    n: u32,
}

impl<'a> TansBitReader<'a> {
    fn new(buf: &'a [u8]) -> TansBitReader<'a> {
        TansBitReader {
            buf,
            pos: 0,
            acc: 0,
            n: 0,
        }
    }

    #[inline]
    fn read(&mut self, bits: u32) -> Result<u32> {
        while self.n < bits {
            if self.pos >= self.buf.len() {
                bail!("h5lite: tANS bitstream exhausted");
            }
            self.acc = (self.acc << 8) | self.buf[self.pos] as u64;
            self.pos += 1;
            self.n += 8;
        }
        self.n -= bits;
        Ok(((self.acc >> self.n) & ((1u64 << bits) - 1)) as u32)
    }
}

/// Normalize a byte histogram to frequencies summing exactly [`TANS_L`],
/// every present symbol ≥ 1. Deterministic: over-shoot is trimmed from
/// the largest entries (smallest symbol wins ties), under-shoot goes to
/// the most frequent symbol.
fn tans_normalize(hist: &[u32; 256]) -> [u16; 256] {
    let total: u64 = hist.iter().map(|&c| c as u64).sum();
    debug_assert!(total > 0);
    let mut f = [0u16; 256];
    let mut sum = 0usize;
    for s in 0..256 {
        if hist[s] > 0 {
            let v = ((hist[s] as u64 * TANS_L as u64) / total).max(1) as u16;
            f[s] = v;
            sum += v as usize;
        }
    }
    while sum > TANS_L {
        let mut best = usize::MAX;
        for s in 0..256 {
            if f[s] > 1 && (best == usize::MAX || f[s] > f[best]) {
                best = s;
            }
        }
        f[best] -= 1;
        sum -= 1;
    }
    if sum < TANS_L {
        let mut best = 0usize;
        for s in 1..256 {
            if hist[s] > hist[best] {
                best = s;
            }
        }
        f[best] += (TANS_L - sum) as u16;
    }
    f
}

/// Spread the symbols over the state table: symbol `s` occupies `f[s]`
/// slots, placed by stepping [`TANS_STEP`] (mod `L`) — the standard FSE
/// scatter that keeps each symbol's slots roughly equidistant.
fn tans_spread(f: &[u16; 256]) -> Vec<u8> {
    let mut spread = vec![0u8; TANS_L];
    let mut pos = 0usize;
    for s in 0..256 {
        for _ in 0..f[s] {
            spread[pos] = s as u8;
            pos = (pos + TANS_STEP) & (TANS_L - 1);
        }
    }
    debug_assert_eq!(pos, 0);
    spread
}

/// One decode-table cell: 4 bytes, so the whole table is 16 KiB and the
/// hot loop is one cache access per symbol.
#[derive(Clone, Copy, Default)]
struct TansCell {
    sym: u8,
    nb: u8,
    new_x: u16,
}

/// Decode table: for state `x`, emit `sym`, then
/// `x' = new_x + next(nb bits)`.
fn tans_decode_table(f: &[u16; 256]) -> Vec<TansCell> {
    let spread = tans_spread(f);
    let mut next = [0u32; 256];
    for s in 0..256 {
        next[s] = f[s] as u32;
    }
    let mut cells = vec![TansCell::default(); TANS_L];
    for (x, cell) in cells.iter_mut().enumerate() {
        let s = spread[x] as usize;
        let big_x = next[s];
        next[s] += 1;
        // big_x ∈ [f, 2f): nb = R - ⌊log2 big_x⌋, new_x = (big_x << nb) - L
        let nb = TANS_R - (31 - big_x.leading_zeros());
        cell.sym = s as u8;
        cell.nb = nb as u8;
        cell.new_x = (((big_x as usize) << nb) - TANS_L) as u16;
    }
    cells
}

/// Encode table: `enc[cum[s] + (x_scaled - f[s])]` is the next state for
/// symbol `s` after the renormalizing shift brought the state down to
/// `x_scaled ∈ [f, 2f)`.
struct TansEncodeTable {
    f: [u16; 256],
    cum: [u32; 256],
    enc: Vec<u16>,
}

fn tans_encode_table(f: &[u16; 256]) -> TansEncodeTable {
    let spread = tans_spread(f);
    let mut cum = [0u32; 256];
    let mut acc = 0u32;
    for s in 0..256 {
        cum[s] = acc;
        acc += f[s] as u32;
    }
    let mut next = [0u32; 256];
    for s in 0..256 {
        next[s] = f[s] as u32;
    }
    let mut enc = vec![0u16; TANS_L];
    for (x, &sym) in spread.iter().enumerate() {
        let s = sym as usize;
        let big_x = next[s];
        next[s] += 1;
        enc[(cum[s] + (big_x - f[s] as u32)) as usize] = x as u16;
    }
    TansEncodeTable { f: *f, cum, enc }
}

/// Serialized size of a coded stream table (flag + presence bitmap +
/// packed 12-bit frequencies).
fn tans_table_ser_len(f: &[u16; 256]) -> usize {
    let present = f.iter().filter(|&&v| v > 0).count();
    1 + 32 + (TANS_R as usize * present).div_ceil(8)
}

fn tans_serialize_table(out: &mut Vec<u8>, f: &[u16; 256]) {
    out.push(TANS_STREAM_CODED);
    let mut bitmap = [0u8; 32];
    for s in 0..256 {
        if f[s] > 0 {
            bitmap[s >> 3] |= 1 << (s & 7);
        }
    }
    out.extend_from_slice(&bitmap);
    let mut w = TansBitWriter::new();
    for s in 0..256 {
        if f[s] > 0 {
            // f ∈ [1, 4096] → f - 1 fits TANS_R bits exactly
            w.write((f[s] - 1) as u32, TANS_R);
        }
    }
    out.extend_from_slice(&w.finish());
}

/// Parse one coded table section (the flag byte already consumed).
/// Rejects tables whose frequencies do not sum to exactly `L`.
fn tans_deserialize_table(frame: &[u8], pos: &mut usize) -> Result<[u16; 256]> {
    if *pos + 32 > frame.len() {
        bail!("h5lite: tANS table bitmap out of bounds");
    }
    let bitmap = &frame[*pos..*pos + 32];
    *pos += 32;
    let present: Vec<usize> = (0..256)
        .filter(|&s| (bitmap[s >> 3] >> (s & 7)) & 1 == 1)
        .collect();
    if present.is_empty() {
        bail!("h5lite: tANS coded table with empty symbol bitmap");
    }
    let nbytes = (TANS_R as usize * present.len()).div_ceil(8);
    if *pos + nbytes > frame.len() {
        bail!("h5lite: tANS table frequencies out of bounds");
    }
    let mut r = TansBitReader::new(&frame[*pos..*pos + nbytes]);
    *pos += nbytes;
    let mut f = [0u16; 256];
    let mut sum = 0usize;
    for s in present {
        let v = r.read(TANS_R)? as u16 + 1;
        f[s] = v;
        sum += v as usize;
    }
    if sum != TANS_L {
        bail!("h5lite: tANS table frequencies sum to {sum}, want {TANS_L}");
    }
    Ok(f)
}

/// Per-stream coding plan of one frame.
enum TansPlan {
    Absent,
    /// Symbols ride the bitstream as plain 8-bit values: the table plus
    /// coded bits would cost more (near-uniform streams like dist-lo).
    Raw,
    Coded([u16; 256]),
}

/// Decide how each stream is stored and estimate the payload cost.
/// Returns the plan and the predicted payload size in bytes (tables +
/// bitstream; excludes header, side buffer and the two state words).
fn tans_plan_streams(hists: &[[u32; 256]; TANS_STREAMS]) -> ([TansPlan; TANS_STREAMS], usize) {
    let mut plan = [
        TansPlan::Absent,
        TansPlan::Absent,
        TansPlan::Absent,
        TansPlan::Absent,
    ];
    let mut bits = 0.0f64;
    let mut table_bytes = 0usize;
    for (st, h) in hists.iter().enumerate() {
        let total: u64 = h.iter().map(|&c| c as u64).sum();
        if total == 0 {
            table_bytes += 1;
            continue;
        }
        let f = tans_normalize(h);
        let mut coded_bits = 0.0f64;
        for s in 0..256 {
            if h[s] > 0 {
                coded_bits += h[s] as f64 * (TANS_R as f64 - (f[s] as f64).log2());
            }
        }
        let coded_cost = (tans_table_ser_len(&f) - 1) as f64 + coded_bits / 8.0;
        if (total as f64) < coded_cost {
            plan[st] = TansPlan::Raw;
            table_bytes += 1;
            bits += total as f64 * 8.0;
        } else {
            plan[st] = TansPlan::Coded(f);
            table_bytes += tans_table_ser_len(&f);
            bits += coded_bits;
        }
    }
    (plan, table_bytes + (bits / 8.0) as usize + 1)
}

/// Walk the token stream once, splitting it into the four tANS symbol
/// streams (plus the bypassed side buffer) and their histograms. Symbols
/// are `(stream, byte)` in decode order.
fn tans_collect_symbols(
    lz: &[u8],
    elem_size: usize,
    raw_len: usize,
    mask: u8,
) -> (Vec<(u8, u8)>, Vec<u8>, [[u32; 256]; TANS_STREAMS]) {
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut syms: Vec<(u8, u8)> = Vec::with_capacity(lz.len());
    let mut side = Vec::new();
    let mut hists = [[0u32; 256]; TANS_STREAMS];
    let mut pos = 0usize;
    let mut out_pos = 0usize;
    while pos < lz.len() {
        let ctrl = lz[pos];
        syms.push((TS_CTRL as u8, ctrl));
        hists[TS_CTRL][ctrl as usize] += 1;
        pos += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            for &b in &lz[pos..pos + run] {
                if (mask >> plane_of(out_pos, plane_n, es)) & 1 == 1 {
                    side.push(b);
                } else {
                    syms.push((TS_LIT as u8, b));
                    hists[TS_LIT][b as usize] += 1;
                }
                out_pos += 1;
            }
            pos += run;
        } else {
            syms.push((TS_DLO as u8, lz[pos]));
            hists[TS_DLO][lz[pos] as usize] += 1;
            syms.push((TS_DHI as u8, lz[pos + 1]));
            hists[TS_DHI][lz[pos + 1] as usize] += 1;
            pos += 2;
            out_pos += (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
        }
    }
    (syms, side, hists)
}

/// Predicted tANS frame size from one histogram walk — near-exact (the
/// per-symbol bit counts vary from the entropy estimate by well under a
/// percent), so the adaptive selector can gate the real encoding pass on
/// it the way the rc trial gates the range coder.
fn tans_predict_len(lz: &[u8], elem_size: usize, raw_len: usize, mask: u8) -> usize {
    let (_, side, hists) = tans_collect_symbols(lz, elem_size, raw_len, mask);
    let (_, payload) = tans_plan_streams(&hists);
    ENTROPY_HEADER_LEN + side.len() + 4 + payload
}

/// Full tANS entropy frame over a token stream (the [`Entropy::Tans`]
/// counterpart of [`entropy_encode_tokens`]; same outer header).
///
/// Symbols are encoded in **reverse** with two interleaved states — coded
/// symbols alternate lanes by their forward coded-symbol index — and the
/// per-symbol bit chunks are then emitted in forward order, so the
/// decoder reads the bitstream strictly forward.
pub fn tans_encode_tokens(lz: &[u8], elem_size: usize, raw_len: usize, mask: u8) -> Vec<u8> {
    let (syms, side, hists) = tans_collect_symbols(lz, elem_size, raw_len, mask);
    let (plan, _) = tans_plan_streams(&hists);
    let tables: [Option<TansEncodeTable>; TANS_STREAMS] = std::array::from_fn(|st| {
        if let TansPlan::Coded(f) = &plan[st] {
            Some(tans_encode_table(f))
        } else {
            None
        }
    });
    let mut coded_left: usize = syms
        .iter()
        .filter(|&&(st, _)| matches!(plan[st as usize], TansPlan::Coded(_)))
        .count();
    let mut states = [TANS_L as u32; 2];
    // (bits, count) per symbol, collected back-to-front
    let mut chunks: Vec<(u16, u8)> = Vec::with_capacity(syms.len());
    for &(st, b) in syms.iter().rev() {
        match &plan[st as usize] {
            TansPlan::Raw => chunks.push((b as u16, 8)),
            TansPlan::Coded(_) => {
                let t = tables[st as usize].as_ref().unwrap();
                let fs = t.f[b as usize] as u32;
                coded_left -= 1;
                let lane = coded_left & 1;
                let x = states[lane];
                let mut nb = 0u32;
                while (x >> nb) >= 2 * fs {
                    nb += 1;
                }
                chunks.push(((x & ((1 << nb) - 1)) as u16, nb as u8));
                let x_scaled = x >> nb;
                states[lane] =
                    (TANS_L + t.enc[(t.cum[b as usize] + (x_scaled - fs)) as usize] as usize)
                        as u32;
            }
            TansPlan::Absent => unreachable!("symbol collected from an absent stream"),
        }
    }
    let mut w = TansBitWriter::new();
    for &(v, nb) in chunks.iter().rev() {
        w.write(v as u32, nb as u32);
    }
    let bitstream = w.finish();
    let mut payload =
        Vec::with_capacity(4 + TANS_STREAMS * (33 + 384) + bitstream.len());
    payload.extend_from_slice(&((states[0] as usize - TANS_L) as u16).to_le_bytes());
    payload.extend_from_slice(&((states[1] as usize - TANS_L) as u16).to_le_bytes());
    for p in &plan {
        match p {
            TansPlan::Absent => payload.push(TANS_STREAM_ABSENT),
            TansPlan::Raw => payload.push(TANS_STREAM_RAW),
            TansPlan::Coded(f) => tans_serialize_table(&mut payload, f),
        }
    }
    payload.extend_from_slice(&bitstream);
    entropy_frame(lz.len(), mask, &side, &payload)
}

/// Decoder-side stream state: the parsed tables plus the two interleaved
/// lanes and the shared bitstream cursor.
struct TansSymbolReader<'a> {
    tables: [Option<Vec<TansCell>>; TANS_STREAMS],
    raw_stream: [bool; TANS_STREAMS],
    reader: TansBitReader<'a>,
    states: [u32; 2],
    n_coded: usize,
}

impl TansSymbolReader<'_> {
    #[inline]
    fn read(&mut self, st: usize) -> Result<u8> {
        if self.raw_stream[st] {
            return Ok(self.reader.read(8)? as u8);
        }
        let Some(table) = &self.tables[st] else {
            bail!("h5lite: tANS symbol from an absent stream");
        };
        let cell = table[self.states[self.n_coded & 1] as usize];
        // in-bounds by construction: new_x + bits < L for any table whose
        // frequencies sum to L (validated at parse time)
        self.states[self.n_coded & 1] = cell.new_x as u32 + self.reader.read(cell.nb as u32)?;
        self.n_coded += 1;
        Ok(cell.sym)
    }
}

/// Invert [`tans_encode_tokens`]: reproduce the LZ token stream from a
/// tANS entropy frame. Robust against corrupt frames — every length and
/// table is validated, and both decode lanes must return to the
/// encoder's start state.
pub fn tans_decode_tokens(frame: &[u8], elem_size: usize, raw_len: usize) -> Result<Vec<u8>> {
    if frame.len() < ENTROPY_HEADER_LEN {
        bail!("h5lite: entropy frame shorter than its header");
    }
    let lz_len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
    let mask = frame[4];
    let side_len = u32::from_le_bytes(frame[5..9].try_into().unwrap()) as usize;
    if lz_len > raw_len + raw_len / 128 + 16 {
        bail!("h5lite: entropy frame claims an implausible token stream ({lz_len} bytes)");
    }
    if ENTROPY_HEADER_LEN + side_len > frame.len() {
        bail!("h5lite: entropy frame side buffer out of bounds");
    }
    let side = &frame[ENTROPY_HEADER_LEN..ENTROPY_HEADER_LEN + side_len];
    let mut pos = ENTROPY_HEADER_LEN + side_len;
    if pos + 4 > frame.len() {
        bail!("h5lite: tANS frame truncated before its state words");
    }
    let x0 = u16::from_le_bytes(frame[pos..pos + 2].try_into().unwrap()) as u32;
    let x1 = u16::from_le_bytes(frame[pos + 2..pos + 4].try_into().unwrap()) as u32;
    pos += 4;
    if x0 as usize >= TANS_L || x1 as usize >= TANS_L {
        bail!("h5lite: tANS start state out of range");
    }
    let mut tables: [Option<Vec<TansCell>>; TANS_STREAMS] = Default::default();
    let mut raw_stream = [false; TANS_STREAMS];
    for st in 0..TANS_STREAMS {
        if pos >= frame.len() {
            bail!("h5lite: tANS frame truncated in its table section");
        }
        let flag = frame[pos];
        pos += 1;
        match flag {
            TANS_STREAM_ABSENT => {}
            TANS_STREAM_RAW => raw_stream[st] = true,
            TANS_STREAM_CODED => {
                let f = tans_deserialize_table(frame, &mut pos)?;
                tables[st] = Some(tans_decode_table(&f));
            }
            _ => bail!("h5lite: unknown tANS stream flag {flag}"),
        }
    }
    let mut sr = TansSymbolReader {
        tables,
        raw_stream,
        reader: TansBitReader::new(&frame[pos..]),
        states: [x0, x1],
        n_coded: 0,
    };
    let es = elem_size.clamp(1, 8);
    let plane_n = (raw_len / es).max(1);
    let mut out = Vec::with_capacity(lz_len);
    let mut out_pos = 0usize;
    let mut sp = 0usize;
    while out.len() < lz_len {
        let ctrl = sr.read(TS_CTRL)?;
        out.push(ctrl);
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            if out.len() + run > lz_len {
                bail!("h5lite: entropy frame literal run overruns the token stream");
            }
            for _ in 0..run {
                let b = if (mask >> plane_of(out_pos, plane_n, es)) & 1 == 1 {
                    if sp >= side.len() {
                        bail!("h5lite: entropy frame side buffer underrun");
                    }
                    let b = side[sp];
                    sp += 1;
                    b
                } else {
                    sr.read(TS_LIT)?
                };
                out.push(b);
                out_pos += 1;
            }
        } else {
            if out.len() + 2 > lz_len {
                bail!("h5lite: entropy frame match token overruns the token stream");
            }
            out.push(sr.read(TS_DLO)?);
            out.push(sr.read(TS_DHI)?);
            out_pos += (ctrl & 0x7f) as usize + LZ_MIN_MATCH;
        }
    }
    if sp != side.len() {
        bail!("h5lite: entropy frame side buffer has {} stray bytes", side.len() - sp);
    }
    if sr.states != [0, 0] {
        bail!(
            "h5lite: tANS decode lanes ended at {:?}, not the start state",
            sr.states
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEADBEEF);
        e.u64(1 << 40);
        e.i64(-42);
        e.f64(3.5);
        e.str("hello/world");
        e.f64s(&[1.0, 2.0]);
        e.u64s(&[9, 8, 7]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.i64().unwrap(), -42);
        assert_eq!(d.f64().unwrap(), 3.5);
        assert_eq!(d.str().unwrap(), "hello/world");
        assert_eq!(d.f64s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(d.u64s().unwrap(), vec![9, 8, 7]);
        assert!(d.done());
    }

    #[test]
    fn dec_truncation_is_error() {
        let mut e = Enc::new();
        e.u32(5);
        let mut d = Dec::new(&e.buf);
        assert!(d.u64().is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&v)), v);
    }

    #[test]
    fn u64_bytes_roundtrip() {
        let v = vec![0u64, u64::MAX, 1 << 63];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&v)), v);
    }

    #[test]
    fn f64_bytes_roundtrip() {
        let v = vec![0.25f64, -1e300];
        assert_eq!(bytes_to_f64s(&f64s_to_bytes(&v)), v);
    }

    fn xorshift_bytes(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 24) as u8
            })
            .collect()
    }

    #[test]
    fn lz_roundtrip_random_and_empty() {
        for n in [0usize, 1, 3, 4, 5, 127, 128, 129, 4096] {
            let data = xorshift_bytes(n as u64 + 7, n);
            let comp = lz_compress(&data);
            assert_eq!(lz_decompress(&comp, n).unwrap(), data, "n={n}");
            let chained = lz_compress_chain(&data, LZ_CHAIN_DEPTH);
            assert_eq!(lz_decompress(&chained, n).unwrap(), data, "chain n={n}");
        }
    }

    #[test]
    fn lz_crushes_repetitive_input() {
        // matches cap at 131 bytes / 3-byte token → ~43:1 on constant input
        let data = vec![42u8; 100_000];
        for comp in [lz_compress(&data), lz_compress_chain(&data, LZ_CHAIN_DEPTH)] {
            assert!(comp.len() < data.len() / 40, "{} bytes", comp.len());
            assert_eq!(lz_decompress(&comp, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn lz_overlapping_match_is_rle() {
        // "abcabcabc..." compresses via distance-3 overlapping matches
        let data: Vec<u8> = (0..3000).map(|i| b"abc"[i % 3]).collect();
        for comp in [lz_compress(&data), lz_compress_chain(&data, LZ_CHAIN_DEPTH)] {
            assert!(comp.len() < 200, "{} bytes", comp.len());
            assert_eq!(lz_decompress(&comp, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn lz_rejects_corrupt_streams() {
        let data = xorshift_bytes(9, 256);
        let comp = lz_compress(&data);
        assert!(lz_decompress(&comp, 255).is_err()); // wrong raw_len
        assert!(lz_decompress(&comp[..comp.len() - 1], 256).is_err()); // truncated
        assert!(lz_decompress(&[0x85, 0xff, 0xff], 100).is_err()); // bad distance
    }

    #[test]
    fn chain_matcher_beats_single_candidate() {
        // the hash chain revisits older, longer matches the one-slot table
        // forgets; on smooth shuffled/delta'd f32 data it must strictly win
        let floats: Vec<f32> = (0..8192).map(|i| (i as f32 * 1e-3).sin()).collect();
        let mut sdl = shuffle(&f32s_to_bytes(&floats), 4);
        delta_encode(&mut sdl);
        let single = lz_compress(&sdl);
        let chained = lz_compress_chain(&sdl, LZ_CHAIN_DEPTH);
        assert!(
            chained.len() < single.len(),
            "chain {} !< single {}",
            chained.len(),
            single.len()
        );
        assert_eq!(lz_decompress(&chained, sdl.len()).unwrap(), sdl);
    }

    #[test]
    fn shuffle_roundtrip_all_elem_sizes() {
        for es in [1usize, 2, 4, 8] {
            let data = xorshift_bytes(es as u64, 64 * es);
            assert_eq!(unshuffle(&shuffle(&data, es), es), data, "es={es}");
        }
    }

    #[test]
    fn shuffle_groups_byte_planes() {
        // elements 0x0100, 0x0200: low bytes first plane, high bytes second
        let data = [0x00, 0x01, 0x00, 0x02];
        assert_eq!(shuffle(&data, 2), vec![0x00, 0x00, 0x01, 0x02]);
    }

    #[test]
    fn delta_roundtrip() {
        let mut data = xorshift_bytes(3, 513);
        let orig = data.clone();
        delta_encode(&mut data);
        assert_ne!(data, orig);
        delta_decode(&mut data);
        assert_eq!(data, orig);
    }

    // -------------------------------------------------------------------
    // entropy stage
    // -------------------------------------------------------------------

    fn rc_only_roundtrip(data: &[u8]) {
        // exercise the raw coder through a mask-0, literal-only stream
        let mut lz = Vec::new();
        let mut s = 0usize;
        while s < data.len() {
            let run = (data.len() - s).min(128);
            lz.push((run - 1) as u8);
            lz.extend_from_slice(&data[s..s + run]);
            s += run;
        }
        let frame = entropy_encode_tokens(&lz, 1, data.len(), 0);
        let back = entropy_decode_tokens(&frame, 1, data.len()).unwrap();
        assert_eq!(back, lz);
    }

    #[test]
    fn range_coder_roundtrips_byte_streams() {
        rc_only_roundtrip(b"");
        rc_only_roundtrip(b"A");
        rc_only_roundtrip(&[0u8; 5000]);
        rc_only_roundtrip(&xorshift_bytes(11, 8192));
        let skewed: Vec<u8> = (0..4096).map(|i| if i % 7 == 0 { 3 } else { 0 }).collect();
        rc_only_roundtrip(&skewed);
    }

    #[test]
    fn entropy_frame_bypass_planes_roundtrip() {
        // plane 1 bypassed: its literals ride the side buffer verbatim
        let noise = xorshift_bytes(42, 2048);
        let raw: Vec<u8> = (0..2048usize)
            .flat_map(|i| [(i % 11) as u8, noise[i]])
            .collect();
        let filtered = shuffle(&raw, 2);
        let lz = lz_compress_chain(&filtered, LZ_CHAIN_DEPTH);
        let mask = bypass_mask(&filtered, 2, raw.len());
        assert_eq!(mask & 0b10, 0b10, "the noise plane must bypass");
        let frame = entropy_encode_tokens(&lz, 2, raw.len(), mask);
        let back = entropy_decode_tokens(&frame, 2, raw.len()).unwrap();
        assert_eq!(back, lz);
        assert_eq!(lz_decompress(&back, filtered.len()).unwrap(), filtered);
    }

    #[test]
    fn entropy_frame_rejects_corruption() {
        let floats: Vec<f32> = (0..2048).map(|i| (i as f32 * 1e-3).sin()).collect();
        let raw = f32s_to_bytes(&floats);
        let enc = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
        assert!(Codec::SHUFFLE_DELTA_LZ_RC.decode(&enc, 4, raw.len()).is_ok());
        // truncated frame
        assert!(Codec::SHUFFLE_DELTA_LZ_RC
            .decode(&enc[..enc.len() - 2], 4, raw.len())
            .is_err());
        assert!(Codec::SHUFFLE_DELTA_LZ_RC.decode(&enc[..4], 4, raw.len()).is_err());
        // absurd token-stream length
        let mut bad = enc.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Codec::SHUFFLE_DELTA_LZ_RC.decode(&bad, 4, raw.len()).is_err());
        // side buffer pointing past the frame
        let mut bad = enc.clone();
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Codec::SHUFFLE_DELTA_LZ_RC.decode(&bad, 4, raw.len()).is_err());
    }

    #[test]
    fn codec_roundtrip_every_variant() {
        let floats: Vec<f32> = (0..2048).map(|i| (i as f32 * 0.001).sin()).collect();
        let raw = f32s_to_bytes(&floats);
        for codec in ALL_CODECS {
            let enc = codec.encode(&raw, 4);
            let dec = codec.decode(&enc, 4, raw.len()).unwrap();
            assert_eq!(dec, raw, "{codec:?}");
        }
    }

    #[test]
    fn entropy_stage_beats_plain_lz_on_smooth_f32() {
        let floats: Vec<f32> = (0..8192)
            .map(|i| 1.0 + ((i as f32) * 1e-3).sin() * 0.25)
            .collect();
        let raw = f32s_to_bytes(&floats);
        let lz = Codec::SHUFFLE_DELTA_LZ.encode(&raw, 4);
        let ent = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
        assert!(
            ent.len() < lz.len() && ent.len() * 3 < raw.len(),
            "ent {} lz {} raw {}",
            ent.len(),
            lz.len(),
            raw.len()
        );
    }

    #[test]
    fn shuffle_delta_lz_beats_plain_lz_on_smooth_f32() {
        // smooth field data: exponent bytes nearly constant → shuffle+delta
        // exposes runs plain byte-LZ cannot see
        let floats: Vec<f32> = (0..8192).map(|i| 1.0 + (i as f32 * 1e-4)).collect();
        let raw = f32s_to_bytes(&floats);
        let plain = Codec::LZ.encode(&raw, 4);
        let sdl = Codec::SHUFFLE_DELTA_LZ.encode(&raw, 4);
        assert!(
            sdl.len() < plain.len() && sdl.len() * 2 < raw.len(),
            "sdl {} plain {} raw {}",
            sdl.len(),
            plain.len(),
            raw.len()
        );
    }

    #[test]
    fn encode_chunk_filter_mask_semantics() {
        // compressible → Some(smaller); incompressible → None; checksum is
        // always over the raw bytes
        let smooth = f32s_to_bytes(&(0..1024).map(|i| 1.0 + i as f32 * 1e-4).collect::<Vec<_>>());
        let (enc, ck) = encode_chunk(Codec::SHUFFLE_DELTA_LZ, &smooth, 4);
        assert!(enc.as_ref().unwrap().len() < smooth.len());
        assert_eq!(ck, checksum32(&smooth));
        let noise = xorshift_bytes(5, 1024);
        let (enc, ck) = encode_chunk(Codec::LZ, &noise, 1);
        assert!(enc.is_none());
        assert_eq!(ck, checksum32(&noise));
    }

    #[test]
    fn adaptive_selection_per_input_class() {
        // smooth → entropy; pure noise → store; constant → compressed
        let smooth =
            f32s_to_bytes(&(0..8192).map(|i| 1.0 + ((i as f32) * 1e-3).sin() * 0.25).collect::<Vec<_>>());
        let enc = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &smooth, 4);
        assert_eq!(enc.codec, Some(Codec::SHUFFLE_DELTA_LZ_RC), "smooth picks entropy");
        assert!(enc.stored.as_ref().unwrap().len() * 2 < smooth.len());
        assert_eq!(enc.checksum, checksum32(&smooth));
        let dec = enc
            .codec
            .unwrap()
            .decode(enc.stored.as_ref().unwrap(), 4, smooth.len())
            .unwrap();
        assert_eq!(dec, smooth);

        let noise = xorshift_bytes(77, 32768);
        let enc = encode_chunk_adaptive(Codec::LZ, &noise, 1);
        assert!(enc.stored.is_none(), "noise must fall back to Store");
        assert!(enc.codec.is_none());

        let zeros = vec![0u8; 32768];
        let enc = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &zeros, 4);
        assert!(enc.stored.as_ref().unwrap().len() < zeros.len() / 40);
    }

    #[test]
    fn adaptive_on_raw_base_is_store() {
        let data = xorshift_bytes(5, 512);
        let enc = encode_chunk_adaptive(Codec::Raw, &data, 1);
        assert!(enc.stored.is_none());
        assert!(enc.codec.is_none());
        assert_eq!(enc.checksum, checksum32(&data));
    }

    #[test]
    fn chunk_codec_byte_mapping() {
        // 0 = raw, 1 = dataset codec (the pre-codec-v2 "applied" bit),
        // 2+code = explicit — and every combination round-trips
        let ds = Codec::SHUFFLE_DELTA_LZ;
        assert_eq!(chunk_codec_to_byte(ds, None), 0);
        assert_eq!(chunk_codec_to_byte(ds, Some(ds)), 1);
        assert_eq!(
            chunk_codec_to_byte(ds, Some(Codec::SHUFFLE_DELTA_LZ_RC)),
            2 + Codec::SHUFFLE_DELTA_LZ_RC.code()
        );
        for applied in
            [None, Some(Codec::LZ), Some(ds), Some(Codec::SHUFFLE_DELTA_LZ_RC)]
        {
            let b = chunk_codec_to_byte(ds, applied);
            assert_eq!(chunk_codec_from_byte(ds, b).unwrap(), applied);
        }
        // a v2-era file's only values decode exactly as before
        assert_eq!(chunk_codec_from_byte(ds, 0).unwrap(), None);
        assert_eq!(chunk_codec_from_byte(ds, 1).unwrap(), Some(ds));
        assert!(chunk_codec_from_byte(ds, 2 + 99).is_err());
    }

    #[test]
    fn checksum_distinguishes_buffers() {
        let a = checksum32(b"hello");
        let b = checksum32(b"hellp");
        assert_ne!(a, b);
        assert_eq!(checksum32(b""), 0x811c_9dc5);
    }

    #[test]
    fn codec_codes_roundtrip() {
        for codec in ALL_CODECS {
            assert_eq!(Codec::from_code(codec.code()).unwrap(), codec);
        }
        assert!(Codec::from_code(99).is_err());
    }

    #[test]
    fn entropy_family_helpers() {
        assert_eq!(Codec::LZ.with_entropy(Entropy::RangeCoder), Codec::LZ_RC);
        assert_eq!(Codec::LZ.with_entropy(Entropy::Tans), Codec::LZ_TANS);
        assert_eq!(Codec::SHUFFLE_DELTA_LZ_RC.without_entropy(), Codec::SHUFFLE_DELTA_LZ);
        assert_eq!(Codec::SHUFFLE_DELTA_LZ_TANS.without_entropy(), Codec::SHUFFLE_DELTA_LZ);
        assert_eq!(Codec::Raw.with_entropy(Entropy::RangeCoder), Codec::Raw);
        assert_eq!(Codec::Raw.with_entropy(Entropy::Tans), Codec::Raw);
        for codec in ALL_CODECS {
            assert_eq!(codec.has_entropy(), codec != codec.without_entropy());
            assert_eq!(codec.has_entropy(), codec.entropy() != Entropy::None);
            if codec != Codec::Raw {
                assert!(codec.with_entropy(Entropy::RangeCoder).has_entropy());
                assert!(codec.with_entropy(Entropy::Tans).has_entropy());
                assert_eq!(
                    codec.with_entropy(Entropy::Tans).filter_stage(),
                    codec.filter_stage()
                );
            }
        }
    }

    #[test]
    fn codec_legacy_byte_values_are_stable() {
        // the on-disk contract: 0–6 mean exactly what the flat pre-tANS
        // enum meant, 7–9 are the tANS family
        let expect = [
            (0u8, Codec::Raw),
            (1, Codec::LZ),
            (2, Codec::SHUFFLE_LZ),
            (3, Codec::SHUFFLE_DELTA_LZ),
            (4, Codec::LZ_RC),
            (5, Codec::SHUFFLE_LZ_RC),
            (6, Codec::SHUFFLE_DELTA_LZ_RC),
            (7, Codec::LZ_TANS),
            (8, Codec::SHUFFLE_LZ_TANS),
            (9, Codec::SHUFFLE_DELTA_LZ_TANS),
        ];
        for (code, codec) in expect {
            assert_eq!(codec.code(), code, "{codec:?}");
            assert_eq!(Codec::from_code(code).unwrap(), codec);
        }
        assert!(Codec::from_code(10).is_err());
    }

    // -------------------------------------------------------------------
    // tANS entropy stage
    // -------------------------------------------------------------------

    fn tans_only_roundtrip(data: &[u8]) {
        // exercise the coder through a mask-0, literal-only stream
        let mut lz = Vec::new();
        let mut s = 0usize;
        while s < data.len() {
            let run = (data.len() - s).min(128);
            lz.push((run - 1) as u8);
            lz.extend_from_slice(&data[s..s + run]);
            s += run;
        }
        let frame = tans_encode_tokens(&lz, 1, data.len(), 0);
        let back = tans_decode_tokens(&frame, 1, data.len()).unwrap();
        assert_eq!(back, lz);
    }

    #[test]
    fn tans_roundtrips_byte_streams() {
        tans_only_roundtrip(b"");
        tans_only_roundtrip(b"A");
        tans_only_roundtrip(&[0u8; 5000]);
        tans_only_roundtrip(&xorshift_bytes(11, 8192));
        let skewed: Vec<u8> = (0..4096).map(|i| if i % 7 == 0 { 3 } else { 0 }).collect();
        tans_only_roundtrip(&skewed);
        // every byte value present: densest possible table
        let dense: Vec<u8> = (0..8192u32).map(|i| (i * 97) as u8).collect();
        tans_only_roundtrip(&dense);
    }

    #[test]
    fn tans_matched_token_streams_roundtrip() {
        // real token streams with matches exercise ctrl/dlo/dhi tables
        for seed in [1u64, 9, 42] {
            let floats: Vec<f32> =
                (0..4096).map(|i| ((i as f32) * 1e-3 * seed as f32).sin()).collect();
            let raw = f32s_to_bytes(&floats);
            let mut filtered = shuffle(&raw, 4);
            delta_encode(&mut filtered);
            let lz = lz_compress_chain(&filtered, LZ_CHAIN_DEPTH);
            let mask = bypass_mask(&filtered, 4, raw.len());
            let frame = tans_encode_tokens(&lz, 4, raw.len(), mask);
            let back = tans_decode_tokens(&frame, 4, raw.len()).unwrap();
            assert_eq!(back, lz, "seed {seed}");
        }
    }

    #[test]
    fn tans_near_uniform_stream_goes_raw() {
        // a noise literal stream must ride the bitstream as plain bytes:
        // the 417-byte coded table could never pay for itself. Raw keeps
        // the frame within a small overhead of the input size.
        let data = xorshift_bytes(31, 8192);
        let mut lz = Vec::new();
        let mut s = 0usize;
        while s < data.len() {
            let run = (data.len() - s).min(128);
            lz.push((run - 1) as u8);
            lz.extend_from_slice(&data[s..s + run]);
            s += run;
        }
        let frame = tans_encode_tokens(&lz, 1, data.len(), 0);
        assert!(
            frame.len() < data.len() + 100,
            "raw-stream flag not taken: {} bytes for {} of noise",
            frame.len(),
            data.len()
        );
        assert_eq!(tans_decode_tokens(&frame, 1, data.len()).unwrap(), lz);
    }

    #[test]
    fn tans_normalize_invariants() {
        let mut hist = [0u32; 256];
        hist[7] = 1;
        let f = tans_normalize(&hist);
        assert_eq!(f[7] as usize, TANS_L, "single symbol takes every state");
        let mut hist = [0u32; 256];
        for (s, h) in hist.iter_mut().enumerate() {
            *h = s as u32 * 13 + 1; // every symbol present, skewed
        }
        let f = tans_normalize(&hist);
        assert_eq!(f.iter().map(|&v| v as usize).sum::<usize>(), TANS_L);
        assert!(f.iter().all(|&v| v >= 1));
        let mut hist = [0u32; 256];
        hist[0] = 1;
        hist[1] = 1_000_000;
        let f = tans_normalize(&hist);
        assert_eq!(f[0], 1, "rare symbols keep a floor of one state");
        assert_eq!(f[0] as usize + f[1] as usize, TANS_L);
    }

    #[test]
    fn tans_frame_rejects_corruption() {
        let floats: Vec<f32> = (0..2048).map(|i| (i as f32 * 1e-3).sin()).collect();
        let raw = f32s_to_bytes(&floats);
        let enc = Codec::SHUFFLE_DELTA_LZ_TANS.encode(&raw, 4);
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&enc, 4, raw.len()).is_ok());
        // truncations at every boundary class
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&enc[..4], 4, raw.len()).is_err());
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS
            .decode(&enc[..enc.len() - 2], 4, raw.len())
            .is_err());
        // absurd token-stream length
        let mut bad = enc.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&bad, 4, raw.len()).is_err());
        // side buffer pointing past the frame
        let mut bad = enc.clone();
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&bad, 4, raw.len()).is_err());
        // start state out of range
        let side_len = u32::from_le_bytes(enc[5..9].try_into().unwrap()) as usize;
        let mut bad = enc.clone();
        bad[ENTROPY_HEADER_LEN + side_len..ENTROPY_HEADER_LEN + side_len + 2]
            .copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&bad, 4, raw.len()).is_err());
        // bogus stream flag
        let mut bad = enc.clone();
        bad[ENTROPY_HEADER_LEN + side_len + 4] = 0x77;
        assert!(Codec::SHUFFLE_DELTA_LZ_TANS.decode(&bad, 4, raw.len()).is_err());
        // flipping bitstream bits must never decode to the same tokens:
        // either an error (state/bounds check) or a different stream the
        // chunk checksum would reject
        let good = tans_decode_tokens(&enc, 4, raw.len()).unwrap();
        for pos in [enc.len() - 1, enc.len() - 9, enc.len() - 33] {
            let mut bad = enc.clone();
            bad[pos] ^= 0x10;
            match tans_decode_tokens(&bad, 4, raw.len()) {
                Ok(tokens) => assert_ne!(tokens, good, "flip at {pos} undetected"),
                Err(_) => {}
            }
        }
    }

    #[test]
    fn tans_beats_raw_on_structured_streams() {
        // coded tables must actually compress a skewed literal stream
        let skewed: Vec<u8> = (0..16384u32)
            .map(|i| if i % 13 == 0 { (i % 5) as u8 + 1 } else { 0 })
            .collect();
        let mut lz = Vec::new();
        let mut s = 0usize;
        while s < skewed.len() {
            let run = (skewed.len() - s).min(128);
            lz.push((run - 1) as u8);
            lz.extend_from_slice(&skewed[s..s + run]);
            s += run;
        }
        let frame = tans_encode_tokens(&lz, 1, skewed.len(), 0);
        assert!(
            frame.len() * 4 < skewed.len(),
            "{} bytes for {} of skewed data",
            frame.len(),
            skewed.len()
        );
        assert_eq!(tans_decode_tokens(&frame, 1, skewed.len()).unwrap(), lz);
    }

    #[test]
    fn tans_predict_tracks_actual_frame_size() {
        for seed in [3u64, 17] {
            let floats: Vec<f32> =
                (0..8192).map(|i| 1.0 + ((i as f32) * 1e-3 * seed as f32).sin() * 0.25).collect();
            let raw = f32s_to_bytes(&floats);
            let mut filtered = shuffle(&raw, 4);
            delta_encode(&mut filtered);
            let lz = lz_compress_chain(&filtered, LZ_CHAIN_DEPTH);
            let mask = bypass_mask(&filtered, 4, raw.len());
            let predicted = tans_predict_len(&lz, 4, raw.len(), mask);
            let actual = tans_encode_tokens(&lz, 4, raw.len(), mask).len();
            let tol = (actual / 50).max(64);
            assert!(
                predicted.abs_diff(actual) <= tol,
                "predicted {predicted} vs actual {actual} (seed {seed})"
            );
        }
    }

    #[test]
    fn adaptive_prefers_tans_within_margin_on_turbulent() {
        // the canonical turbulent field: tANS lands within TANS_PREFER_PCT
        // of the rc frame, so the selector trades the sliver of ratio for
        // decode speed; the explicit rc pipeline must still be smaller
        let raw = f32s_to_bytes(&crate::util::synth::turbulent_field(
            8192,
            crate::util::synth::TURB_SEED,
        ));
        let enc = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
        assert_eq!(enc.codec, Some(Codec::SHUFFLE_DELTA_LZ_TANS), "turbulent picks tANS");
        let stored = enc.stored.as_ref().unwrap();
        let rc = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
        assert!(rc.len() <= stored.len(), "rc {} vs tans {}", rc.len(), stored.len());
        assert!(
            stored.len() * 100 <= rc.len() * (100 + TANS_PREFER_PCT),
            "give-back above {TANS_PREFER_PCT}%: tans {} rc {}",
            stored.len(),
            rc.len()
        );
        let back = enc.codec.unwrap().decode(stored, 4, raw.len()).unwrap();
        assert_eq!(back, raw);
    }
}
