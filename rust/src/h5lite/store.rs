//! Pluggable byte-store backends for [`H5File`](super::H5File).
//!
//! Every raw byte operation of the format layer — positional reads and
//! writes, grow-only length management and the commit protocol's durability
//! barriers — goes through the [`Store`] trait, so the same format code runs
//! against two backends:
//!
//! * [`DirectFile`] — today's behaviour: positional I/O straight to the file
//!   descriptor, `sync_data` barriers. Every write is on disk when the call
//!   returns; a barrier makes it durable.
//! * [`PagedImage`] — the HDF5 core-VFD pattern: writes land in a 64 MiB-paged
//!   in-memory image and return at memory speed, [`Store::barrier`] snapshots
//!   the dirty byte ranges (contents included) into an ordered batch queue,
//!   and a background flusher thread applies batches to disk strictly in
//!   order — grow, page-aligned writes, `sync_data` — so the on-disk file
//!   always equals a *prefix* of the barrier history plus at most one torn
//!   batch. Because the commit protocol issues the footer barrier before the
//!   superblock barrier, a torn flush always recovers to the last durably
//!   committed epoch.
//!
//! The image never evicts pages; absent pages are demand-faulted from disk
//! (zeros past end of file, matching `set_len` semantics), which is sound
//! because the flusher only ever writes ranges that were dirtied through the
//! image — a page absent from the table is untouched on disk since open.
//! Dropping a [`PagedImage`] issues a final barrier for any un-barriered
//! writes, drains the queue and joins the flusher, so after drop the file is
//! byte-identical to what a [`DirectFile`] run of the same operations leaves.
//!
//! [`Store::set_flush_fault`] is the fault-injection hook behind the
//! crash-recovery suite: it kills the flusher before the write op that would
//! cross a cumulative byte threshold, at an op (page-split) boundary,
//! simulating a crash mid-flush.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};

/// Which [`Store`] backend an [`H5File`](super::H5File) runs on.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum Backing {
    /// Positional I/O straight to the descriptor ([`DirectFile`]).
    #[default]
    Direct,
    /// Paged in-memory image with a background flusher ([`PagedImage`]).
    Paged,
}

/// Page size of the [`PagedImage`] backend. Flusher write ops never cross a
/// page boundary, so fault injection (and a real crash) tears batches at
/// page-aligned op edges.
pub const PAGE_BYTES: u64 = 64 << 20;

/// Counter snapshot of a store's flush machinery (all zeros except
/// `flushed_bytes`/barrier counts on [`DirectFile`], whose writes are
/// synchronous by construction).
#[derive(Clone, Copy, Debug, Default)]
pub struct FlushStats {
    /// Bytes not yet on disk: image-dirty ranges still awaiting a barrier
    /// plus snapshotted batches queued for the flusher (the backlog).
    pub dirty_bytes: u64,
    /// Image pages covered by the not-yet-barriered dirty ranges.
    pub dirty_pages: u64,
    /// Cumulative payload bytes the flusher has written to disk.
    pub flushed_bytes: u64,
    /// Cumulative wall time the flusher spent applying batches.
    pub busy_seconds: f64,
    /// Barriers issued ([`Store::barrier`] calls).
    pub barriers_issued: u64,
    /// Barriers fully applied and fsynced to disk.
    pub barriers_durable: u64,
}

/// The raw byte-store seam under [`H5File`](super::H5File): positional
/// reads/writes, grow-only sizing, and the durability barrier the commit
/// protocol orders its footer/superblock writes with.
pub trait Store: Send + Sync {
    /// Fill `buf` from `offset`; error if the range exceeds the store.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> Result<()>;
    /// Write all of `data` at `offset`, growing the store if needed.
    fn write_all_at(&self, data: &[u8], offset: u64) -> Result<()>;
    /// Current logical length.
    fn len(&self) -> Result<u64>;
    /// Grow to at least `len` (never shrinks — a committed footer must never
    /// be truncated behind a concurrent reader). Growth reads as zeros.
    fn set_len_min(&self, len: u64) -> Result<()>;
    /// Durability barrier: all writes issued before this call become durable
    /// before any write issued after it. [`DirectFile`] syncs inline;
    /// [`PagedImage`] snapshots the dirty ranges into an ordered batch and
    /// returns immediately.
    fn barrier(&self) -> Result<()>;
    /// Block until every issued barrier is durable on disk (errors if the
    /// flusher died). Immediate on [`DirectFile`].
    fn wait_durable(&self) -> Result<()>;
    /// Flush machinery counters.
    fn flush_stats(&self) -> FlushStats;
    /// Which backend this is.
    fn backing(&self) -> Backing;
    /// Fault injection for crash tests: kill the flusher before the write op
    /// that would push cumulative flushed bytes past `after_flushed_bytes`.
    /// Returns false when the backend has no flusher to kill.
    fn set_flush_fault(&self, _after_flushed_bytes: u64) -> bool {
        false
    }
    /// Attach (or with `None`, detach) a tee observing every barrier batch —
    /// the in-transit streaming hook (see [`crate::stream`]). Returns false
    /// when the backend has no batch queue to tee ([`DirectFile`] writes
    /// synchronously; there is no batch stream to observe).
    fn set_batch_sink(&self, _sink: Option<Arc<dyn BatchSink>>) -> bool {
        false
    }
}

/// Observer of the paged backend's ordered batch stream. [`Store::barrier`]
/// calls [`BatchSink::on_batch`] for every snapshotted batch, strictly in
/// sequence order and *before* the barrier returns, so a sink sees exactly
/// the batches the flusher will apply, in the order it will apply them. The
/// flusher calls [`BatchSink::on_durable`] after a batch is fully applied
/// and fsynced. Sequence numbers start at 1 and are dense: batch `seq`
/// becomes durable only after batches `1..seq`.
///
/// Callbacks run on the writer thread (`on_batch`, inside the barrier) and
/// the flusher thread (`on_durable`) respectively — implementations must be
/// quick and must never call back into the store.
pub trait BatchSink: Send + Sync {
    /// A barrier snapshotted this batch: logical file length and the dirty
    /// ranges with their contents. The contents are `Arc`-shared with the
    /// flush queue so a sink retains them by cloning the handles — teeing a
    /// batch costs O(ranges), never a payload copy on the writer thread.
    fn on_batch(&self, seq: u64, set_len: u64, ranges: &[(u64, Arc<Vec<u8>>)]);
    /// The flusher durably applied batch `seq` (grow + writes + fsync done).
    fn on_durable(&self, seq: u64);
}

// ---------------------------------------------------------------------------
// DirectFile
// ---------------------------------------------------------------------------

/// Positional-I/O backend: the pre-refactor behaviour, bit-identical on-disk
/// format and durability (`sync_data` at every barrier).
pub struct DirectFile {
    file: File,
    written: AtomicU64,
    barriers: AtomicU64,
}

impl DirectFile {
    /// Create (truncating) a file at `path`.
    pub fn create(path: &Path) -> Result<DirectFile> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(DirectFile {
            file,
            written: AtomicU64::new(0),
            barriers: AtomicU64::new(0),
        })
    }

    /// Open an existing file read + write.
    pub fn open(path: &Path) -> Result<DirectFile> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        Ok(DirectFile {
            file,
            written: AtomicU64::new(0),
            barriers: AtomicU64::new(0),
        })
    }
}

impl Store for DirectFile {
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> Result<()> {
        self.file.read_exact_at(buf, offset)?;
        Ok(())
    }

    fn write_all_at(&self, data: &[u8], offset: u64) -> Result<()> {
        self.file.write_all_at(data, offset)?;
        self.written.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }

    fn set_len_min(&self, len: u64) -> Result<()> {
        let cur = self.file.metadata()?.len();
        if len > cur {
            self.file.set_len(len)?;
        }
        Ok(())
    }

    fn barrier(&self) -> Result<()> {
        self.file.sync_data()?;
        self.barriers.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn wait_durable(&self) -> Result<()> {
        Ok(())
    }

    fn flush_stats(&self) -> FlushStats {
        let b = self.barriers.load(Ordering::Relaxed);
        FlushStats {
            flushed_bytes: self.written.load(Ordering::Relaxed),
            barriers_issued: b,
            barriers_durable: b,
            ..FlushStats::default()
        }
    }

    fn backing(&self) -> Backing {
        Backing::Direct
    }
}

// ---------------------------------------------------------------------------
// PagedImage
// ---------------------------------------------------------------------------

/// Coalescing set of dirty byte ranges (`offset → len`). Unlike the format
/// layer's free-list, inserts may overlap arbitrarily (rewrites re-dirty the
/// same bytes), so insertion merges every overlapping or touching range.
#[derive(Default)]
struct RangeSet {
    ranges: BTreeMap<u64, u64>,
    bytes: u64,
}

impl RangeSet {
    fn insert(&mut self, off: u64, len: u64) {
        if len == 0 {
            return;
        }
        let mut start = off;
        let mut end = off + len;
        while let Some((&o, &l)) = self.ranges.range(..=end).next_back() {
            if o + l < start {
                break;
            }
            self.ranges.remove(&o);
            self.bytes -= l;
            start = start.min(o);
            end = end.max(o + l);
        }
        self.ranges.insert(start, end - start);
        self.bytes += end - start;
    }
}

/// The in-memory file image: lazily-allocated, never-evicted 64 MiB pages,
/// the logical length, and the dirty ranges since the last barrier.
struct ImageState {
    pages: BTreeMap<u64, Box<[u8]>>,
    len: u64,
    dirty: RangeSet,
}

/// One barrier's worth of work for the flusher: the logical length at the
/// barrier and the dirty ranges *with their contents copied out*. Contents
/// must be snapshotted — the superblock is rewritten every commit and freed
/// extents get reallocated, so flushing live-image bytes for an older batch
/// would leak later-epoch data into an earlier durability point and break
/// the footer-before-superblock ordering.
struct Batch {
    /// Barrier sequence number (1-based, dense): the `seq` reported to any
    /// attached [`BatchSink`] for this batch.
    seq: u64,
    set_len: u64,
    /// Snapshotted contents, `Arc`-shared with any attached [`BatchSink`]
    /// (the tee keeps the handles; the allocation outlives the flush if a
    /// subscriber queue still holds it).
    ranges: Vec<(u64, Arc<Vec<u8>>)>,
    bytes: u64,
}

struct FlushQueue {
    batches: VecDeque<Batch>,
    shutdown: bool,
    /// Why the flusher stopped early (I/O error or injected fault), if it did.
    dead: Option<String>,
}

struct FlushShared {
    queue: OrderedMutex<FlushQueue>,
    cv: OrderedCondvar,
    flushed_bytes: AtomicU64,
    busy_ns: AtomicU64,
    barriers_issued: AtomicU64,
    barriers_durable: AtomicU64,
    queued_bytes: AtomicU64,
    /// Fault injection threshold (`u64::MAX` = disabled).
    fault_after: AtomicU64,
    /// Streaming tee, if attached (see [`BatchSink`]).
    sink: OrderedMutex<Option<Arc<dyn BatchSink>>>,
}

impl FlushShared {
    fn sink(&self) -> Option<Arc<dyn BatchSink>> {
        self.sink.lock().unwrap().clone()
    }
}

/// Paged in-memory image backend: collective writes land in memory,
/// barriers snapshot ordered batches, a background thread streams them to
/// disk. See the module docs for the durability contract.
pub struct PagedImage {
    file: File,
    state: OrderedMutex<ImageState>,
    shared: Arc<FlushShared>,
    flusher: OrderedMutex<Option<JoinHandle<()>>>,
}

impl PagedImage {
    /// Create (truncating) a paged image over the file at `path`.
    pub fn create(path: &Path) -> Result<PagedImage> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        PagedImage::with_file(file)
    }

    /// Open an existing file through a paged image; absent pages fault in
    /// from the current on-disk contents on demand.
    pub fn open(path: &Path) -> Result<PagedImage> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        PagedImage::with_file(file)
    }

    fn with_file(file: File) -> Result<PagedImage> {
        let len = file.metadata()?.len();
        let shared = Arc::new(FlushShared {
            queue: OrderedMutex::new(
                LockRank::StoreQueue,
                FlushQueue {
                    batches: VecDeque::new(),
                    shutdown: false,
                    dead: None,
                },
            ),
            cv: OrderedCondvar::new(),
            flushed_bytes: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            barriers_issued: AtomicU64::new(0),
            barriers_durable: AtomicU64::new(0),
            queued_bytes: AtomicU64::new(0),
            fault_after: AtomicU64::new(u64::MAX),
            sink: OrderedMutex::new(LockRank::StoreSink, None),
        });
        let flush_file = file.try_clone()?;
        let flush_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("h5lite-flush".into())
            .spawn(move || flusher_loop(flush_file, flush_shared))
            .context("h5lite: spawn flusher")?;
        Ok(PagedImage {
            file,
            state: OrderedMutex::new(
                LockRank::StoreState,
                ImageState {
                    pages: BTreeMap::new(),
                    len,
                    dirty: RangeSet::default(),
                },
            ),
            shared,
            flusher: OrderedMutex::new(LockRank::StoreFlusherHandle, Some(handle)),
        })
    }

    /// Demand-fault `page_no` from disk: zeros past end of file. Sound
    /// against the concurrently writing flusher because the flusher only
    /// writes ranges dirtied through the image, whose pages are present —
    /// an absent page's disk bytes are untouched since open.
    fn fault_page(file: &File, pages: &mut BTreeMap<u64, Box<[u8]>>, page_no: u64) -> Result<()> {
        if pages.contains_key(&page_no) {
            return Ok(());
        }
        let mut page = vec![0u8; PAGE_BYTES as usize].into_boxed_slice();
        let mut off = page_no * PAGE_BYTES;
        let mut pos = 0usize;
        while pos < page.len() {
            match file.read_at(&mut page[pos..], off) {
                Ok(0) => break, // end of file: the rest stays zero
                Ok(n) => {
                    pos += n;
                    off += n as u64;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("h5lite: page fault read"),
            }
        }
        pages.insert(page_no, page);
        Ok(())
    }
}

/// Copy `buf.len()` bytes at `off` out of the page table. Callers fault the
/// covered pages first; an absent page here reads as zeros (only reachable
/// for barrier snapshots, whose ranges are always fully paged-in).
fn copy_from_pages(pages: &BTreeMap<u64, Box<[u8]>>, off: u64, buf: &mut [u8]) {
    let mut pos = 0usize;
    while pos < buf.len() {
        let abs = off + pos as u64;
        let page_no = abs / PAGE_BYTES;
        let in_page = (abs % PAGE_BYTES) as usize;
        let n = (PAGE_BYTES as usize - in_page).min(buf.len() - pos);
        match pages.get(&page_no) {
            Some(p) => buf[pos..pos + n].copy_from_slice(&p[in_page..in_page + n]),
            None => buf[pos..pos + n].fill(0),
        }
        pos += n;
    }
}

/// Apply one batch to disk: grow, write each range split at page
/// boundaries (checking the fault threshold before every op), then fsync.
fn apply_batch(file: &File, shared: &FlushShared, batch: &Batch) -> Result<()> {
    let cur = file.metadata().context("h5lite: flusher stat")?.len();
    if batch.set_len > cur {
        file.set_len(batch.set_len).context("h5lite: flusher grow")?;
    }
    for (off, data) in &batch.ranges {
        let mut pos = 0usize;
        while pos < data.len() {
            let abs = off + pos as u64;
            let page_end = (abs / PAGE_BYTES + 1) * PAGE_BYTES;
            let n = ((page_end - abs) as usize).min(data.len() - pos);
            let done = shared.flushed_bytes.load(Ordering::Relaxed);
            let limit = shared.fault_after.load(Ordering::Relaxed);
            if done + n as u64 > limit {
                bail!("injected flush fault after {done} flushed bytes");
            }
            file.write_all_at(&data[pos..pos + n], abs)
                .context("h5lite: flusher write")?;
            shared.flushed_bytes.fetch_add(n as u64, Ordering::Relaxed);
            pos += n;
        }
    }
    file.sync_data().context("h5lite: flusher sync")?;
    Ok(())
}

fn flusher_loop(file: File, shared: Arc<FlushShared>) {
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(b) = q.batches.pop_front() {
                    break b;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let t0 = Instant::now();
        let res = apply_batch(&file, &shared, &batch);
        shared
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        shared.queued_bytes.fetch_sub(batch.bytes, Ordering::Relaxed);
        match res {
            Ok(()) => {
                shared.barriers_durable.fetch_add(1, Ordering::Relaxed);
                if let Some(sink) = shared.sink() {
                    sink.on_durable(batch.seq);
                }
                shared.cv.notify_all();
            }
            Err(e) => {
                // die at the op boundary: later batches stay unapplied, so
                // the disk holds a strict prefix of the barrier history
                // plus this one torn batch
                shared.queue.lock().unwrap().dead = Some(e.to_string());
                shared.cv.notify_all();
                return;
            }
        }
    }
}

impl Store for PagedImage {
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> Result<()> {
        if buf.is_empty() {
            return Ok(());
        }
        let mut st = self.state.lock().unwrap();
        let end = offset + buf.len() as u64;
        if end > st.len {
            bail!("h5lite: read [{offset}, {end}) past image end {}", st.len);
        }
        for page_no in offset / PAGE_BYTES..=(end - 1) / PAGE_BYTES {
            PagedImage::fault_page(&self.file, &mut st.pages, page_no)?;
        }
        copy_from_pages(&st.pages, offset, buf);
        Ok(())
    }

    fn write_all_at(&self, data: &[u8], offset: u64) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let mut st = self.state.lock().unwrap();
        let mut pos = 0usize;
        while pos < data.len() {
            let abs = offset + pos as u64;
            let page_no = abs / PAGE_BYTES;
            let in_page = (abs % PAGE_BYTES) as usize;
            let n = (PAGE_BYTES as usize - in_page).min(data.len() - pos);
            if in_page == 0 && n == PAGE_BYTES as usize {
                // whole-page overwrite: skip the disk fault
                st.pages.entry(page_no).or_insert_with(|| {
                    vec![0u8; PAGE_BYTES as usize].into_boxed_slice()
                });
            } else {
                PagedImage::fault_page(&self.file, &mut st.pages, page_no)?;
            }
            let page = st.pages.get_mut(&page_no).unwrap();
            page[in_page..in_page + n].copy_from_slice(&data[pos..pos + n]);
            pos += n;
        }
        st.len = st.len.max(offset + data.len() as u64);
        st.dirty.insert(offset, data.len() as u64);
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        Ok(self.state.lock().unwrap().len)
    }

    fn set_len_min(&self, len: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        st.len = st.len.max(len);
        Ok(())
    }

    fn barrier(&self) -> Result<()> {
        {
            let q = self.shared.queue.lock().unwrap();
            if let Some(why) = &q.dead {
                bail!("h5lite: flusher stopped: {why}");
            }
        }
        let batch = {
            let mut st = self.state.lock().unwrap();
            let ranges: Vec<(u64, Arc<Vec<u8>>)> = st
                .dirty
                .ranges
                .iter()
                .map(|(&o, &l)| {
                    let mut buf = vec![0u8; l as usize];
                    copy_from_pages(&st.pages, o, &mut buf);
                    (o, Arc::new(buf))
                })
                .collect();
            let bytes = st.dirty.bytes;
            st.dirty = RangeSet::default();
            Batch {
                seq: 0, // assigned under the queue lock below
                set_len: st.len,
                ranges,
                bytes,
            }
        };
        let mut batch = batch;
        let mut q = self.shared.queue.lock().unwrap();
        batch.seq = self.shared.barriers_issued.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.queued_bytes.fetch_add(batch.bytes, Ordering::Relaxed);
        // tee under the queue lock: sinks see batches strictly in seq order,
        // and always before the flusher could report the batch durable
        if let Some(sink) = self.shared.sink() {
            sink.on_batch(batch.seq, batch.set_len, &batch.ranges);
        }
        q.batches.push_back(batch);
        self.shared.cv.notify_all();
        Ok(())
    }

    fn wait_durable(&self) -> Result<()> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(why) = &q.dead {
                bail!("h5lite: flusher stopped: {why}");
            }
            let issued = self.shared.barriers_issued.load(Ordering::Relaxed);
            let durable = self.shared.barriers_durable.load(Ordering::Relaxed);
            if q.batches.is_empty() && issued == durable {
                return Ok(());
            }
            q = self.shared.cv.wait(q).unwrap();
        }
    }

    fn flush_stats(&self) -> FlushStats {
        let (dirty_bytes, dirty_pages) = {
            let st = self.state.lock().unwrap();
            let pages: BTreeSet<u64> = st
                .dirty
                .ranges
                .iter()
                .flat_map(|(&o, &l)| o / PAGE_BYTES..=(o + l - 1) / PAGE_BYTES)
                .collect();
            (st.dirty.bytes, pages.len() as u64)
        };
        FlushStats {
            dirty_bytes: dirty_bytes + self.shared.queued_bytes.load(Ordering::Relaxed),
            dirty_pages,
            flushed_bytes: self.shared.flushed_bytes.load(Ordering::Relaxed),
            busy_seconds: self.shared.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            barriers_issued: self.shared.barriers_issued.load(Ordering::Relaxed),
            barriers_durable: self.shared.barriers_durable.load(Ordering::Relaxed),
        }
    }

    fn backing(&self) -> Backing {
        Backing::Paged
    }

    fn set_flush_fault(&self, after_flushed_bytes: u64) -> bool {
        self.shared
            .fault_after
            .store(after_flushed_bytes, Ordering::Relaxed);
        true
    }

    fn set_batch_sink(&self, sink: Option<Arc<dyn BatchSink>>) -> bool {
        *self.shared.sink.lock().unwrap() = sink;
        true
    }
}

impl Drop for PagedImage {
    fn drop(&mut self) {
        // final barrier so un-barriered writes reach disk (matching
        // DirectFile, where every write is on disk immediately), then drain
        // and join; a dead flusher just leaves the torn state for recovery
        let _ = self.barrier();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        // take the handle out of its lock, then drop the guard BEFORE
        // joining: joining a thread while holding any lock is the
        // join-under-lock shape the rank audit exists to keep out (the
        // joined thread only needs StoreQueue here, but the pattern rots)
        let handle = self.flusher.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::Mutex;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("h5lite_store_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn range_set_merges_overlaps_and_touches() {
        let mut rs = RangeSet::default();
        rs.insert(100, 50);
        rs.insert(150, 10); // touching
        assert_eq!(rs.ranges.len(), 1);
        assert_eq!(rs.ranges[&100], 60);
        assert_eq!(rs.bytes, 60);
        rs.insert(120, 100); // overlapping, extends the end
        assert_eq!(rs.ranges.len(), 1);
        assert_eq!(rs.ranges[&100], 120);
        rs.insert(500, 5); // disjoint
        assert_eq!(rs.ranges.len(), 2);
        rs.insert(90, 500); // swallows everything
        assert_eq!(rs.ranges.len(), 1);
        assert_eq!(rs.ranges[&90], 500);
        assert_eq!(rs.bytes, 500);
        rs.insert(90, 10); // fully contained: no change
        assert_eq!(rs.ranges[&90], 500);
        assert_eq!(rs.bytes, 500);
    }

    #[test]
    fn paged_image_write_read_drop_roundtrip() {
        let p = tmp("roundtrip");
        {
            let img = PagedImage::create(&p).unwrap();
            img.write_all_at(b"hello", 10).unwrap();
            img.write_all_at(b"world", 100).unwrap();
            img.set_len_min(200).unwrap();
            assert_eq!(img.len().unwrap(), 200);
            let mut buf = [0u8; 5];
            img.read_exact_at(&mut buf, 10).unwrap();
            assert_eq!(&buf, b"hello");
            // unwritten bytes read as zeros
            let mut z = [9u8; 4];
            img.read_exact_at(&mut z, 50).unwrap();
            assert_eq!(z, [0u8; 4]);
            // read past the logical end fails
            let mut over = [0u8; 8];
            assert!(img.read_exact_at(&mut over, 197).is_err());
            img.barrier().unwrap();
            img.wait_durable().unwrap();
        }
        // after drop the disk file holds the image bit-exact
        let disk = std::fs::read(&p).unwrap();
        assert_eq!(disk.len(), 200);
        assert_eq!(&disk[10..15], b"hello");
        assert_eq!(&disk[100..105], b"world");
        assert!(disk[50..60].iter().all(|&b| b == 0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn paged_image_faults_existing_file_contents() {
        let p = tmp("fault");
        std::fs::write(&p, vec![7u8; 1000]).unwrap();
        let img = PagedImage::open(&p).unwrap();
        assert_eq!(img.len().unwrap(), 1000);
        let mut buf = [0u8; 10];
        img.read_exact_at(&mut buf, 500).unwrap();
        assert_eq!(buf, [7u8; 10]);
        // a write is visible through the image before any flush
        img.write_all_at(&[1, 2, 3], 500).unwrap();
        img.read_exact_at(&mut buf, 500).unwrap();
        assert_eq!(&buf[..3], &[1, 2, 3]);
        drop(img);
        assert_eq!(&std::fs::read(&p).unwrap()[500..503], &[1, 2, 3]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn barrier_snapshots_are_ordered_and_content_stable() {
        // overwrite the same bytes across two barriers: the disk must end at
        // the *second* content even though both batches cover the range, and
        // killing the flusher between them must leave the first
        let p = tmp("order");
        let img = PagedImage::create(&p).unwrap();
        img.write_all_at(&[1u8; 64], 0).unwrap();
        img.barrier().unwrap();
        img.write_all_at(&[2u8; 64], 0).unwrap();
        img.barrier().unwrap();
        img.wait_durable().unwrap();
        let stats = img.flush_stats();
        assert_eq!(stats.barriers_issued, 2);
        assert_eq!(stats.barriers_durable, 2);
        assert_eq!(stats.flushed_bytes, 128, "both snapshots must flush");
        assert_eq!(stats.dirty_bytes, 0);
        drop(img);
        assert_eq!(&std::fs::read(&p).unwrap()[..], &[2u8; 64]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn flush_fault_kills_at_op_boundary_and_surfaces() {
        let p = tmp("kill");
        let img = PagedImage::create(&p).unwrap();
        img.write_all_at(&[5u8; 256], 0).unwrap();
        img.barrier().unwrap();
        img.wait_durable().unwrap();
        // second batch dies before its (single) op crosses the threshold
        assert!(img.set_flush_fault(256));
        img.write_all_at(&[6u8; 256], 0).unwrap();
        img.barrier().unwrap();
        assert!(img.wait_durable().is_err(), "fault must surface");
        // later barriers error instead of silently queueing forever
        img.write_all_at(&[7u8; 8], 0).unwrap();
        assert!(img.barrier().is_err());
        let stats = img.flush_stats();
        assert_eq!(stats.barriers_durable, 1);
        drop(img);
        // the torn batch never applied: disk holds the first batch intact
        assert_eq!(&std::fs::read(&p).unwrap()[..256], &[5u8; 256]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn batch_sink_sees_ordered_batches_then_durability() {
        struct Rec {
            events: Mutex<Vec<(bool, u64)>>, // (is_durable, seq)
            bytes: AtomicU64,
        }
        impl BatchSink for Rec {
            fn on_batch(&self, seq: u64, set_len: u64, ranges: &[(u64, Arc<Vec<u8>>)]) {
                assert!(set_len > 0);
                for (_, d) in ranges {
                    self.bytes.fetch_add(d.len() as u64, Ordering::Relaxed);
                }
                self.events.lock().unwrap().push((false, seq));
            }
            fn on_durable(&self, seq: u64) {
                self.events.lock().unwrap().push((true, seq));
            }
        }
        let p = tmp("sink");
        let img = PagedImage::create(&p).unwrap();
        let rec = Arc::new(Rec {
            events: Mutex::new(Vec::new()),
            bytes: AtomicU64::new(0),
        });
        assert!(img.set_batch_sink(Some(rec.clone())));
        img.write_all_at(&[1u8; 64], 0).unwrap();
        img.barrier().unwrap();
        img.write_all_at(&[2u8; 32], 64).unwrap();
        img.barrier().unwrap();
        img.wait_durable().unwrap();
        let ev = rec.events.lock().unwrap().clone();
        // publish of seq N always precedes its durability, seqs are dense
        let publishes: Vec<u64> = ev.iter().filter(|(d, _)| !d).map(|&(_, s)| s).collect();
        let durables: Vec<u64> = ev.iter().filter(|(d, _)| *d).map(|&(_, s)| s).collect();
        assert_eq!(publishes, vec![1, 2]);
        assert_eq!(durables, vec![1, 2]);
        for seq in 1..=2u64 {
            let pub_at = ev.iter().position(|&e| e == (false, seq)).unwrap();
            let dur_at = ev.iter().position(|&e| e == (true, seq)).unwrap();
            assert!(pub_at < dur_at, "publish must precede durability");
        }
        assert_eq!(rec.bytes.load(Ordering::Relaxed), 96);
        // detaching stops the tee
        assert!(img.set_batch_sink(None));
        img.write_all_at(&[3u8; 8], 0).unwrap();
        img.barrier().unwrap();
        img.wait_durable().unwrap();
        assert_eq!(rec.events.lock().unwrap().len(), ev.len());
        drop(img);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn direct_file_stats_count_writes_and_barriers() {
        let p = tmp("direct");
        let f = DirectFile::create(&p).unwrap();
        f.write_all_at(&[1u8; 100], 0).unwrap();
        f.barrier().unwrap();
        f.set_len_min(50).unwrap(); // never shrinks
        assert_eq!(f.len().unwrap(), 100);
        let s = f.flush_stats();
        assert_eq!(s.flushed_bytes, 100);
        assert_eq!(s.barriers_issued, 1);
        assert_eq!(s.barriers_durable, 1);
        assert_eq!(s.dirty_bytes, 0);
        assert!(!f.set_flush_fault(0), "no flusher to kill");
        assert!(!f.set_batch_sink(None), "no batch queue to tee");
        f.wait_durable().unwrap();
        drop(f);
        std::fs::remove_file(&p).ok();
    }
}
