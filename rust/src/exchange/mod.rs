//! The three-phase **communication schema** (paper §2.2, ref. [12]).
//!
//! 1. **Bottom-up** — every d-grid that was not updated during the
//!    computation phase (i.e. every interior l-grid node) is set to the
//!    averaged values of its child d-grids. This doubles as the multigrid
//!    *restriction* operator.
//! 2. **Horizontal** — face-adjacent d-grids at the same level exchange
//!    ghost layers; physical-boundary faces apply the domain BCs.
//! 3. **Top-down** — ghost layers across level jumps (adaptive refinement
//!    edges) are set: fine grids receive injected coarse values, coarse
//!    grids receive area-averaged fine values (flux conservation across
//!    d-grid boundaries). This doubles as the *prolongation* side.
//!
//! Ranks are logical: all d-grids live in one address space, but every
//! transfer whose endpoints reside on different ranks is accounted in
//! [`ExchangeStats`] — these byte counts feed the cluster model that
//! regenerates the paper's Fig 2a.

use crate::nbs::{Face, NeighbourhoodServer, Neighbour, ALL_FACES};
use crate::physics::bc::{apply_face_bc, DomainBc};
use crate::tree::dgrid::{pidx, DGrid, FieldSet};
use crate::DGRID_N;

/// Which field generation an exchange operates on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Gen {
    Cur,
    Prev,
    Temp,
}

impl Gen {
    pub fn of(self, g: &DGrid) -> &FieldSet {
        match self {
            Gen::Cur => &g.cur,
            Gen::Prev => &g.prev,
            Gen::Temp => &g.temp,
        }
    }

    pub fn of_mut(self, g: &mut DGrid) -> &mut FieldSet {
        match self {
            Gen::Cur => &mut g.cur,
            Gen::Prev => &mut g.prev,
            Gen::Temp => &mut g.temp,
        }
    }
}

/// Traffic accounting for one exchange pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExchangeStats {
    /// Ghost-layer messages between distinct ranks.
    pub messages: u64,
    /// Bytes crossing rank boundaries.
    pub cross_rank_bytes: u64,
    /// Total ghost bytes moved (including rank-local copies).
    pub total_bytes: u64,
}

impl ExchangeStats {
    fn account(&mut self, src_rank: u32, dst_rank: u32, bytes: u64) {
        self.total_bytes += bytes;
        if src_rank != dst_rank {
            self.messages += 1;
            self.cross_rank_bytes += bytes;
        }
    }

    pub fn merge(&mut self, o: &ExchangeStats) {
        self.messages += o.messages;
        self.cross_rank_bytes += o.cross_rank_bytes;
        self.total_bytes += o.total_bytes;
    }
}

const N: usize = DGRID_N;
const LAYER: usize = N * N;

/// Read the interior layer adjacent to `face` into `buf` (N×N values,
/// indexed `a·N+b` over the two tangential axes in ascending axis order).
pub(crate) fn read_face_layer(fs: &FieldSet, v: usize, face: Face, buf: &mut [f32]) {
    let f = fs.var(v);
    let fixed = if face.dir() < 0 { 1 } else { N };
    for a in 0..N {
        for b in 0..N {
            buf[a * N + b] = match face.axis() {
                0 => f[pidx(fixed, a + 1, b + 1)],
                1 => f[pidx(a + 1, fixed, b + 1)],
                _ => f[pidx(a + 1, b + 1, fixed)],
            };
        }
    }
}

/// Write `buf` (N×N) into the ghost layer of `face`.
pub(crate) fn write_ghost_layer(fs: &mut FieldSet, v: usize, face: Face, buf: &[f32]) {
    let f = fs.var_mut(v);
    let fixed = if face.dir() < 0 { 0 } else { N + 1 };
    for a in 0..N {
        for b in 0..N {
            let val = buf[a * N + b];
            match face.axis() {
                0 => f[pidx(fixed, a + 1, b + 1)] = val,
                1 => f[pidx(a + 1, fixed, b + 1)] = val,
                _ => f[pidx(a + 1, b + 1, fixed)] = val,
            }
        }
    }
}

/// Tangential axes of a face, in ascending order (matches the layer layout).
pub(crate) fn tangential(face: Face) -> (usize, usize) {
    match face.axis() {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    }
}

/// Phase 1 — bottom-up: restrict child d-grids into their parents,
/// deepest-first so multi-level trees propagate correctly.
pub fn bottom_up(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    gen: Gen,
    vars: &[usize],
    stats: &mut ExchangeStats,
) {
    let max_d = nbs.tree.max_depth();
    for d in (0..max_d).rev() {
        for idx in nbs.tree.nodes_at_depth(d) {
            let node = nbs.tree.node(idx);
            if node.is_leaf() {
                continue;
            }
            let children = node.children.clone();
            let parent_rank = node.rank;
            for &ch in &children {
                let child_node = nbs.tree.node(ch);
                let oct = child_node.loc.octant();
                let child_rank = child_node.rank;
                let (oi, oj, ok) = (
                    ((oct >> 2) & 1) as usize,
                    ((oct >> 1) & 1) as usize,
                    (oct & 1) as usize,
                );
                for &v in vars {
                    // restrict child interior (N³) into the parent octant
                    let mut block = vec![0.0f32; (N / 2) * (N / 2) * (N / 2)];
                    {
                        let cfs = gen.of(&grids[ch as usize]);
                        let mut interior = vec![0.0f32; N * N * N];
                        cfs.extract_interior(v, &mut interior);
                        crate::physics::restrict_block(N, &interior, &mut block);
                    }
                    let pfs = gen.of_mut(&mut grids[idx as usize]);
                    let f = pfs.var_mut(v);
                    let m = N / 2;
                    for i in 0..m {
                        for j in 0..m {
                            for k in 0..m {
                                f[pidx(oi * m + i + 1, oj * m + j + 1, ok * m + k + 1)] =
                                    block[(i * m + j) * m + k];
                            }
                        }
                    }
                    stats.account(child_rank, parent_rank, (m * m * m * 4) as u64);
                }
            }
        }
    }
}

/// Phase 2 — horizontal: same-level ghost exchange + physical boundaries.
///
/// Parallel across receiving grids (perf pass): every task writes only its
/// own grid's ghost cells and reads only neighbours' interiors.
pub fn horizontal(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    gen: Gen,
    vars: &[usize],
    bc: &DomainBc,
    stats: &mut ExchangeStats,
) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let msgs = AtomicU64::new(0);
    let cross = AtomicU64::new(0);
    let total = AtomicU64::new(0);
    // aliased — same me-mutable/peer-shared discipline as
    // `solver::level_exchange`
    let gptr = crate::util::SendPtr::new_aliased(grids);
    let n = nbs.tree.len();
    crate::util::parallel_for(n, |i| {
        let idx = i as u32;
        let mut buf = [0.0f32; LAYER];
        // SAFETY: see `solver::level_exchange` — ghost writes are
        // task-exclusive, interior reads are unwritten in this pass.
        let me = unsafe { &mut gptr.slice(i, 1)[0] };
        for face in ALL_FACES {
            match nbs.neighbour(idx, face) {
                Neighbour::Boundary => {
                    apply_face_bc(gen.of_mut(me), face, bc.face(face));
                }
                Neighbour::Same { idx: nb } => {
                    // SAFETY: shared read of a neighbour's interior —
                    // cells no task writes in this pass (aliased pointer).
                    let peer = unsafe { &gptr.slice(nb as usize, 1)[0] };
                    let src_rank = nbs.tree.node(nb).rank;
                    let dst_rank = nbs.tree.node(idx).rank;
                    for &v in vars {
                        read_face_layer(gen.of(peer), v, face.opposite(), &mut buf);
                        write_ghost_layer(gen.of_mut(me), v, face, &buf);
                        total.fetch_add((LAYER * 4) as u64, Ordering::Relaxed);
                        if src_rank != dst_rank {
                            msgs.fetch_add(1, Ordering::Relaxed);
                            cross.fetch_add((LAYER * 4) as u64, Ordering::Relaxed);
                        }
                    }
                }
                _ => {} // cross-level handled in phase 3
            }
        }
    });
    stats.messages += msgs.into_inner();
    stats.cross_rank_bytes += cross.into_inner();
    stats.total_bytes += total.into_inner();
}

/// Phase 3 — top-down: ghost layers across refinement edges.
pub fn top_down(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    gen: Gen,
    vars: &[usize],
    stats: &mut ExchangeStats,
) {
    let mut buf = vec![0.0f32; LAYER];
    let mut src = vec![0.0f32; LAYER];
    for idx in 0..grids.len() as u32 {
        let node = nbs.tree.node(idx);
        if !node.is_leaf() {
            continue; // only leaves sit on refinement edges as receivers here
        }
        for face in ALL_FACES {
            match nbs.neighbour(idx, face) {
                Neighbour::Coarser { idx: nb } => {
                    // fine ghost ← injected coarse values: each fine ghost
                    // cell (a,b) reads coarse cell (off + a/2) on the layer
                    // adjacent to the shared face.
                    let (a_axis, b_axis) = tangential(face);
                    let (ci, cj, ck) = node.loc.coords();
                    let coords = [ci as usize, cj as usize, ck as usize];
                    let off_a = (coords[a_axis] % 2) * (N / 2);
                    let off_b = (coords[b_axis] % 2) * (N / 2);
                    let src_rank = nbs.tree.node(nb).rank;
                    let dst_rank = node.rank;
                    for &v in vars {
                        read_face_layer(gen.of(&grids[nb as usize]), v, face.opposite(), &mut src);
                        for a in 0..N {
                            for b in 0..N {
                                buf[a * N + b] =
                                    src[(off_a + a / 2) * N + (off_b + b / 2)];
                            }
                        }
                        write_ghost_layer(gen.of_mut(&mut grids[idx as usize]), v, face, &buf);
                        // only half the coarse layer is actually needed
                        stats.account(src_rank, dst_rank, (LAYER * 4 / 4) as u64);
                    }
                }
                Neighbour::Finer { idx: kids } => {
                    // coarse ghost ← area-averaged fine values (conservative)
                    let (a_axis, b_axis) = tangential(face);
                    let dst_rank = node.rank;
                    for &v in vars {
                        for a in 0..N {
                            for b in 0..N {
                                buf[a * N + b] = 0.0;
                            }
                        }
                        for &ch in &kids {
                            let chn = nbs.tree.node(ch);
                            let (ki, kj, kk) = chn.loc.coords();
                            let kcoords = [ki as usize, kj as usize, kk as usize];
                            let off_a = (kcoords[a_axis] % 2) * (N / 2);
                            let off_b = (kcoords[b_axis] % 2) * (N / 2);
                            read_face_layer(
                                gen.of(&grids[ch as usize]),
                                v,
                                face.opposite(),
                                &mut src,
                            );
                            for a in 0..N / 2 {
                                for b in 0..N / 2 {
                                    let avg = 0.25
                                        * (src[(2 * a) * N + 2 * b]
                                            + src[(2 * a) * N + 2 * b + 1]
                                            + src[(2 * a + 1) * N + 2 * b]
                                            + src[(2 * a + 1) * N + 2 * b + 1]);
                                    buf[(off_a + a) * N + off_b + b] = avg;
                                }
                            }
                            stats.account(chn.rank, dst_rank, (LAYER * 4 / 4) as u64);
                        }
                        write_ghost_layer(gen.of_mut(&mut grids[idx as usize]), v, face, &buf);
                    }
                }
                _ => {}
            }
        }
    }
}

/// A full communication phase: bottom-up, horizontal, top-down (paper order).
pub fn full_exchange(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    gen: Gen,
    vars: &[usize],
    bc: &DomainBc,
) -> ExchangeStats {
    let mut stats = ExchangeStats::default();
    bottom_up(nbs, grids, gen, vars, &mut stats);
    horizontal(nbs, grids, gen, vars, bc, &mut stats);
    top_down(nbs, grids, gen, vars, &mut stats);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::physics::bc::DomainBc;
    use crate::tree::sfc;
    use crate::tree::uid::LocCode;
    use crate::tree::{BBox, SpaceTree};
    use crate::var;

    fn setup(depth: u32, ranks: u32) -> (NeighbourhoodServer, Vec<DGrid>) {
        let mut t = SpaceTree::full(BBox::unit(), depth);
        sfc::partition(&mut t, ranks);
        let grids: Vec<DGrid> = t.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        (NeighbourhoodServer::new(t), grids)
    }

    /// Fill each grid's interior of var v with its arena index as constant.
    fn paint(grids: &mut [DGrid], v: usize) {
        for (i, g) in grids.iter_mut().enumerate() {
            let data = vec![i as f32; crate::DGRID_CELLS];
            g.cur.set_interior(v, &data);
        }
    }

    #[test]
    fn horizontal_fills_ghosts_with_neighbour_values() {
        let (nbs, mut grids) = setup(1, 1);
        paint(&mut grids, var::P);
        let mut stats = ExchangeStats::default();
        horizontal(
            &nbs,
            &mut grids,
            Gen::Cur,
            &[var::P],
            &DomainBc::all_walls(),
            &mut stats,
        );
        // child 0 (octant 000) has +x neighbour octant 100
        let a = nbs.tree.lookup(LocCode::ROOT.child(0)).unwrap();
        let b = nbs.tree.lookup(LocCode::ROOT.child(0b100)).unwrap();
        let ghost = grids[a as usize].cur.var(var::P)[pidx(N + 1, 5, 5)];
        assert_eq!(ghost, b as f32);
        assert!(stats.total_bytes > 0);
    }

    #[test]
    fn horizontal_boundary_applies_bc() {
        let (nbs, mut grids) = setup(1, 1);
        paint(&mut grids, var::P);
        let mut stats = ExchangeStats::default();
        horizontal(
            &nbs,
            &mut grids,
            Gen::Cur,
            &[var::P],
            &DomainBc::all_walls(),
            &mut stats,
        );
        // -x face of octant 000 is a wall ⇒ Neumann for P
        let a = nbs.tree.lookup(LocCode::ROOT.child(0)).unwrap() as usize;
        assert_eq!(
            grids[a].cur.var(var::P)[pidx(0, 5, 5)],
            grids[a].cur.var(var::P)[pidx(1, 5, 5)]
        );
    }

    #[test]
    fn cross_rank_traffic_counted_only_across_ranks() {
        let (nbs1, mut g1) = setup(1, 1);
        paint(&mut g1, var::P);
        let (nbs8, mut g8) = setup(1, 9); // 9 nodes, 9 ranks ⇒ every pair crosses
        paint(&mut g8, var::P);
        let mut s1 = ExchangeStats::default();
        let mut s8 = ExchangeStats::default();
        horizontal(&nbs1, &mut g1, Gen::Cur, &[var::P], &DomainBc::all_walls(), &mut s1);
        horizontal(&nbs8, &mut g8, Gen::Cur, &[var::P], &DomainBc::all_walls(), &mut s8);
        assert_eq!(s1.messages, 0);
        assert_eq!(s1.cross_rank_bytes, 0);
        assert!(s8.messages > 0);
        assert_eq!(s1.total_bytes, s8.total_bytes);
    }

    #[test]
    fn bottom_up_restricts_children_average() {
        let (nbs, mut grids) = setup(1, 1);
        // children constant 1..8 ⇒ parent octants hold each child's value
        for oct in 0..8u8 {
            let idx = nbs.tree.lookup(LocCode::ROOT.child(oct)).unwrap() as usize;
            let data = vec![(oct + 1) as f32; crate::DGRID_CELLS];
            grids[idx].cur.set_interior(var::T, &data);
        }
        let mut stats = ExchangeStats::default();
        bottom_up(&nbs, &mut grids, Gen::Cur, &[var::T], &mut stats);
        let root = &grids[0].cur;
        // octant 000 → parent cells [1..8]³ hold child-1 value
        assert_eq!(root.var(var::T)[pidx(1, 1, 1)], 1.0);
        assert_eq!(root.var(var::T)[pidx(8, 8, 8)], 1.0);
        // octant 111 (child 8)
        assert_eq!(root.var(var::T)[pidx(16, 16, 16)], 8.0);
        assert_eq!(stats.total_bytes, 8 * (8 * 8 * 8 * 4));
    }

    #[test]
    fn bottom_up_multi_level_propagates() {
        let (nbs, mut grids) = setup(2, 1);
        for idx in nbs.tree.nodes_at_depth(2) {
            let data = vec![2.0f32; crate::DGRID_CELLS];
            grids[idx as usize].cur.set_interior(var::U, &data);
        }
        let mut stats = ExchangeStats::default();
        bottom_up(&nbs, &mut grids, Gen::Cur, &[var::U], &mut stats);
        assert_eq!(grids[0].cur.var(var::U)[pidx(8, 8, 8)], 2.0);
    }

    #[test]
    fn top_down_coarse_to_fine_injection() {
        // adaptive: child 0 refined, its sibling at same level not
        let mut t = SpaceTree::root_only(BBox::unit());
        t.refine(0);
        let c0 = t.lookup(LocCode::ROOT.child(0)).unwrap();
        t.refine(c0);
        sfc::partition(&mut t, 1);
        let nbs = NeighbourhoodServer::new(t);
        let mut grids: Vec<DGrid> =
            nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        // paint the coarse +x sibling (octant 100) with 7.0
        let c4 = nbs.tree.lookup(LocCode::ROOT.child(0b100)).unwrap() as usize;
        let data = vec![7.0f32; crate::DGRID_CELLS];
        grids[c4].cur.set_interior(var::P, &data);
        let mut stats = ExchangeStats::default();
        top_down(&nbs, &mut grids, Gen::Cur, &[var::P], &mut stats);
        // the depth-2 grid at +x face of the refined region gets ghost 7.0
        let fine = nbs
            .tree
            .lookup(LocCode::from_coords(2, 1, 0, 0).unwrap())
            .unwrap() as usize;
        assert_eq!(grids[fine].cur.var(var::P)[pidx(N + 1, 5, 5)], 7.0);
    }

    #[test]
    fn top_down_fine_to_coarse_average_conserves() {
        let mut t = SpaceTree::root_only(BBox::unit());
        t.refine(0);
        let c0 = t.lookup(LocCode::ROOT.child(0)).unwrap();
        t.refine(c0);
        sfc::partition(&mut t, 1);
        let nbs = NeighbourhoodServer::new(t);
        let mut grids: Vec<DGrid> =
            nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        // the four depth-2 grids on c0's +x face hold value 4.0
        for (j, k) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
            let idx = nbs
                .tree
                .lookup(LocCode::from_coords(2, 1, j, k).unwrap())
                .unwrap() as usize;
            let data = vec![4.0f32; crate::DGRID_CELLS];
            grids[idx].cur.set_interior(var::U, &data);
        }
        let mut stats = ExchangeStats::default();
        top_down(&nbs, &mut grids, Gen::Cur, &[var::U], &mut stats);
        // coarse sibling c4 (octant 100) sees averaged 4.0 in its -x ghost
        let c4 = nbs.tree.lookup(LocCode::ROOT.child(0b100)).unwrap() as usize;
        for a in 1..=N {
            for b in 1..=N {
                assert_eq!(grids[c4].cur.var(var::U)[pidx(0, a, b)], 4.0);
            }
        }
    }

    #[test]
    fn full_exchange_runs_all_phases() {
        let (nbs, mut grids) = setup(2, 4);
        paint(&mut grids, var::P);
        let stats = full_exchange(
            &nbs,
            &mut grids,
            Gen::Cur,
            &[var::P],
            &DomainBc::all_walls(),
        );
        assert!(stats.total_bytes > 0);
        assert!(stats.messages > 0);
    }

    #[test]
    fn exchange_stats_merge() {
        let mut a = ExchangeStats {
            messages: 1,
            cross_rank_bytes: 10,
            total_bytes: 20,
        };
        a.merge(&ExchangeStats {
            messages: 2,
            cross_rank_bytes: 5,
            total_bytes: 7,
        });
        assert_eq!(a.messages, 3);
        assert_eq!(a.cross_rank_bytes, 15);
        assert_eq!(a.total_bytes, 27);
    }
}
