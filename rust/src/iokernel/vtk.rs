//! The legacy **one-binary-VTK-file-per-process** output path (paper §3's
//! motivation): every rank dumps its grids into an individual binary file
//! per time step. This is the baseline the shared-file kernel replaces —
//! kept (a) as a working fallback exporter and (b) to regenerate the
//! motivating comparison (file counts, contention) in the benches.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{IoTuning, Machine, WriteWorkload};
use crate::exchange::Gen;
use crate::tree::dgrid::DGrid;
use crate::tree::sfc::Partition;
use crate::tree::SpaceTree;
use crate::util::parallel_for;
use crate::{DGRID_CELLS, DGRID_N, NVAR};

/// Report of a per-process VTK-style dump.
#[derive(Clone, Copy, Debug)]
pub struct VtkReport {
    pub files_written: u64,
    pub bytes: u64,
    pub real_seconds: f64,
    /// Modelled duration on the target machine (independent I/O).
    pub modelled_seconds: f64,
    pub modelled_bandwidth: f64,
}

/// Write one file per rank under `dir`, named `part_<rank>_t<t>.vtk`.
/// The payload is a minimal "structured points" style binary layout: a
/// text header followed by raw little-endian cell data for all leaf grids
/// of the rank.
pub fn write_per_process(
    dir: &Path,
    machine: &Machine,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    t: f64,
) -> Result<VtkReport> {
    std::fs::create_dir_all(dir).context("vtk: create output dir")?;
    let t0 = Instant::now();
    let offsets = part.row_offsets();
    let paths: Vec<PathBuf> = (0..part.n_ranks)
        .map(|r| dir.join(format!("part_{r:05}_t{t:.6}.vtk")))
        .collect();
    let total_bytes = std::sync::atomic::AtomicU64::new(0);
    let errors = std::sync::Mutex::new(Vec::new());
    parallel_for(part.n_ranks as usize, |r| {
        let run = || -> Result<u64> {
            let mut f = std::fs::File::create(&paths[r]).context("vtk: create")?;
            let row0 = offsets[r] as usize;
            let count = part.counts[r] as usize;
            let mut written = 0u64;
            let header = format!(
                "# mpfluid binary vtk-style dump\nrank {r} t {t:.6} grids {count} n {DGRID_N} vars {NVAR}\n"
            );
            f.write_all(header.as_bytes())?;
            written += header.len() as u64;
            let mut interior = vec![0.0f32; DGRID_CELLS];
            for &idx in &part.curve[row0..row0 + count] {
                let node = tree.node(idx);
                if !node.is_leaf() {
                    continue; // legacy path exported the finest level only
                }
                let g = &grids[idx as usize];
                for v in 0..NVAR {
                    Gen::Cur.of(g).extract_interior(v, &mut interior);
                    for x in &interior {
                        f.write_all(&x.to_le_bytes())?;
                    }
                    written += (DGRID_CELLS * 4) as u64;
                }
            }
            Ok(written)
        };
        match run() {
            Ok(w) => {
                total_bytes.fetch_add(w, std::sync::atomic::Ordering::Relaxed);
            }
            Err(e) => errors.lock().unwrap().push(e),
        }
    });
    if let Some(e) = errors.into_inner().unwrap().pop() {
        return Err(e);
    }
    let bytes = total_bytes.load(std::sync::atomic::Ordering::Relaxed);
    let real_seconds = t0.elapsed().as_secs_f64().max(1e-9);
    // modelled as independent (non-collective) I/O on the target machine
    let est = machine.estimate_write(
        &WriteWorkload {
            ranks: part.n_ranks as u64,
            total_bytes: bytes,
            n_datasets: 1,
            n_grids: tree.n_leaves() as u64,
        },
        &IoTuning {
            collective_buffering: false,
            file_locking: false,
            alignment: false,
        },
    );
    Ok(VtkReport {
        files_written: part.n_ranks as u64,
        bytes,
        real_seconds,
        modelled_seconds: est.seconds,
        modelled_bandwidth: est.bandwidth,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::{sfc, BBox};

    #[test]
    fn writes_one_file_per_rank() {
        let dir = std::env::temp_dir().join(format!("vtk_test_{}", std::process::id()));
        let mut tree = SpaceTree::full(BBox::unit(), 1);
        let part = sfc::partition(&mut tree, 3);
        let grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        let rep =
            write_per_process(&dir, &Machine::local(), &tree, &part, &grids, 0.5).unwrap();
        assert_eq!(rep.files_written, 3);
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 3);
        // leaves only: 8 leaves × 5 vars × 4096 cells × 4 B + headers
        assert!(rep.bytes > 8 * 5 * 4096 * 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn modelled_time_worse_than_collective_kernel() {
        // the motivation of §3: per-process independent I/O on JuQueen is
        // far slower than the collective shared-file kernel
        let m = Machine::juqueen();
        let w = crate::cluster::paper_depth6_workload(8192);
        let indep = m.estimate_write(
            &w,
            &IoTuning {
                collective_buffering: false,
                file_locking: false,
                alignment: false,
            },
        );
        let coll = m.estimate_write(&w, &IoTuning::default());
        assert!(indep.seconds > 5.0 * coll.seconds);
    }
}
