//! The paper's **HDF5 I/O kernel** (§3) — snapshot output, checkpoint
//! restart and branching files on top of [`crate::h5lite`] +
//! [`crate::pario`].
//!
//! ## File structure (paper Fig 4)
//!
//! ```text
//! /common                      constant data, written once
//!     @dt @nu @alpha @rho @beta_g @t_inf @q_int
//!     @domain_min @domain_max @dgrid_n @n_ranks
//!     refinement_spacings      f64[max_depth+1]
//! /simulation
//!     /t=<elapsed>             one group per written time step
//!         grid_property        u64[n_grids]        packed UID per grid
//!         subgrid_uid          u64[n_grids, 8]     child UIDs (0 = leaf)
//!         bounding_box         f64[n_grids, 6]     min[3], max[3]
//!         cell_type            u8 [n_grids, 16³]
//!         current_cell_data    f32[n_grids, 5·16³]
//!         previous_cell_data   f32[n_grids, 5·16³]
//!         temp_cell_data       f32[n_grids, 5·16³]
//!         /lod                 multi-resolution pyramid (crate::lod):
//!             level_<ℓ>_cells  f32[n_ℓ, 5·16³]   2^ℓ-downsampled grids
//!             level_<ℓ>_locs   u64[n_ℓ]          location code per row
//! ```
//!
//! Rows are ordered along the Lebesgue curve, rank-major: each rank's grids
//! occupy one contiguous row range (its hyperslab), and the root grid is
//! always row 0 — the traversal entry point for the offline sliding window
//! (paper §3.1). Row offsets come from the partition's prefix sum, the
//! stand-in for the paper's MPI reduction + prefix reduction (§3.2).
//!
//! Every rank packs its grids into one *linear write buffer* per dataset
//! (the paper's one-to-one storage mapping, §3.2) and hands the slabs to
//! [`ParallelIo::collective_write`].
//!
//! The three heavy `*_cell_data` datasets (≈97 % of the snapshot volume)
//! are stored **chunked + compressed** (h5lite format v2, the
//! [`SnapshotOptions::cell_codec`] pipeline — shuffle/delta + hash-chain
//! LZ by default — in [`CHUNK_ROWS`]-row chunks) unless
//! [`SnapshotOptions::compress`] is off or the file is format v1; the
//! topology datasets stay contiguous — they are tiny and the sliding
//! window reads them row-at-a-time. The codec-v2 adaptive selector
//! upgrades compressible chunks to the entropy pipeline and stores
//! incompressible ones raw, per chunk, on the aggregator threads. Reads
//! decompress transparently, so the restart/window paths are unchanged.

pub mod vtk;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::exchange::Gen;
use crate::h5lite::codec::Codec;
use crate::h5lite::{codec, Attr, Backing, Dataset, Dtype, H5File, FORMAT_V2};
use crate::lod;
use crate::pario::{IoReport, LodSink, ParallelIo, SlabWrite};
use crate::physics::Params;
use crate::tree::dgrid::DGrid;
use crate::tree::sfc::Partition;
use crate::tree::uid::{LocCode, Uid};
use crate::tree::{BBox, SpaceTree};
use crate::{DGRID_CELLS, NVAR};

/// Cell-data elements per dataset row (all variables' interiors).
pub const ROW_ELEMS: usize = NVAR * DGRID_CELLS;

/// Bytes of one cell-data row (f32 elements) — the currency of the
/// byte-budgeted window queries.
pub const ROW_BYTES: u64 = (ROW_ELEMS * 4) as u64;

/// Rows per chunk of the compressed `*_cell_data` datasets. One row is
/// `ROW_ELEMS · 4` = 80 KiB, so a full chunk is 640 KiB of raw cell data —
/// big enough for the LZ window to bite, small enough that every aggregator
/// gets several chunks to pipeline.
pub const CHUNK_ROWS: u64 = 8;

/// The heavy datasets of one snapshot, in write order.
pub const DATASETS: [&str; 7] = [
    "grid_property",
    "subgrid_uid",
    "bounding_box",
    "cell_type",
    "current_cell_data",
    "previous_cell_data",
    "temp_cell_data",
];

/// Timestep group path for an elapsed time.
pub fn ts_group(t: f64) -> String {
    format!("/simulation/t={t:.6}")
}

/// Write the `/common` group (once, at file creation — paper §3.1).
pub fn write_common(
    file: &mut H5File,
    par: &Params,
    tree: &SpaceTree,
    n_ranks: u64,
) -> Result<()> {
    let max_depth = tree.max_depth();
    let spacings: Vec<f64> = (0..=max_depth).map(|d| tree.h_at_depth(d)).collect();
    let domain = tree.domain;
    let g = file.ensure_group("/common");
    g.attrs.insert("dt".into(), Attr::F64(par.dt as f64));
    g.attrs.insert("nu".into(), Attr::F64(par.nu as f64));
    g.attrs.insert("alpha".into(), Attr::F64(par.alpha as f64));
    g.attrs.insert("rho".into(), Attr::F64(par.rho as f64));
    g.attrs.insert("beta_g".into(), Attr::F64(par.beta_g as f64));
    g.attrs.insert("t_inf".into(), Attr::F64(par.t_inf as f64));
    g.attrs.insert("q_int".into(), Attr::F64(par.q_int as f64));
    g.attrs
        .insert("domain_min".into(), Attr::F64Vec(domain.min.to_vec()));
    g.attrs
        .insert("domain_max".into(), Attr::F64Vec(domain.max.to_vec()));
    g.attrs
        .insert("dgrid_n".into(), Attr::I64(crate::DGRID_N as i64));
    g.attrs.insert("n_ranks".into(), Attr::I64(n_ranks as i64));
    g.attrs
        .insert("refinement_spacings".into(), Attr::F64Vec(spacings));
    file.commit()
}

/// Read the solver parameters back from `/common`.
pub fn read_common(file: &H5File) -> Result<(Params, u64)> {
    let g = file.group("/common")?;
    let f = |k: &str| -> Result<f64> {
        match g.attrs.get(k) {
            Some(Attr::F64(v)) => Ok(*v),
            _ => bail!("iokernel: missing /common attr '{k}'"),
        }
    };
    let n_ranks = match g.attrs.get("n_ranks") {
        Some(Attr::I64(v)) => *v as u64,
        _ => bail!("iokernel: missing n_ranks"),
    };
    Ok((
        Params {
            dt: f("dt")? as f32,
            h: 0.0, // per-level, derived from the tree
            nu: f("nu")? as f32,
            alpha: f("alpha")? as f32,
            beta_g: f("beta_g")? as f32,
            t_inf: f("t_inf")? as f32,
            q_int: f("q_int")? as f32,
            rho: f("rho")? as f32,
            omega: 1.0,
        },
        n_ranks,
    ))
}

/// Read the domain bounding box from `/common` — shared by the snapshot
/// restore and the window's LOD level selection (one parser for the
/// on-disk attribute encoding).
pub fn read_domain(file: &H5File) -> Result<BBox> {
    let g = file.group("/common")?;
    match (g.attrs.get("domain_min"), g.attrs.get("domain_max")) {
        (Some(Attr::F64Vec(a)), Some(Attr::F64Vec(b))) if a.len() == 3 && b.len() == 3 => {
            Ok(BBox {
                min: [a[0], a[1], a[2]],
                max: [b[0], b[1], b[2]],
            })
        }
        _ => bail!("iokernel: missing /common domain attributes"),
    }
}

/// Selectable snapshot content — the paper's stated future-work knob
/// (§3.1: "this is subject to be revised in future iterations of the
/// kernel to allow users turn off unnecessary functions and, thus, reduce
/// the amount of data in the file"). The topology datasets and the current
/// cell data are always written (they carry the output + offline-window
/// functionality); the rest is optional:
///
/// * `previous`/`temp` — only needed for bit-exact checkpoint *restart*;
///   a visualisation-only snapshot can drop them (−2/3 of the cell data).
/// * `cell_type` — only needed when the scenario has obstacle geometry.
/// * `compress` — chunked shuffle/delta/LZ storage for the cell data
///   (transparent to readers; ignored on format-v1 files).
/// * `lod` — the multi-resolution pyramid ([`crate::lod`]) derived from
///   `current_cell_data` during the collective write, enabling
///   byte-budgeted window queries; ≤ a few percent of the file, folded on
///   the aggregator threads. Off ⇒ the snapshot looks exactly like a
///   pre-LOD file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotOptions {
    pub previous: bool,
    pub temp: bool,
    pub cell_type: bool,
    pub compress: bool,
    /// Base codec of the chunked cell-data datasets (the filter family the
    /// per-chunk adaptive selector works within). The default
    /// `SHUFFLE_DELTA_LZ` is right for smooth-to-turbulent f32 fields;
    /// benches pin other variants to isolate pipeline stages.
    pub cell_codec: Codec,
    pub lod: bool,
    /// Storage backend the snapshot expects its file on
    /// ([`crate::h5lite::store`]): `Direct` writes synchronously,
    /// `Paged` returns from commit once the in-memory image is
    /// consistent and drains through the background flusher —
    /// overlapping step N+1's pack/compress with step N's flush. The
    /// backend is a property of the open file (chosen at
    /// `create_backed`/`open_backed` time), so the kernel *validates*
    /// rather than switches: a mismatch fails loudly instead of
    /// silently running with different durability semantics than the
    /// caller planned for.
    pub backing: Backing,
}

impl Default for SnapshotOptions {
    /// Full checkpoint (the paper's current single-file-supports-all mode),
    /// cell data chunk-compressed, LOD pyramid alongside.
    fn default() -> SnapshotOptions {
        SnapshotOptions {
            previous: true,
            temp: true,
            cell_type: true,
            compress: true,
            cell_codec: Codec::SHUFFLE_DELTA_LZ,
            lod: true,
            backing: Backing::Direct,
        }
    }
}

impl SnapshotOptions {
    /// Visualisation-only output: topology + current data (+ pyramid —
    /// interactive exploration is exactly what this mode serves).
    pub fn output_only() -> SnapshotOptions {
        SnapshotOptions {
            previous: false,
            temp: false,
            cell_type: false,
            ..SnapshotOptions::default()
        }
    }

    /// Full checkpoint with the v1-style contiguous cell data (the
    /// uncompressed baseline the fig8 bench compares against).
    pub fn uncompressed() -> SnapshotOptions {
        SnapshotOptions {
            compress: false,
            ..SnapshotOptions::default()
        }
    }

    /// Full checkpoint on the paged backend: commit returns at image
    /// consistency, the flusher drains in the background. Pair with a
    /// file from [`H5File::create_backed`]/`open_backed` with
    /// [`Backing::Paged`].
    pub fn paged() -> SnapshotOptions {
        SnapshotOptions {
            backing: Backing::Paged,
            ..SnapshotOptions::default()
        }
    }

    /// Number of datasets this selection writes.
    pub fn n_datasets(&self) -> u64 {
        4 + self.previous as u64 + self.temp as u64 + self.cell_type as u64
    }
}

/// Shared guard of the snapshot write paths: the storage backend is fixed
/// when the file is opened, so a write planned for one backend must not
/// silently run on the other (the durability contract — when commit
/// returns vs. when bytes are on disk — would differ from what the caller
/// sized its overlap for).
fn check_backing(file: &H5File, opts: &SnapshotOptions) -> Result<()> {
    if file.backing() != opts.backing {
        bail!(
            "iokernel: snapshot options expect the {:?} backend but the file \
             is {:?}-backed — open it with H5File::open_backed/create_backed \
             using the matching Backing (or adjust SnapshotOptions::backing)",
            opts.backing,
            file.backing()
        );
    }
    Ok(())
}

/// Report of one snapshot write.
#[derive(Clone, Copy, Debug)]
pub struct SnapshotReport {
    pub io: IoReport,
    pub n_grids: u64,
    /// Seconds spent packing rank buffers (the paper's extra memory/copy
    /// trade-off, §3.2).
    pub pack_seconds: f64,
    /// LOD-pyramid storage report (`None` when `SnapshotOptions::lod` is
    /// off or the tree has no refinement). The fold time itself rides the
    /// collective write ([`IoReport::lod_seconds`]).
    pub lod: Option<lod::LodWriteReport>,
}

/// Write one complete simulation snapshot at elapsed time `t`.
///
/// Creates the timestep group + datasets collectively, packs each rank's
/// grids into linear buffers, and issues one collective write.
pub fn write_snapshot(
    file: &mut H5File,
    io: &ParallelIo,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    t: f64,
) -> Result<SnapshotReport> {
    write_snapshot_with(file, io, tree, part, grids, t, &SnapshotOptions::default())
}

/// [`write_snapshot`] with content selection.
pub fn write_snapshot_with(
    file: &mut H5File,
    io: &ParallelIo,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    t: f64,
    opts: &SnapshotOptions,
) -> Result<SnapshotReport> {
    let n = tree.len() as u64;
    let group = ts_group(t);
    check_backing(file, opts)?;
    // the heavy cell-data datasets go chunked+compressed on v2 files
    let compress = opts.compress && file.version() >= FORMAT_V2;
    let cell_ds = |file: &mut H5File, name: &str| -> Result<Dataset> {
        if compress {
            file.create_dataset_chunked(
                &group,
                name,
                Dtype::F32,
                &[n, ROW_ELEMS as u64],
                CHUNK_ROWS,
                opts.cell_codec,
            )
        } else {
            file.create_dataset(&group, name, Dtype::F32, &[n, ROW_ELEMS as u64])
        }
    };
    // --- collective dataset creation (all ranks agree on shapes) --------
    let ds_prop = file.create_dataset(&group, "grid_property", Dtype::U64, &[n])?;
    let ds_sub = file.create_dataset(&group, "subgrid_uid", Dtype::U64, &[n, 8])?;
    let ds_bbox = file.create_dataset(&group, "bounding_box", Dtype::F64, &[n, 6])?;
    let ds_ct = if opts.cell_type {
        Some(file.create_dataset(&group, "cell_type", Dtype::U8, &[n, DGRID_CELLS as u64])?)
    } else {
        None
    };
    let ds_cur = cell_ds(file, "current_cell_data")?;
    let ds_prev = if opts.previous {
        Some(cell_ds(file, "previous_cell_data")?)
    } else {
        None
    };
    let ds_tmp = if opts.temp {
        Some(cell_ds(file, "temp_cell_data")?)
    } else {
        None
    };

    // --- pack per-rank linear buffers ------------------------------------
    let offsets = part.row_offsets();
    let (packs, pack_seconds) = pack_all_ranks(tree, part, grids, PackSelect::for_snapshot(opts));

    // --- one collective write over all datasets --------------------------
    let mut writes: Vec<SlabWrite> = Vec::with_capacity(packs.len() * DATASETS.len());
    for p in &packs {
        let row0 = offsets[p.rank as usize];
        writes.push(slab(p.rank, &ds_prop, row0, &p.prop));
        writes.push(slab(p.rank, &ds_sub, row0, &p.sub));
        writes.push(slab(p.rank, &ds_bbox, row0, &p.bbox));
        if let Some(ds) = &ds_ct {
            writes.push(slab(p.rank, ds, row0, &p.ct));
        }
        writes.push(slab(p.rank, &ds_cur, row0, &p.cur));
        if let Some(ds) = &ds_prev {
            writes.push(slab(p.rank, ds, row0, &p.prev));
        }
        if let Some(ds) = &ds_tmp {
            writes.push(slab(p.rank, ds, row0, &p.tmp));
        }
    }
    let (report, lod_report) = collective_write_with_pyramid(
        file,
        io,
        tree,
        part,
        &writes,
        opts.n_datasets(),
        &ds_cur,
        &group,
        opts,
    )?;
    file.ensure_group(&group)
        .attrs
        .insert("elapsed".into(), Attr::F64(t));
    file.commit()?;
    Ok(SnapshotReport {
        io: report,
        n_grids: n,
        pack_seconds,
        lod: lod_report,
    })
}

/// Shared tail of [`write_snapshot_with`] and [`rewrite_snapshot_cells`]:
/// issue the collective write with the pyramid fold riding the fill phase
/// ([`LodSink`]), then fold the interior levels and store them. Refuses to
/// leave a **stale** pyramid behind: rewriting the cell data of a
/// pyramid-bearing snapshot with `opts.lod` off would silently keep
/// serving the pre-correction folds to budgeted readers.
#[allow(clippy::too_many_arguments)]
fn collective_write_with_pyramid(
    file: &mut H5File,
    io: &ParallelIo,
    tree: &SpaceTree,
    part: &Partition,
    writes: &[SlabWrite],
    n_datasets: u64,
    ds_cur: &Dataset,
    group: &str,
    opts: &SnapshotOptions,
) -> Result<(IoReport, Option<lod::LodWriteReport>)> {
    let mut builder = (opts.lod && tree.max_depth() > 0)
        .then(|| lod::PyramidBuilder::new(tree, part));
    if builder.is_none()
        && file
            .group(&format!("{group}/{}", lod::LOD_GROUP))
            .is_ok()
    {
        bail!(
            "iokernel: '{group}' carries a LOD pyramid but the write has \
             lod off — the pyramid would go stale; pass lod: true to refold"
        );
    }
    let report = {
        let sink = builder.as_ref().map(|b| LodSink { ds: ds_cur, builder: b });
        io.collective_write_lod(file, writes, n_datasets, tree.len() as u64, sink.as_ref())?
    };
    let compress = opts.compress && file.version() >= FORMAT_V2;
    let lod_report = match builder.as_mut() {
        Some(b) => {
            b.finish()?;
            Some(b.write(file, group, compress)?)
        }
        None => None,
    };
    Ok((report, lod_report))
}

/// Steering-driven **in-place rewrite** of an existing snapshot's cell
/// data — the long-running interactive scenario (paper §2.3): a steered
/// run keeps correcting the fields of a timestep it already wrote while
/// readers explore the file. The topology datasets are immutable; `opts`
/// selects which cell-data generations are rewritten, the same opt-in
/// flags as the original write. On a v2.1 file every rewritten chunk's old
/// extent is recycled by the free-space manager, so N rewrites keep the
/// file near its single-write size instead of growing ~N×; the commit at
/// the end publishes the new state to readers opening the file afterwards.
/// Leave the file on its default [`crate::h5lite::ReusePolicy::AfterCommit`]
/// when readers explore it while the run keeps writing; switch to
/// `Immediate` only for writer-exclusive sessions (a reader holding an
/// older footer would hit checksum errors on chunks rewritten in place).
/// A front end that must keep one consistent view across *many* rewrite
/// commits opens a `crate::window::SnapshotReader` session: its epoch pin
/// parks the extents these rewrites retire until the session drops.
pub fn rewrite_snapshot_cells(
    file: &mut H5File,
    io: &ParallelIo,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    t: f64,
    opts: &SnapshotOptions,
) -> Result<SnapshotReport> {
    let n = tree.len() as u64;
    let group = ts_group(t);
    check_backing(file, opts)?;
    let ds_cur = file.dataset(&group, "current_cell_data")?;
    if ds_cur.shape[0] != n {
        bail!(
            "iokernel: rewrite at t={t} brings {n} grids, snapshot stores {}",
            ds_cur.shape[0]
        );
    }
    let ds_prev = if opts.previous {
        Some(file.dataset(&group, "previous_cell_data")?)
    } else {
        None
    };
    let ds_tmp = if opts.temp {
        Some(file.dataset(&group, "temp_cell_data")?)
    } else {
        None
    };

    let offsets = part.row_offsets();
    // cells-only pack: the topology is immutable and never rewritten
    let (packs, pack_seconds) = pack_all_ranks(tree, part, grids, PackSelect::for_rewrite(opts));

    let mut writes: Vec<SlabWrite> = Vec::with_capacity(packs.len() * 3);
    for p in &packs {
        let row0 = offsets[p.rank as usize];
        writes.push(slab(p.rank, &ds_cur, row0, &p.cur));
        if let Some(ds) = &ds_prev {
            writes.push(slab(p.rank, ds, row0, &p.prev));
        }
        if let Some(ds) = &ds_tmp {
            writes.push(slab(p.rank, ds, row0, &p.tmp));
        }
    }
    let n_datasets = 1 + opts.previous as u64 + opts.temp as u64;
    // the pyramid is derived data: a steering correction of the cell
    // fields must refold it, or budgeted readers would keep seeing the
    // pre-correction coarse levels (rewriting the level rows recycles the
    // old extents through the free-space manager like any chunk rewrite);
    // the helper refuses a lod-off rewrite of a pyramid-bearing snapshot
    let (report, lod_report) = collective_write_with_pyramid(
        file, io, tree, part, &writes, n_datasets, &ds_cur, &group, opts,
    )?;
    file.commit()?;
    Ok(SnapshotReport {
        io: report,
        n_grids: n,
        pack_seconds,
        lod: lod_report,
    })
}

fn slab<'a>(rank: u32, ds: &'a Dataset, row0: u64, data: &'a [u8]) -> SlabWrite<'a> {
    SlabWrite {
        rank,
        ds,
        row_start: row0,
        data,
    }
}

/// One rank's packed linear write buffers.
struct RankPack {
    rank: u32,
    prop: Vec<u8>,
    sub: Vec<u8>,
    bbox: Vec<u8>,
    ct: Vec<u8>,
    cur: Vec<u8>,
    prev: Vec<u8>,
    tmp: Vec<u8>,
}

/// Which buffers [`pack_rank`] fills: each write path pays only for what
/// it will actually hand to the collective write — the steering rewrite
/// skips the immutable topology, and both paths skip generations their
/// [`SnapshotOptions`] deselect.
#[derive(Clone, Copy)]
struct PackSelect {
    topology: bool,
    cell_type: bool,
    previous: bool,
    temp: bool,
}

impl PackSelect {
    fn for_snapshot(opts: &SnapshotOptions) -> PackSelect {
        PackSelect {
            topology: true,
            cell_type: opts.cell_type,
            previous: opts.previous,
            temp: opts.temp,
        }
    }

    fn for_rewrite(opts: &SnapshotOptions) -> PackSelect {
        PackSelect {
            topology: false,
            cell_type: false,
            ..PackSelect::for_snapshot(opts)
        }
    }
}

/// Pack every rank's linear write buffers in curve order (the paper's
/// one-to-one storage mapping, §3.2), returning the packs and the pack
/// time.
fn pack_all_ranks(
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    sel: PackSelect,
) -> (Vec<RankPack>, f64) {
    let t_pack = std::time::Instant::now();
    let mut packs: Vec<RankPack> = Vec::with_capacity(part.n_ranks as usize);
    // rows in curve order, grouped per rank (contiguous by construction)
    let mut row = 0usize;
    for r in 0..part.n_ranks {
        let count = part.counts[r as usize] as usize;
        let rows = &part.curve[row..row + count];
        packs.push(pack_rank(r, rows, tree, grids, sel));
        row += count;
    }
    (packs, t_pack.elapsed().as_secs_f64())
}

fn pack_rank(
    rank: u32,
    rows: &[u32],
    tree: &SpaceTree,
    grids: &[DGrid],
    sel: PackSelect,
) -> RankPack {
    let n = rows.len();
    let cap = |on: bool, per_row: usize| if on { n * per_row } else { 0 };
    let mut prop = Vec::with_capacity(cap(sel.topology, 8));
    let mut sub = Vec::with_capacity(cap(sel.topology, 64));
    let mut bbox = Vec::with_capacity(cap(sel.topology, 48));
    let mut ct = Vec::with_capacity(cap(sel.cell_type, DGRID_CELLS));
    let mut cur = Vec::with_capacity(n * ROW_ELEMS * 4);
    let mut prev = Vec::with_capacity(cap(sel.previous, ROW_ELEMS * 4));
    let mut tmp = Vec::with_capacity(cap(sel.temp, ROW_ELEMS * 4));
    let mut interior = vec![0.0f32; DGRID_CELLS];
    for &idx in rows {
        let g = &grids[idx as usize];
        if sel.topology {
            let node = tree.node(idx);
            prop.extend_from_slice(&node.uid().0.to_le_bytes());
            if node.is_leaf() {
                sub.extend_from_slice(&[0u8; 64]);
            } else {
                for &c in &node.children {
                    sub.extend_from_slice(&tree.node(c).uid().0.to_le_bytes());
                }
            }
            for v in node.bbox.min.iter().chain(node.bbox.max.iter()) {
                bbox.extend_from_slice(&v.to_le_bytes());
            }
        }
        if sel.cell_type {
            ct.extend_from_slice(&g.cell_type);
        }
        for (gen, buf, on) in [
            (Gen::Cur, &mut cur, true),
            (Gen::Prev, &mut prev, sel.previous),
            (Gen::Temp, &mut tmp, sel.temp),
        ] {
            if !on {
                continue;
            }
            let fs = gen.of(g);
            for v in 0..NVAR {
                fs.extract_interior(v, &mut interior);
                buf.extend_from_slice(&codec::f32s_to_bytes(&interior));
            }
        }
    }
    RankPack {
        rank,
        prop,
        sub,
        bbox,
        ct,
        cur,
        prev,
        tmp,
    }
}

/// List the elapsed times of all snapshots in the file, ascending.
pub fn list_timesteps(file: &H5File) -> Vec<f64> {
    let mut ts: Vec<f64> = match file.group("/simulation") {
        Ok(sim) => sim
            .groups
            .keys()
            .filter_map(|k| k.strip_prefix("t=").and_then(|s| s.parse().ok()))
            .collect(),
        Err(_) => Vec::new(),
    };
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts
}

/// A snapshot restored from file: reconstructed topology + field data.
pub struct RestoredSnapshot {
    pub tree: SpaceTree,
    pub part: Partition,
    pub grids: Vec<DGrid>,
    pub t: f64,
    pub params: Params,
}

/// Restore the complete simulation state from the snapshot at time `t`
/// (paper §3.2: read `grid property`, rebuild the topology without the
/// neighbourhood server's serial decomposition, then read the hyperslabs).
pub fn read_snapshot(file: &H5File, t: f64) -> Result<RestoredSnapshot> {
    let group = ts_group(t);
    let (params, _) = read_common(file)?;
    let ds_prop = file.dataset(&group, "grid_property")?;
    let uids: Vec<Uid> = file
        .read_all_u64(&ds_prop)?
        .into_iter()
        .map(Uid)
        .collect();
    let n = uids.len();
    if n == 0 {
        bail!("iokernel: empty snapshot at t={t}");
    }

    // --- rebuild the topology from location codes ------------------------
    let domain = read_domain(file)?;
    let mut locs: Vec<LocCode> = uids.iter().map(|u| u.loc()).collect();
    locs.sort_by_key(|l| l.depth());
    let mut tree = SpaceTree::root_only(domain);
    for loc in &locs {
        if loc.depth() == 0 {
            continue;
        }
        let parent = loc
            .parent()
            .ok_or_else(|| anyhow!("iokernel: orphan loc code"))?;
        let pidx = tree
            .lookup(parent)
            .ok_or_else(|| anyhow!("iokernel: missing parent grid in snapshot"))?;
        tree.refine(pidx); // no-op for siblings already created
    }
    if tree.len() != n {
        bail!(
            "iokernel: snapshot topology inconsistent ({} grids in file, {} reconstructed)",
            n,
            tree.len()
        );
    }

    // --- restore rank assignment from the UIDs ---------------------------
    let mut curve_rows: Vec<u32> = Vec::with_capacity(n);
    let mut counts: HashMap<u32, u32> = HashMap::new();
    for uid in &uids {
        let idx = tree
            .lookup(uid.loc())
            .ok_or_else(|| anyhow!("iokernel: UID loc not in tree"))?;
        tree.nodes[idx as usize].rank = uid.rank();
        tree.nodes[idx as usize].local = uid.local();
        curve_rows.push(idx);
        *counts.entry(uid.rank()).or_default() += 1;
    }
    let n_ranks = counts.keys().max().map(|r| r + 1).unwrap_or(1);
    let part = Partition {
        n_ranks,
        counts: (0..n_ranks)
            .map(|r| counts.get(&r).copied().unwrap_or(0))
            .collect(),
        curve: curve_rows,
    };

    // --- field data -------------------------------------------------------
    // optional datasets may be absent (SnapshotOptions); default to
    // fluid-only cell types / zero generations
    let ds_ct = file.dataset(&group, "cell_type").ok();
    let ds_cur = file.dataset(&group, "current_cell_data")?;
    let ds_prev = file.dataset(&group, "previous_cell_data").ok();
    let ds_tmp = file.dataset(&group, "temp_cell_data").ok();
    let mut grids: Vec<DGrid> = tree.nodes.iter().map(|nn| DGrid::new(nn.uid())).collect();
    for (row, uid) in uids.iter().enumerate() {
        let idx = tree.lookup(uid.loc()).unwrap() as usize;
        let g = &mut grids[idx];
        if let Some(ds) = &ds_ct {
            g.cell_type = file.read_rows(ds, row as u64, 1)?;
        }
        for (ds, gen) in [
            (Some(&ds_cur), Gen::Cur),
            (ds_prev.as_ref(), Gen::Prev),
            (ds_tmp.as_ref(), Gen::Temp),
        ] {
            let Some(ds) = ds else { continue };
            let bytes = file.read_rows(ds, row as u64, 1)?;
            let vals = codec::bytes_to_f32s(&bytes);
            let fs = gen.of_mut(g);
            for v in 0..NVAR {
                fs.set_interior(v, &vals[v * DGRID_CELLS..(v + 1) * DGRID_CELLS]);
            }
        }
    }
    Ok(RestoredSnapshot {
        tree,
        part,
        grids,
        t,
        params,
    })
}

/// Create a **branching file** (paper §3.2, §4): a fresh file seeded with
/// the source's `/common` group and the snapshot at `t`, recording its
/// ancestry. Subsequent write-outs of the steered run go there, giving the
/// branching simulation paths of Fig 5.
pub fn branch_file<P: AsRef<Path>>(
    src: &H5File,
    t: f64,
    new_path: P,
    io: &ParallelIo,
) -> Result<H5File> {
    let snap = read_snapshot(src, t).context("iokernel: branch source snapshot")?;
    let mut dst = H5File::create(new_path, src.alignment)?;
    // copy /common
    let common = src.group("/common")?.clone();
    *dst.ensure_group("/common") = common;
    let g = dst.ensure_group("/common");
    g.attrs.insert(
        "branched_from".into(),
        Attr::Str(format!("{}@t={t:.6}", src.path.display())),
    );
    dst.commit()?;
    write_snapshot(&mut dst, io, &snap.tree, &snap.part, &snap.grids, t)?;
    Ok(dst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::tree::sfc;
    use crate::var;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("iokernel_test_{}_{}", std::process::id(), name));
        p
    }

    fn setup(depth: u32, ranks: u32) -> (SpaceTree, Partition, Vec<DGrid>) {
        let mut tree = SpaceTree::full(BBox::unit(), depth);
        let part = sfc::partition(&mut tree, ranks);
        let mut grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        // distinguishable data: each grid's pressure = its arena index
        for (i, g) in grids.iter_mut().enumerate() {
            let data = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &data);
            let t = vec![300.0 + i as f32; DGRID_CELLS];
            g.prev.set_interior(var::T, &t);
        }
        (tree, part, grids)
    }

    fn params() -> Params {
        Params {
            dt: 0.01,
            h: 0.0,
            nu: 0.001,
            alpha: 0.002,
            beta_g: 0.5,
            t_inf: 300.0,
            q_int: 0.0,
            rho: 1.2,
            omega: 1.0,
        }
    }

    fn io() -> ParallelIo {
        ParallelIo::new(Machine::local(), IoTuning::default(), 4)
    }

    #[test]
    fn snapshot_write_read_roundtrip() {
        let p = tmp("roundtrip");
        let (tree, part, grids) = setup(1, 4);
        {
            let mut f = H5File::create(&p, 1).unwrap();
            write_common(&mut f, &params(), &tree, 4).unwrap();
            let rep = write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.25).unwrap();
            assert_eq!(rep.n_grids, 9);
            assert!(rep.io.bytes > 0);
        }
        let f = H5File::open(&p).unwrap();
        let snap = read_snapshot(&f, 0.25).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        assert_eq!(snap.part.n_ranks, 4);
        assert!((snap.params.rho - 1.2).abs() < 1e-6);
        // field data restored per grid (match by location code)
        for (i, n) in tree.nodes.iter().enumerate() {
            let j = snap.tree.lookup(n.loc).unwrap() as usize;
            let mut out = vec![0.0f32; DGRID_CELLS];
            snap.grids[j].cur.extract_interior(var::P, &mut out);
            assert_eq!(out[0], i as f32, "grid {i} pressure");
            snap.grids[j].prev.extract_interior(var::T, &mut out);
            assert_eq!(out[100], 300.0 + i as f32, "grid {i} prev T");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn root_grid_is_row_zero() {
        let p = tmp("row0");
        let (tree, part, grids) = setup(1, 3);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 3).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        let ds = f.dataset(&ts_group(0.0), "grid_property").unwrap();
        let uids = f.read_all_u64(&ds).unwrap();
        let root = Uid(uids[0]);
        assert_eq!(root.loc(), LocCode::ROOT);
        assert_eq!(root.rank(), 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn subgrid_uid_links_children() {
        let p = tmp("subgrid");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        let g = ts_group(0.0);
        let subs = f.read_all_u64(&f.dataset(&g, "subgrid_uid").unwrap()).unwrap();
        let props = f.read_all_u64(&f.dataset(&g, "grid_property").unwrap()).unwrap();
        // root (row 0) has 8 non-null children, all present in grid_property
        for c in 0..8 {
            let child = subs[c];
            assert_ne!(child, 0);
            assert!(props.contains(&child));
        }
        // leaves have null children
        assert!(subs[8..].iter().all(|&u| u == 0));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn multiple_timesteps_listed_sorted() {
        let p = tmp("list");
        let (tree, part, grids) = setup(0, 1);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 1).unwrap();
        for t in [0.5, 0.0, 0.25] {
            write_snapshot(&mut f, &io(), &tree, &part, &grids, t).unwrap();
        }
        assert_eq!(list_timesteps(&f), vec![0.0, 0.25, 0.5]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn restart_preserves_adaptive_topology() {
        let p = tmp("adaptive");
        let mut tree = SpaceTree::adaptive(BBox::unit(), 3, &|b, _| {
            b.contains_point([0.01, 0.01, 0.01])
        });
        let part = sfc::partition(&mut tree, 5);
        let grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 5).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 1.0).unwrap();
        let snap = read_snapshot(&f, 1.0).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        assert_eq!(snap.tree.max_depth(), 3);
        // every loc code surviving
        for n in &tree.nodes {
            assert!(snap.tree.lookup(n.loc).is_some(), "{:?} lost", n.loc);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn branch_file_carries_common_and_snapshot() {
        let p = tmp("branch_src");
        let q = tmp("branch_dst");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.5).unwrap();
        let branch = branch_file(&f, 0.5, &q, &io()).unwrap();
        // ancestry recorded
        match branch.group("/common").unwrap().attrs.get("branched_from") {
            Some(Attr::Str(s)) => assert!(s.contains("t=0.500000")),
            other => panic!("missing ancestry: {other:?}"),
        }
        // snapshot restored from the branch
        let snap = read_snapshot(&branch, 0.5).unwrap();
        assert_eq!(snap.tree.len(), 9);
        // branch has exactly one timestep
        assert_eq!(list_timesteps(&branch), vec![0.5]);
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&q).ok();
    }

    #[test]
    fn checkpoint_bytes_match_paper_accounting() {
        // file payload per grid ≈ DGrid::checkpoint_bytes() + topology rows
        let p = tmp("bytes");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        let rep = write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        let per_grid = rep.io.bytes / 9;
        let expected = DGrid::checkpoint_bytes() as u64 + 8 + 64 + 48;
        assert_eq!(per_grid, expected);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn output_only_snapshot_is_smaller_and_readable() {
        let p = tmp("optsel");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        let full =
            write_snapshot_with(&mut f, &io(), &tree, &part, &grids, 0.0, &SnapshotOptions::default())
                .unwrap();
        let lean = write_snapshot_with(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            1.0,
            &SnapshotOptions::output_only(),
        )
        .unwrap();
        // the paper's future-work knob: ~2/3 of the cell data gone
        assert!(lean.io.bytes * 2 < full.io.bytes, "{} vs {}", lean.io.bytes, full.io.bytes);
        // still fully readable: topology + current data restored
        let snap = read_snapshot(&f, 1.0).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        let idx = snap.tree.lookup(tree.node(3).loc).unwrap() as usize;
        let mut out = vec![0.0f32; DGRID_CELLS];
        snap.grids[idx].cur.extract_interior(var::P, &mut out);
        assert_eq!(out[0], 3.0);
        // absent generations default to zero
        snap.grids[idx].prev.extract_interior(var::T, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
        // the offline window session works on the lean snapshot too
        let reader = crate::window::SnapshotReader::open(&f, 1.0).unwrap();
        let w = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(w.len(), 8);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn snapshot_options_dataset_counts() {
        assert_eq!(SnapshotOptions::default().n_datasets(), 7);
        assert_eq!(SnapshotOptions::output_only().n_datasets(), 4);
        assert_eq!(
            SnapshotOptions {
                temp: false,
                ..SnapshotOptions::default()
            }
            .n_datasets(),
            6
        );
    }

    #[test]
    fn compressed_snapshot_roundtrips_bit_exact() {
        let p = tmp("comp_exact");
        let (tree, part, grids) = setup(1, 4);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 4).unwrap();
        let comp = write_snapshot_with(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
        let raw = write_snapshot_with(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            1.0,
            &SnapshotOptions::uncompressed(),
        )
        .unwrap();
        // same logical bytes, fewer stored bytes
        assert_eq!(comp.io.bytes, raw.io.bytes);
        assert!(comp.io.stored_bytes < raw.io.stored_bytes, "{comp:?}");
        assert!(comp.io.compress_seconds > 0.0);
        assert_eq!(raw.io.stored_bytes, raw.io.bytes);
        // reopen and byte-compare every dataset between the two snapshots
        let f = H5File::open(&p).unwrap();
        for name in DATASETS {
            let a = f.dataset(&ts_group(0.0), name).unwrap();
            let b = f.dataset(&ts_group(1.0), name).unwrap();
            assert_eq!(
                f.read_rows(&a, 0, a.shape[0]).unwrap(),
                f.read_rows(&b, 0, b.shape[0]).unwrap(),
                "dataset {name}"
            );
        }
        // the cell data is chunked on disk, the topology is not
        assert!(f.dataset(&ts_group(0.0), "current_cell_data").unwrap().is_chunked());
        assert!(!f.dataset(&ts_group(0.0), "grid_property").unwrap().is_chunked());
        assert!(!f.dataset(&ts_group(1.0), "current_cell_data").unwrap().is_chunked());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn compressed_snapshot_restores_full_state() {
        let p = tmp("comp_restore");
        let (tree, part, grids) = setup(1, 4);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 4).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.25).unwrap();
        let snap = read_snapshot(&f, 0.25).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        let mut out = vec![0.0f32; DGRID_CELLS];
        for (i, n) in tree.nodes.iter().enumerate() {
            let j = snap.tree.lookup(n.loc).unwrap() as usize;
            snap.grids[j].cur.extract_interior(var::P, &mut out);
            assert_eq!(out[0], i as f32, "grid {i} pressure");
            snap.grids[j].prev.extract_interior(var::T, &mut out);
            assert_eq!(out[100], 300.0 + i as f32, "grid {i} prev T");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_file_falls_back_to_contiguous_snapshot() {
        let p = tmp("v1_snap");
        let (tree, part, grids) = setup(1, 2);
        {
            let mut f =
                H5File::create_versioned(&p, 1, crate::h5lite::FORMAT_V1).unwrap();
            write_common(&mut f, &params(), &tree, 2).unwrap();
            // default options ask for compression; a v1 file silently
            // stores contiguous instead of failing
            let rep = write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
            assert_eq!(rep.io.stored_bytes, rep.io.bytes);
        }
        let f = H5File::open(&p).unwrap();
        assert_eq!(f.version(), crate::h5lite::FORMAT_V1);
        assert!(!f.dataset(&ts_group(0.0), "current_cell_data").unwrap().is_chunked());
        let snap = read_snapshot(&f, 0.0).unwrap();
        assert_eq!(snap.tree.len(), 9);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_snapshot_errors() {
        let p = tmp("missing");
        let (tree, _, _) = setup(0, 1);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 1).unwrap();
        assert!(read_snapshot(&f, 9.9).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn steering_rewrites_keep_file_near_single_write_size() {
        use crate::h5lite::ReusePolicy;
        // the acceptance scenario: a steered run rewrites every chunk of a
        // snapshot N times; with the free-space manager the file must stay
        // ≤ ~1.2× the single-write size (it grew ~N× before), repack then
        // compacts it, and verify passes on the result
        let p = tmp("steer");
        let (tree, part, mut grids) = setup(1, 4);
        let mut f = H5File::create(&p, 1).unwrap();
        f.set_reuse_policy(ReusePolicy::Immediate);
        write_common(&mut f, &params(), &tree, 4).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        let single = std::fs::metadata(&p).unwrap().len();
        let steps = 6u32;
        for step in 0..steps {
            // the steering correction: shift every grid's pressure field
            for (i, g) in grids.iter_mut().enumerate() {
                let data = vec![i as f32 + step as f32; DGRID_CELLS];
                g.cur.set_interior(var::P, &data);
            }
            let rep = rewrite_snapshot_cells(
                &mut f,
                &io(),
                &tree,
                &part,
                &grids,
                0.0,
                &SnapshotOptions::default(),
            )
            .unwrap();
            assert!(rep.io.reclaimed_bytes > 0, "step {step} reclaimed nothing");
        }
        let after = std::fs::metadata(&p).unwrap().len();
        assert!(
            after as f64 <= single as f64 * 1.2,
            "rewrites amplified the file: {after} B vs single-write {single} B"
        );
        // readers restore the *last* steering state
        let snap = read_snapshot(&f, 0.0).unwrap();
        let j = snap.tree.lookup(tree.node(3).loc).unwrap() as usize;
        let mut out = vec![0.0f32; DGRID_CELLS];
        snap.grids[j].cur.extract_interior(var::P, &mut out);
        assert_eq!(out[0], 3.0 + (steps - 1) as f32);
        // compaction reaches at most the pre-rewrite footprint, and the
        // compacted file is structurally clean
        f.repack().unwrap();
        let packed = std::fs::metadata(&p).unwrap().len();
        assert!(packed <= after, "{packed} !<= {after}");
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        let snap = read_snapshot(&f, 0.0).unwrap();
        snap.grids[j].cur.extract_interior(var::P, &mut out);
        assert_eq!(out[0], 3.0 + (steps - 1) as f32);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn snapshot_stores_pyramid_and_rewrite_refolds_it() {
        let p = tmp("lod_snap");
        let (tree, part, mut grids) = setup(1, 2);
        // uniform leaves → a uniform pyramid root, easy to assert exactly
        for g in grids.iter_mut() {
            for v in 0..NVAR {
                g.cur.set_interior(v, &[2.0; DGRID_CELLS]);
            }
        }
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        let rep = write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        let lod_rep = rep.lod.expect("default options must store the pyramid");
        assert_eq!(lod_rep.levels, 1);
        assert!(lod_rep.stored_bytes > 0);
        let idx = crate::lod::LodIndex::open(&f, &ts_group(0.0))
            .unwrap()
            .expect("lod group missing");
        let l1 = idx.level(1).unwrap();
        assert!(l1.read_row(&f, 0).unwrap().iter().all(|&x| x == 2.0));
        // a steering correction must refold the pyramid, or budgeted
        // readers would keep seeing the pre-correction coarse levels
        for g in grids.iter_mut() {
            for v in 0..NVAR {
                g.cur.set_interior(v, &[6.0; DGRID_CELLS]);
            }
        }
        let rw = rewrite_snapshot_cells(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
        assert!(rw.lod.is_some());
        assert!(l1.read_row(&f, 0).unwrap().iter().all(|&x| x == 6.0));
        // and the pyramid-bearing file stays structurally clean
        let vr = f.verify().unwrap();
        assert!(vr.ok(), "{:?}", vr.errors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rewrite_with_lod_off_refuses_to_stale_the_pyramid() {
        let p = tmp("lod_stale");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        let lod_off = SnapshotOptions {
            lod: false,
            ..SnapshotOptions::default()
        };
        // pyramid-bearing snapshot: a lod-off rewrite must fail loudly
        // instead of silently serving pre-correction folds to readers
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        assert!(
            rewrite_snapshot_cells(&mut f, &io(), &tree, &part, &grids, 0.0, &lod_off)
                .is_err()
        );
        // a pyramid-less snapshot keeps accepting lod-off rewrites
        write_snapshot_with(&mut f, &io(), &tree, &part, &grids, 1.0, &lod_off).unwrap();
        rewrite_snapshot_cells(&mut f, &io(), &tree, &part, &grids, 1.0, &lod_off).unwrap();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn lod_off_snapshot_has_no_pyramid_group() {
        let p = tmp("lod_off");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        let opts = SnapshotOptions {
            lod: false,
            ..SnapshotOptions::default()
        };
        let rep =
            write_snapshot_with(&mut f, &io(), &tree, &part, &grids, 0.0, &opts).unwrap();
        assert!(rep.lod.is_none());
        assert!(crate::lod::LodIndex::open(&f, &ts_group(0.0)).unwrap().is_none());
        // the file is indistinguishable from a pre-LOD one and restores
        let snap = read_snapshot(&f, 0.0).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rewrite_refuses_topology_mismatch() {
        let p = tmp("steer_mismatch");
        let (tree, part, grids) = setup(1, 2);
        let mut f = H5File::create(&p, 1).unwrap();
        write_common(&mut f, &params(), &tree, 2).unwrap();
        write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.0).unwrap();
        // a differently-refined domain must not silently rewrite
        let (tree2, part2, grids2) = setup(0, 1);
        assert!(rewrite_snapshot_cells(
            &mut f,
            &io(),
            &tree2,
            &part2,
            &grids2,
            0.0,
            &SnapshotOptions::default(),
        )
        .is_err());
        // and rewriting a missing timestep fails cleanly too
        assert!(rewrite_snapshot_cells(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            7.7,
            &SnapshotOptions::default(),
        )
        .is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn paged_snapshot_roundtrips_and_backing_mismatch_fails() {
        let p = tmp("paged");
        let (tree, part, grids) = setup(1, 4);
        let mut f = H5File::create_backed(&p, 1, Backing::Paged).unwrap();
        write_common(&mut f, &params(), &tree, 4).unwrap();
        // default options plan for the direct backend: refused loudly
        assert!(
            write_snapshot(&mut f, &io(), &tree, &part, &grids, 0.25).is_err(),
            "direct-options write on a paged file must be refused"
        );
        let rep = write_snapshot_with(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            0.25,
            &SnapshotOptions::paged(),
        )
        .unwrap();
        assert_eq!(rep.n_grids, 9);
        assert!(
            rep.io.flush_backlog_bytes > 0,
            "the collective write must land in the image: {:?}",
            rep.io
        );
        // drain, close, reopen through the plain direct path: the flushed
        // file is an ordinary snapshot file
        f.wait_durable().unwrap();
        drop(f);
        let mut f = H5File::open(&p).unwrap();
        let snap = read_snapshot(&f, 0.25).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        let j = snap.tree.lookup(tree.node(3).loc).unwrap() as usize;
        let mut out = vec![0.0f32; DGRID_CELLS];
        snap.grids[j].cur.extract_interior(var::P, &mut out);
        assert_eq!(out[0], 3.0);
        // the guard works in both directions: paged options on the
        // direct-backed reopen are refused too
        assert!(rewrite_snapshot_cells(
            &mut f,
            &io(),
            &tree,
            &part,
            &grids,
            0.25,
            &SnapshotOptions::paged(),
        )
        .is_err());
        std::fs::remove_file(&p).ok();
    }
}
