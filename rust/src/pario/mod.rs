//! **pario** — the MPI-IO-like parallel I/O middleware with collective
//! buffering (two-phase I/O).
//!
//! This is the layer between the I/O kernel and the file format, playing
//! the role ROMIO/MPI-IO plays under Parallel HDF5 (paper §3, §5.2):
//!
//! * every logical rank contributes hyperslab writes (a dataset, a row
//!   range, bytes);
//! * with **collective buffering** on, ranks are grouped onto *aggregators*
//!   (the bridge nodes of §5.2); each aggregator concatenates its ranks'
//!   slabs — a real memcpy "fill" phase — merges adjacent row ranges into
//!   few large contiguous operations, and streams them to the file from its
//!   own thread;
//! * **chunked datasets** (h5lite format v2) take the deeply-integrated
//!   compression path of Jin et al. (2022): the collective view of all
//!   slabs is re-bucketed per chunk, and each aggregator assembles,
//!   compresses and writes its chunks *during* the fill phase — the codec
//!   overlaps the streaming instead of preceding it, and only the
//!   compressed extents hit the file. Since codec v2 the aggregator runs
//!   the **adaptive selector** ([`codec::encode_chunk_adaptive`]) on its
//!   own thread: a trial-compression picks raw / LZ / LZ+entropy per
//!   chunk, the selection is recorded in the per-chunk codec byte, and
//!   [`IoReport::codec_chunks`] tallies the classes;
//! * with collective buffering off, every rank issues its own small write
//!   ops directly (the paper's "severe contention" baseline);
//! * with **file locking** on, a global lock serialises every write op —
//!   the real wall-clock effect of GPFS's conservative byte-range locking
//!   that the paper disables (safe because hyperslabs are disjoint).
//!
//! Every collective write returns an [`IoReport`] with both the *real*
//! measured duration/op-counts/compressed-byte counts on this host and the
//! *modelled* duration on the target [`Machine`] (how long the same
//! byte/op pattern would take on JuQueen/SuperMUC) — benches report the
//! modelled number, EXPERIMENTS.md records both.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cluster::{IoEstimate, IoTuning, Machine, WriteWorkload};
use crate::h5lite::codec::Codec;
use crate::h5lite::{codec, Backing, Dataset, Dtype, H5File, Layout};
use crate::lod::PyramidBuilder;
use crate::metrics::{names, Metrics};
use crate::sync::{LockRank, OrderedMutex};
use crate::util::parallel_for;

/// One rank's contribution to a collective dataset write.
pub struct SlabWrite<'a> {
    pub rank: u32,
    pub ds: &'a Dataset,
    pub row_start: u64,
    pub data: &'a [u8],
}

/// Outcome of one collective write: real measurement + machine model.
#[derive(Clone, Copy, Debug)]
pub struct IoReport {
    /// Wall-clock seconds of the real file I/O on this host.
    pub real_seconds: f64,
    /// Real effective bandwidth achieved on this host (raw bytes/s).
    pub real_bandwidth: f64,
    /// Raw payload bytes contributed by the ranks.
    pub bytes: u64,
    /// Bytes that physically hit the file: smaller than `bytes` when chunk
    /// compression engaged; can *exceed* `bytes` when a partial-chunk
    /// collective write re-stores whole chunks (read-modify-write
    /// amplification).
    pub stored_bytes: u64,
    /// Physical write ops issued after merging (one per merged contiguous
    /// run, one per chunk extent).
    pub write_ops: u64,
    /// Bytes of abandoned chunk extents this write retired to the file's
    /// free-space manager (format v2.1): rewritten chunks hand their old
    /// extents back for reuse instead of leaking them.
    pub reclaimed_bytes: u64,
    /// CPU seconds the aggregators spent in the chunk codec (summed across
    /// threads; overlapped with streaming in the real run).
    pub compress_seconds: f64,
    /// Chunks per storage class the adaptive selector picked this write:
    /// stored raw, LZ-family, or LZ + entropy frame (codec v2).
    pub codec_chunks: CodecChunks,
    /// CPU seconds the aggregators spent folding assembled source rows
    /// into the LOD pyramid's accumulation buffers (summed across threads;
    /// overlapped with streaming, like the codec). Zero when the write
    /// carried no [`LodSink`].
    pub lod_seconds: f64,
    /// Wall-clock seconds the storage backend's background flusher spent
    /// draining dirty pages to disk *during this call* (busy-time delta;
    /// 0 on the direct backend, whose writes are synchronous). Overlaps
    /// `real_seconds` — it runs on the flusher thread.
    pub flush_seconds: f64,
    /// Flush backlog at return: bytes this write left dirty in the paged
    /// image or queued behind a durability barrier, still on their way to
    /// disk (0 on the direct backend). The overlap the paged backend buys —
    /// step N+1's fill runs while these bytes drain.
    pub flush_backlog_bytes: u64,
    /// Wall-clock seconds the attached [`crate::stream::EpochPublisher`]
    /// spent teeing batches *during this call* — publish time rides the
    /// writer's commit path, so this is the streaming tax on commit-return
    /// (0 with no publisher attached).
    pub publish_seconds: f64,
    /// Slowest live subscriber's queued payload bytes at return — the
    /// in-transit counterpart of `flush_backlog_bytes` (0 with no
    /// publisher or no subscribers).
    pub publish_backlog_bytes: u64,
    /// Modelled cost on the target machine.
    pub modelled: IoEstimate,
}

/// Per-write tally of the adaptive codec's per-chunk selections
/// ([`codec::encode_chunk_adaptive`]): how many chunks landed in each
/// storage class. `store` chunks were incompressible and hit the file raw;
/// the entropy classes split per backend (`rc` = range coder, `tans` =
/// table-driven ANS) so a run can see which coder its data actually picked.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CodecChunks {
    pub store: u64,
    pub lz: u64,
    pub rc: u64,
    pub tans: u64,
}

/// Selection tally plus raw-byte attribution per actual codec code, used
/// to pick the *dominant* codec the machine model prices (`compress_bw`
/// is per-codec since codec v2). All-atomic, like the neighbouring
/// stored/ops counters — the aggregator threads record their selections
/// without a serialization point.
#[derive(Default)]
struct CodecTally {
    store: AtomicU64,
    lz: AtomicU64,
    rc: AtomicU64,
    tans: AtomicU64,
    /// Raw bytes encoded per codec code (index = `Codec::code()`).
    raw_by_code: [AtomicU64; 10],
}

impl CodecTally {
    fn record(&self, applied: Option<Codec>, raw_bytes: u64) {
        match applied.map(|c| c.entropy()) {
            None => self.store.fetch_add(1, Ordering::Relaxed),
            Some(codec::Entropy::RangeCoder) => self.rc.fetch_add(1, Ordering::Relaxed),
            Some(codec::Entropy::Tans) => self.tans.fetch_add(1, Ordering::Relaxed),
            Some(codec::Entropy::None) => self.lz.fetch_add(1, Ordering::Relaxed),
        };
        if let Some(c) = applied {
            self.raw_by_code[c.code() as usize].fetch_add(raw_bytes, Ordering::Relaxed);
        }
    }

    fn chunks(&self) -> CodecChunks {
        CodecChunks {
            store: self.store.load(Ordering::Relaxed),
            lz: self.lz.load(Ordering::Relaxed),
            rc: self.rc.load(Ordering::Relaxed),
            tans: self.tans.load(Ordering::Relaxed),
        }
    }

    /// The codec that encoded the most raw bytes this write (`None` when
    /// every chunk stored raw — or no chunks moved at all).
    fn dominant(&self) -> Option<Codec> {
        let (code, bytes) = self
            .raw_by_code
            .iter()
            .enumerate()
            .map(|(i, b)| (i, b.load(Ordering::Relaxed)))
            .max_by_key(|&(_, b)| b)?;
        if bytes == 0 {
            return None;
        }
        Codec::from_code(code as u8).ok()
    }
}

/// Fold sink for the multi-resolution pyramid ([`crate::lod`]): when a
/// collective write carries one, the aggregators fold every assembled row
/// of the source dataset into the builder's accumulation buffers during
/// the fill phase — the pyramid rides the parallel write instead of
/// costing a second pass over the data (Jin et al. 2022).
pub struct LodSink<'a> {
    /// The pyramid's source dataset (the snapshot's `current_cell_data`).
    pub ds: &'a Dataset,
    pub builder: &'a PyramidBuilder,
}

impl LodSink<'_> {
    /// Is `other` the sink's source dataset? (Layout identity: the chunk
    /// registry id for chunked datasets, the payload offset for
    /// contiguous ones.)
    fn matches(&self, other: &Dataset) -> bool {
        match (&self.ds.layout, &other.layout) {
            (Layout::Chunked { id: a, .. }, Layout::Chunked { id: b, .. }) => a == b,
            (Layout::Contiguous { offset: a }, Layout::Contiguous { offset: b }) => a == b,
            _ => false,
        }
    }
}

/// The parallel I/O driver. `n_ranks` is the logical process count (the
/// scale the machine model prices); the real work is spread over this
/// host's cores, one thread per aggregator.
pub struct ParallelIo {
    pub machine: Machine,
    pub tuning: IoTuning,
    pub n_ranks: u64,
    /// Counters/timers of everything this driver moved (`pario.*`).
    pub metrics: Metrics,
    /// Global lock used when `tuning.file_locking` (GPFS token stand-in).
    lock: OrderedMutex<()>,
    /// In-transit epoch publisher attached to the snapshot file, if any —
    /// the driver only *reads* its stats (publish time, backlog) into each
    /// [`IoReport`]; attaching it to the file is the caller's move
    /// ([`crate::stream::EpochPublisher::attach`]).
    publisher: OrderedMutex<Option<Arc<crate::stream::EpochPublisher>>>,
}

/// An op the fill phase produced: contiguous rows of one dataset.
struct MergedOp {
    ds_offset: u64,
    row_bytes: u64,
    row_start: u64,
    data: Vec<u8>,
}

/// One chunk of one chunked dataset, assembled from the collective view of
/// every rank's slabs that touch it.
struct ChunkJob<'a> {
    ds: &'a Dataset,
    chunk_no: u64,
    /// `(row offset within the chunk, rows, source bytes)`.
    pieces: Vec<(u64, u64, &'a [u8])>,
    /// Rows of this chunk covered by the pieces (if short of the chunk's
    /// row count, the writer read-modify-writes against existing content).
    covered: u64,
}

impl ParallelIo {
    pub fn new(machine: Machine, tuning: IoTuning, n_ranks: u64) -> ParallelIo {
        ParallelIo {
            machine,
            tuning,
            n_ranks,
            metrics: Metrics::new(),
            lock: OrderedMutex::new(LockRank::ParioFileLock, ()),
            publisher: OrderedMutex::new(LockRank::ParioPublisher, None),
        }
    }

    /// Point the driver at the file's in-transit publisher so every
    /// [`IoReport`] carries publish-time and subscriber-backlog accounting
    /// (pass `None` to detach). The publisher itself must be attached to
    /// the snapshot file separately.
    pub fn set_publisher(&self, publisher: Option<Arc<crate::stream::EpochPublisher>>) {
        *self.publisher.lock().unwrap() = publisher;
    }

    /// Number of aggregators this driver will use.
    pub fn aggregators(&self) -> u64 {
        if self.tuning.collective_buffering {
            self.machine.aggregators(self.n_ranks)
        } else {
            self.n_ranks
        }
    }

    /// Perform a collective write of many hyperslabs, two-phase when
    /// collective buffering is enabled. `n_datasets`/`n_grids` feed the
    /// machine model (they describe the whole snapshot this write belongs
    /// to).
    pub fn collective_write(
        &self,
        file: &H5File,
        writes: &[SlabWrite],
        n_datasets: u64,
        n_grids: u64,
    ) -> Result<IoReport> {
        self.collective_write_lod(file, writes, n_datasets, n_grids, None)
    }

    /// [`ParallelIo::collective_write`] with an optional LOD fold sink:
    /// rows of the sink's source dataset are folded into the pyramid
    /// builder by the aggregator threads as they assemble them (fill-phase
    /// overlap — see [`LodSink`]).
    pub fn collective_write_lod(
        &self,
        file: &H5File,
        writes: &[SlabWrite],
        n_datasets: u64,
        n_grids: u64,
        lod: Option<&LodSink>,
    ) -> Result<IoReport> {
        let t0 = Instant::now();
        let bytes: u64 = writes.iter().map(|w| w.data.len() as u64).sum();
        let reclaimed0 = file.space_stats().reclaimed_bytes;
        let flush0 = file.flush_stats();
        let publisher = self.publisher.lock().unwrap().clone();
        let publish0 = publisher.as_ref().map(|p| p.stats().publish_seconds);
        let aggs = self.aggregators().max(1);

        let (contig, chunked): (Vec<&SlabWrite>, Vec<&SlabWrite>) =
            writes.iter().partition(|w| !w.ds.is_chunked());

        // --- phase 1a: fill aggregator buffers over contiguous slabs ----
        let mut per_agg: Vec<Vec<&SlabWrite>> = (0..aggs).map(|_| Vec::new()).collect();
        for &w in &contig {
            let a = (w.rank as u64 * aggs / self.n_ranks.max(1)).min(aggs - 1);
            per_agg[a as usize].push(w);
        }
        let merged: Vec<Vec<MergedOp>> = per_agg
            .iter()
            .map(|slabs| {
                let mut sorted: Vec<&&SlabWrite> = slabs.iter().collect();
                sorted.sort_by_key(|w| (w.ds.contiguous_offset().unwrap_or(0), w.row_start));
                let mut ops: Vec<MergedOp> = Vec::new();
                for w in sorted {
                    let off = w.ds.contiguous_offset().unwrap_or(0);
                    let rb = w.ds.row_bytes();
                    match ops.last_mut() {
                        Some(last)
                            if self.tuning.collective_buffering
                                && last.ds_offset == off
                                && last.row_start + last.data.len() as u64 / rb.max(1)
                                    == w.row_start =>
                        {
                            // contiguous with previous slab: one big op
                            last.data.extend_from_slice(w.data);
                        }
                        _ => ops.push(MergedOp {
                            ds_offset: off,
                            row_bytes: rb,
                            row_start: w.row_start,
                            data: w.data.to_vec(),
                        }),
                    }
                }
                ops
            })
            .collect();

        // --- phase 1b: re-bucket chunked slabs per chunk (collective view)
        let jobs = chunk_jobs(&chunked)?;
        let chunk_by_agg: Vec<Vec<&ChunkJob>> = {
            let mut v: Vec<Vec<&ChunkJob>> = (0..aggs).map(|_| Vec::new()).collect();
            for (i, j) in jobs.iter().enumerate() {
                v[i % aggs as usize].push(j);
            }
            v
        };

        // --- phase 2: stream to the file, one thread per aggregator -----
        // Contiguous runs pwrite directly; chunk jobs assemble, compress
        // (the fill-phase codec overlap) and append extents.
        let stored_atomic = AtomicU64::new(0);
        let ops_atomic = AtomicU64::new(0);
        let compress_ns = AtomicU64::new(0);
        let lod_ns = AtomicU64::new(0);
        let tally = CodecTally::default();
        // Leaf-adjacent rank: pushed to with the aggregator's file lock
        // (ParioFileLock) still held on the contiguous path.
        let errors = OrderedMutex::new(LockRank::ParioErrors, Vec::new());
        parallel_for(aggs as usize, |a| {
            for op in &merged[a] {
                let guard = if self.tuning.file_locking {
                    Some(self.lock.lock().unwrap())
                } else {
                    None
                };
                // reconstruct a dataset view for positional row writes
                let ds = Dataset {
                    dtype: Dtype::U8,
                    shape: vec![u64::MAX / op.row_bytes.max(1), op.row_bytes],
                    layout: Layout::Contiguous {
                        offset: op.ds_offset,
                    },
                };
                if let Err(e) = file.write_rows(&ds, op.row_start, &op.data) {
                    errors.lock().unwrap().push(e);
                }
                drop(guard);
                // the fold overlap also serves the uncompressed layout:
                // a contiguous source dataset folds from the merged ops
                if let Some(sink) = lod {
                    if sink.ds.contiguous_offset() == Some(op.ds_offset) {
                        let tl = Instant::now();
                        sink.builder.fold_rows(op.row_start, &op.data);
                        lod_ns.fetch_add(tl.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                }
                ops_atomic.fetch_add(1, Ordering::Relaxed);
                stored_atomic.fetch_add(op.data.len() as u64, Ordering::Relaxed);
            }
            for job in &chunk_by_agg[a] {
                match self.write_chunk_job(file, job, &compress_ns, lod, &lod_ns) {
                    Ok((stored, raw_bytes, applied)) => {
                        ops_atomic.fetch_add(1, Ordering::Relaxed);
                        stored_atomic.fetch_add(stored, Ordering::Relaxed);
                        tally.record(applied, raw_bytes);
                    }
                    Err(e) => errors.lock().unwrap().push(e),
                }
            }
        });
        if let Some(e) = errors.into_inner().unwrap().pop() {
            return Err(e);
        }

        let stored_bytes = stored_atomic.load(Ordering::Relaxed);
        let write_ops = ops_atomic.load(Ordering::Relaxed);
        let compress_seconds = compress_ns.load(Ordering::Relaxed) as f64 / 1e9;
        let lod_seconds = lod_ns.load(Ordering::Relaxed) as f64 / 1e9;
        let real_seconds = t0.elapsed().as_secs_f64().max(1e-9);
        let codec_chunks = tally.chunks();
        let workload = WriteWorkload {
            ranks: self.n_ranks,
            total_bytes: bytes,
            n_datasets,
            n_grids,
        };
        // price the compressed path only when compression actually shrank
        // the volume; RMW amplification (stored > raw on partial-chunk
        // writes) is not a compression win and the model has no term for
        // it. The model's per-codec compress_bw is looked up through the
        // codec that encoded the most raw bytes this write — the adaptive
        // selector can mix pipelines within one write, and the dominant
        // one is what the aggregator cores actually spent their time in.
        let dominant = tally.dominant().unwrap_or(Codec::SHUFFLE_DELTA_LZ);
        // On the paged backend the file returns as soon as the in-memory
        // image is consistent and the flusher drains in the background, so
        // the model prices the overlap (fill/codec vs. flush) instead of a
        // synchronous drain.
        let mut modelled = if file.backing() == Backing::Paged {
            self.machine
                .estimate_write_paged(&workload, &self.tuning, stored_bytes, dominant)
        } else if stored_bytes < bytes {
            self.machine
                .estimate_write_compressed(&workload, &self.tuning, stored_bytes, dominant)
        } else {
            self.machine.estimate_write(&workload, &self.tuning)
        };
        // price the pyramid fold. With collective buffering it pipelines
        // behind the fill/codec/stream stages on the aggregator threads,
        // so only the excess over the slowest stage costs modelled
        // wall-clock; independent I/O has no threads to pipeline behind —
        // each rank folds its own slabs serially, like the codec term in
        // the machine model's independent branch.
        if let Some(sink) = lod {
            let fold_bytes: u64 = writes
                .iter()
                .filter(|w| sink.matches(w.ds))
                .map(|w| w.data.len() as u64)
                .sum();
            if fold_bytes > 0 {
                let t_fold = if self.tuning.collective_buffering {
                    self.machine.estimate_fold(fold_bytes, self.n_ranks)
                } else {
                    fold_bytes as f64
                        / (self.n_ranks.max(1) as f64 * self.machine.fold_bw)
                };
                if self.tuning.collective_buffering {
                    let pipeline = modelled
                        .t_stream
                        .max(modelled.t_aggregate)
                        .max(modelled.t_compress);
                    modelled.seconds += (t_fold - pipeline).max(0.0);
                } else {
                    modelled.seconds += t_fold;
                }
                modelled.bandwidth = bytes as f64 / modelled.seconds;
                modelled.t_fold = t_fold;
            }
        }
        // space the free-space manager got back from rewritten chunks: the
        // estimate carries it so steady-state file size can be derived from
        // the model (stored bytes in, reclaimed bytes back out)
        let reclaimed_bytes = file
            .space_stats()
            .reclaimed_bytes
            .saturating_sub(reclaimed0);
        modelled.reclaimed_bytes = reclaimed_bytes;
        self.metrics.add("pario.bytes_raw", bytes);
        self.metrics.add("pario.bytes_stored", stored_bytes);
        self.metrics.add("pario.bytes_reclaimed", reclaimed_bytes);
        self.metrics.add("pario.write_ops", write_ops);
        self.metrics.add("pario.chunks", jobs.len() as u64);
        self.metrics.add("pario.chunks_store", codec_chunks.store);
        self.metrics.add("pario.chunks_lz", codec_chunks.lz);
        self.metrics.add("pario.chunks_rc", codec_chunks.rc);
        self.metrics.add("pario.chunks_tans", codec_chunks.tans);
        self.metrics
            .add_ns("pario.compress", compress_ns.load(Ordering::Relaxed));
        if let Some(sink) = lod {
            self.metrics.add("pario.lod_rows", sink.builder.rows_folded());
            self.metrics
                .add_ns("pario.lod_fold", lod_ns.load(Ordering::Relaxed));
        }
        // Flusher activity during this call (all-zero on the direct
        // backend). Backlog-seconds is estimated from the flusher's own
        // observed bandwidth so far; before it has flushed anything there
        // is no rate to divide by and the gauge reports 0.
        let flush1 = file.flush_stats();
        let flush_seconds = (flush1.busy_seconds - flush0.busy_seconds).max(0.0);
        let flush_backlog_bytes = flush1.dirty_bytes;
        self.metrics
            .set_gauge(names::H5_DIRTY_PAGES, flush1.dirty_pages as f64);
        self.metrics
            .set_gauge(names::H5_FLUSH_BYTES, flush1.flushed_bytes as f64);
        let backlog_seconds = if flush1.flushed_bytes > 0 && flush1.busy_seconds > 0.0 {
            flush_backlog_bytes as f64 / (flush1.flushed_bytes as f64 / flush1.busy_seconds)
        } else {
            0.0
        };
        self.metrics
            .set_gauge(names::H5_FLUSH_BACKLOG_SECONDS, backlog_seconds);
        let (publish_seconds, publish_backlog_bytes) = match (&publisher, publish0) {
            (Some(p), Some(s0)) => {
                let stats = p.stats();
                ((stats.publish_seconds - s0).max(0.0), stats.backlog_bytes)
            }
            _ => (0.0, 0),
        };
        Ok(IoReport {
            real_seconds,
            real_bandwidth: bytes as f64 / real_seconds,
            bytes,
            stored_bytes,
            write_ops,
            reclaimed_bytes,
            compress_seconds,
            codec_chunks,
            lod_seconds,
            flush_seconds,
            flush_backlog_bytes,
            publish_seconds,
            publish_backlog_bytes,
            modelled,
        })
    }

    /// Assemble, compress and store one chunk; returns the stored extent
    /// size, the raw bytes encoded, and the codec the adaptive selector
    /// applied (`None` = stored raw). Runs on an aggregator thread — the
    /// trial-compression and the selection happen right here, preserving
    /// the lock-free disjoint-write discipline (each chunk belongs to
    /// exactly one aggregator; nothing below takes a lock the contiguous
    /// path does not).
    fn write_chunk_job(
        &self,
        file: &H5File,
        job: &ChunkJob,
        compress_ns: &AtomicU64,
        lod: Option<&LodSink>,
        lod_ns: &AtomicU64,
    ) -> Result<(u64, u64, Option<Codec>)> {
        let rb = job.ds.row_bytes();
        let rows_here = job.ds.chunk_rows_at(job.chunk_no);
        let raw_len = (rows_here * rb) as usize;
        // partial collective coverage: merge over whatever the chunk held
        let mut raw = if job.covered < rows_here {
            file.read_chunk_raw(job.ds, job.chunk_no)?.as_ref().clone()
        } else {
            vec![0u8; raw_len]
        };
        for (row_off, rows, src) in &job.pieces {
            let at = (row_off * rb) as usize;
            raw[at..at + (rows * rb) as usize].copy_from_slice(src);
        }
        // the deep integration: codec runs here, on the aggregator thread,
        // while sibling aggregators are already streaming
        let (chunk_rows, chunk_codec, _) = job.ds.chunk_meta().unwrap();
        // pyramid fold of the assembled chunk — same overlap as the codec
        // (the merged `raw` covers the whole chunk, so even a
        // partial-coverage write folds the chunk's post-write content)
        if let Some(sink) = lod {
            if sink.matches(job.ds) {
                let tl = Instant::now();
                sink.builder.fold_rows(job.chunk_no * chunk_rows, &raw);
                lod_ns.fetch_add(tl.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
        let tc = Instant::now();
        let enc = codec::encode_chunk_adaptive(chunk_codec, &raw, job.ds.dtype.size());
        compress_ns.fetch_add(tc.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let stored = enc.stored_or(&raw);
        let guard = if self.tuning.file_locking {
            Some(self.lock.lock().unwrap())
        } else {
            None
        };
        file.write_chunk_encoded(
            job.ds,
            job.chunk_no,
            stored,
            raw.len() as u64,
            enc.checksum,
            enc.codec,
        )?;
        drop(guard);
        Ok((stored.len() as u64, raw.len() as u64, enc.codec))
    }
}

/// Re-bucket the collective view of chunked-dataset slabs into per-chunk
/// assembly jobs, deterministically ordered (dataset id, then chunk no).
/// Bounds are validated here — the contiguous path gets its range errors
/// from [`H5File::write_rows`] during phase 2, but an unchecked overrun
/// in the chunk walk would spin instead of failing.
fn chunk_jobs<'a>(chunked: &[&'a SlabWrite<'a>]) -> Result<Vec<ChunkJob<'a>>> {
    let mut per_chunk: BTreeMap<(u64, u64), ChunkJob<'a>> = BTreeMap::new();
    for w in chunked {
        let (_, _, id) = w.ds.chunk_meta().unwrap();
        let rb = w.ds.row_bytes().max(1);
        if w.data.len() as u64 % rb != 0 {
            bail!("pario: rank {} slab is not a whole number of rows", w.rank);
        }
        let rows = w.data.len() as u64 / rb;
        if w.row_start + rows > w.ds.shape[0] {
            bail!(
                "pario: rank {} hyperslab [{}, {}) exceeds {} rows",
                w.rank,
                w.row_start,
                w.row_start + rows,
                w.ds.shape[0]
            );
        }
        let mut done = 0u64;
        for (chunk_no, row_in_chunk, take) in w.ds.chunk_spans(w.row_start, rows) {
            let src_off = (done * rb) as usize;
            let src = &w.data[src_off..src_off + (take * rb) as usize];
            let job = per_chunk.entry((id, chunk_no)).or_insert_with(|| ChunkJob {
                ds: w.ds,
                chunk_no,
                pieces: Vec::new(),
                covered: 0,
            });
            job.pieces.push((row_in_chunk, take, src));
            job.covered += take;
            done += take;
        }
    }
    // slabs must be disjoint (the kernel's hyperslab contract): an overlap
    // would double-count `covered`, skip the read-modify-write and silently
    // zero the uncovered tail — fail loudly instead, like the other
    // validation above
    for job in per_chunk.values_mut() {
        job.pieces.sort_by_key(|&(row_off, _, _)| row_off);
        for i in 1..job.pieces.len() {
            let (prev_off, prev_rows, _) = job.pieces[i - 1];
            let (off, _, _) = job.pieces[i];
            if prev_off + prev_rows > off {
                bail!(
                    "pario: overlapping hyperslabs in chunk {} (rows {} and {})",
                    job.chunk_no,
                    prev_off + prev_rows,
                    off
                );
            }
        }
    }
    Ok(per_chunk.into_values().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5lite::codec::Codec;
    use crate::h5lite::{codec, Dtype};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pario_test_{}_{}", std::process::id(), name));
        p
    }

    fn make_writes<'a>(
        ds: &'a Dataset,
        bufs: &'a [Vec<u8>],
        rows_per_rank: u64,
    ) -> Vec<SlabWrite<'a>> {
        bufs.iter()
            .enumerate()
            .map(|(r, b)| SlabWrite {
                rank: r as u32,
                ds,
                row_start: r as u64 * rows_per_rank,
                data: b,
            })
            .collect()
    }

    #[test]
    fn collective_write_lands_all_bytes() {
        let p = tmp("all");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U64, &[32, 2]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..8u64)
            .map(|r| codec::u64s_to_bytes(&(0..8).map(|i| r * 100 + i).collect::<Vec<_>>()))
            .collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let rep = io.collective_write(&f, &writes, 1, 32).unwrap();
        assert_eq!(rep.bytes, 8 * 8 * 8);
        assert_eq!(rep.stored_bytes, rep.bytes); // contiguous: nothing compressed
        assert_eq!(rep.compress_seconds, 0.0);
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[8], 100);
        assert_eq!(all[63], 707);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn merging_reduces_write_ops() {
        let p = tmp("merge");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[64, 4]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..16).map(|r| vec![r as u8; 16]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        // collective: 16 contiguous rank slabs merge into few agg-sized ops
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
        let rep = io.collective_write(&f, &writes, 1, 64).unwrap();
        assert!(rep.write_ops <= io.aggregators());
        // independent: one op per rank slab
        let io2 = ParallelIo::new(
            Machine::local(),
            IoTuning {
                collective_buffering: false,
                ..IoTuning::default()
            },
            16,
        );
        let rep2 = io2.collective_write(&f, &writes, 1, 64).unwrap();
        assert_eq!(rep2.write_ops, 16);
        assert!(rep.write_ops < rep2.write_ops);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn disjoint_slabs_same_dataset_correct_under_locking() {
        let p = tmp("lock");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[128, 8]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..32).map(|r| vec![r as u8; 32]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(
            Machine::local(),
            IoTuning {
                file_locking: true,
                ..IoTuning::default()
            },
            32,
        );
        io.collective_write(&f, &writes, 1, 128).unwrap();
        let back = f.read_rows(&ds, 0, 128).unwrap();
        for r in 0..32usize {
            assert!(back[r * 32..(r + 1) * 32].iter().all(|&b| b == r as u8));
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn multi_dataset_writes_do_not_merge_across_datasets() {
        let p = tmp("multids");
        let mut f = H5File::create(&p, 1).unwrap();
        let d1 = f.create_dataset("/g", "a", Dtype::U8, &[8, 4]).unwrap();
        let d2 = f.create_dataset("/g", "b", Dtype::U8, &[8, 4]).unwrap();
        let b1 = vec![1u8; 32];
        let b2 = vec![2u8; 32];
        let writes = vec![
            SlabWrite {
                rank: 0,
                ds: &d1,
                row_start: 0,
                data: &b1,
            },
            SlabWrite {
                rank: 0,
                ds: &d2,
                row_start: 0,
                data: &b2,
            },
        ];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        let rep = io.collective_write(&f, &writes, 2, 8).unwrap();
        assert_eq!(rep.write_ops, 2);
        assert!(f.read_rows(&d1, 0, 8).unwrap().iter().all(|&b| b == 1));
        assert!(f.read_rows(&d2, 0, 8).unwrap().iter().all(|&b| b == 2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn report_contains_model_estimate() {
        let p = tmp("model");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[16, 4]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; 16]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::juqueen(), IoTuning::default(), 2048);
        let rep = io.collective_write(&f, &writes, 7, 16).unwrap();
        assert!(rep.modelled.seconds > 0.0);
        assert!(rep.real_bandwidth > 0.0);
        std::fs::remove_file(&p).ok();
    }

    // -------------------------------------------------------------------
    // edge cases
    // -------------------------------------------------------------------

    #[test]
    fn empty_slab_list_is_a_noop() {
        let p = tmp("empty");
        let f = H5File::create(&p, 1).unwrap();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let rep = io.collective_write(&f, &[], 0, 0).unwrap();
        assert_eq!(rep.bytes, 0);
        assert_eq!(rep.stored_bytes, 0);
        assert_eq!(rep.write_ops, 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn single_rank_write_lands() {
        let p = tmp("single");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U64, &[4, 2]).unwrap();
        let buf = codec::u64s_to_bytes(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let writes = vec![SlabWrite {
            rank: 0,
            ds: &ds,
            row_start: 0,
            data: &buf,
        }];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        let rep = io.collective_write(&f, &writes, 1, 4).unwrap();
        assert_eq!(rep.write_ops, 1);
        assert_eq!(f.read_all_u64(&ds).unwrap(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn non_adjacent_row_ranges_do_not_merge() {
        let p = tmp("gap");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[16, 4]).unwrap();
        let b1 = vec![1u8; 8]; // rows 0..2
        let b2 = vec![2u8; 8]; // rows 4..6 — a 2-row hole in between
        let writes = vec![
            SlabWrite {
                rank: 0,
                ds: &ds,
                row_start: 0,
                data: &b1,
            },
            SlabWrite {
                rank: 0,
                ds: &ds,
                row_start: 4,
                data: &b2,
            },
        ];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        let rep = io.collective_write(&f, &writes, 1, 16).unwrap();
        assert_eq!(rep.write_ops, 2, "a hole must split the physical ops");
        let back = f.read_rows(&ds, 0, 16).unwrap();
        assert!(back[0..8].iter().all(|&b| b == 1));
        assert!(back[8..16].iter().all(|&b| b == 0)); // hole untouched
        assert!(back[16..24].iter().all(|&b| b == 2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn locking_on_and_off_produce_identical_contents() {
        let mk = |name: &str, locking: bool| -> Vec<u8> {
            let p = tmp(name);
            let mut f = H5File::create(&p, 1).unwrap();
            let dc = f.create_dataset("/g", "plain", Dtype::U8, &[32, 4]).unwrap();
            let dk = f
                .create_dataset_chunked("/g", "packed", Dtype::F32, &[32, 8], 8, Codec::SHUFFLE_DELTA_LZ)
                .unwrap();
            let bufs: Vec<Vec<u8>> = (0..8).map(|r| vec![r as u8; 16]).collect();
            let fbufs: Vec<Vec<u8>> = (0..8)
                .map(|r| {
                    codec::f32s_to_bytes(
                        &(0..32).map(|i| r as f32 + i as f32 * 0.5).collect::<Vec<_>>(),
                    )
                })
                .collect();
            let mut writes = make_writes(&dc, &bufs, 4);
            writes.extend(make_writes(&dk, &fbufs, 4));
            let io = ParallelIo::new(
                Machine::local(),
                IoTuning {
                    file_locking: locking,
                    ..IoTuning::default()
                },
                8,
            );
            io.collective_write(&f, &writes, 2, 32).unwrap();
            // compare logical dataset contents (extent placement is
            // allocation-order dependent, the data must not be)
            let mut out = f.read_rows(&dc, 0, 32).unwrap();
            out.extend(f.read_rows(&dk, 0, 32).unwrap());
            std::fs::remove_file(&p).ok();
            out
        };
        assert_eq!(mk("lock_on", true), mk("lock_off", false));
    }

    // -------------------------------------------------------------------
    // chunked + compressed collective path
    // -------------------------------------------------------------------

    fn smooth_bufs(ranks: u64, rows_per_rank: u64, row_elems: usize) -> Vec<Vec<u8>> {
        (0..ranks)
            .map(|r| {
                let v: Vec<f32> = (0..rows_per_rank as usize * row_elems)
                    .map(|i| 2.0 + ((r as usize * 31 + i) as f32 * 1e-3).sin())
                    .collect();
                codec::f32s_to_bytes(&v)
            })
            .collect()
    }

    #[test]
    fn chunked_collective_write_roundtrips_and_compresses() {
        let p = tmp("chunk_coll");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[32, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let bufs = smooth_bufs(8, 4, 16);
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let rep = io.collective_write(&f, &writes, 1, 32).unwrap();
        assert_eq!(rep.bytes, 32 * 16 * 4);
        assert!(rep.stored_bytes < rep.bytes, "{rep:?}");
        assert_eq!(rep.write_ops, 4); // one op per chunk
        // chunk compression engaged → the model prices the reduced volume
        assert_eq!(rep.modelled.stored_bytes, rep.stored_bytes);
        let back = f.read_rows(&ds, 0, 32).unwrap();
        let want: Vec<u8> = bufs.concat();
        assert_eq!(back, want);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_spanning_two_ranks_is_assembled_from_both() {
        let p = tmp("chunk_span");
        let mut f = H5File::create(&p, 1).unwrap();
        // chunk_rows 4, but ranks own 3 rows each → every chunk boundary
        // crosses a rank boundary
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[12, 2], 4, Codec::LZ)
            .unwrap();
        let bufs: Vec<Vec<u8>> = (0..4u64)
            .map(|r| codec::u64s_to_bytes(&(0..6).map(|i| r * 10 + i).collect::<Vec<_>>()))
            .collect();
        let writes = make_writes(&ds, &bufs, 3);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let rep = io.collective_write(&f, &writes, 1, 12).unwrap();
        assert_eq!(rep.write_ops, 3);
        let all = f.read_all_u64(&ds).unwrap();
        for r in 0..4u64 {
            for i in 0..6u64 {
                assert_eq!(all[(r * 6 + i) as usize], r * 10 + i);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn partial_chunk_coverage_preserves_existing_rows() {
        let p = tmp("chunk_part");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[8, 1], 8, Codec::LZ)
            .unwrap();
        // seed all 8 rows directly
        f.write_rows(&ds, 0, &codec::u64s_to_bytes(&(0..8).collect::<Vec<_>>()))
            .unwrap();
        // collective write covering only rows 2..4
        let buf = codec::u64s_to_bytes(&[200, 300]);
        let writes = vec![SlabWrite {
            rank: 0,
            ds: &ds,
            row_start: 2,
            data: &buf,
        }];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        io.collective_write(&f, &writes, 1, 8).unwrap();
        assert_eq!(
            f.read_all_u64(&ds).unwrap(),
            vec![0, 1, 200, 300, 4, 5, 6, 7]
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn overlapping_chunked_slabs_rejected() {
        // overlap would double-count chunk coverage and skip the RMW,
        // silently zeroing rows — the collective write must refuse it
        let p = tmp("chunk_overlap");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[8, 1], 8, Codec::LZ)
            .unwrap();
        let b1 = codec::u64s_to_bytes(&[1, 2, 3, 4, 5, 6]); // rows 0..6
        let b2 = codec::u64s_to_bytes(&[7, 8]); // rows 0..2 — overlaps b1
        let writes = vec![
            SlabWrite {
                rank: 0,
                ds: &ds,
                row_start: 0,
                data: &b1,
            },
            SlabWrite {
                rank: 1,
                ds: &ds,
                row_start: 0,
                data: &b2,
            },
        ];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 2);
        assert!(io.collective_write(&f, &writes, 1, 8).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn out_of_range_chunked_slab_errors_instead_of_hanging() {
        let p = tmp("chunk_oob");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::U64, &[10, 1], 4, Codec::LZ)
            .unwrap();
        // 4 rows starting at row 8 of a 10-row dataset: 2 rows past the end
        let buf = codec::u64s_to_bytes(&[1, 2, 3, 4]);
        let writes = vec![SlabWrite {
            rank: 0,
            ds: &ds,
            row_start: 8,
            data: &buf,
        }];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        assert!(io.collective_write(&f, &writes, 1, 10).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn metrics_account_raw_and_stored() {
        let p = tmp("metrics");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let bufs = smooth_bufs(4, 4, 16);
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let rep = io.collective_write(&f, &writes, 1, 16).unwrap();
        assert_eq!(io.metrics.counter("pario.bytes_raw"), rep.bytes);
        assert_eq!(io.metrics.counter("pario.bytes_stored"), rep.stored_bytes);
        assert_eq!(io.metrics.counter("pario.chunks"), 2);
        assert!(io.metrics.seconds("pario.compress") > 0.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn lod_sink_folds_during_the_collective_write() {
        use crate::iokernel::ROW_ELEMS;
        use crate::lod::PyramidBuilder;
        use crate::tree::{sfc, BBox, SpaceTree};
        // a depth-1 domain: 9 rows (root + 8 leaves), each rank writes its
        // partition slice of the chunked source dataset; the sink must see
        // every leaf row exactly once, during the write itself
        let p = tmp("lod_fold");
        let mut tree = SpaceTree::full(BBox::unit(), 1);
        let part = sfc::partition(&mut tree, 3);
        let offsets = part.row_offsets();
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked(
                "/g",
                "cur",
                Dtype::F32,
                &[9, ROW_ELEMS as u64],
                4,
                Codec::SHUFFLE_DELTA_LZ,
            )
            .unwrap();
        let bufs: Vec<Vec<u8>> = (0..3)
            .map(|r| {
                codec::f32s_to_bytes(&vec![
                    5.0f32;
                    part.counts[r] as usize * ROW_ELEMS
                ])
            })
            .collect();
        let writes: Vec<SlabWrite> = bufs
            .iter()
            .enumerate()
            .map(|(r, b)| SlabWrite {
                rank: r as u32,
                ds: &ds,
                row_start: offsets[r],
                data: b,
            })
            .collect();
        let mut builder = PyramidBuilder::new(&tree, &part);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let rep = io
            .collective_write_lod(
                &f,
                &writes,
                1,
                9,
                Some(&LodSink {
                    ds: &ds,
                    builder: &builder,
                }),
            )
            .unwrap();
        assert_eq!(builder.rows_folded(), 8, "one fold per leaf row");
        assert_eq!(io.metrics.counter("pario.lod_rows"), 8);
        assert!(rep.lod_seconds >= 0.0);
        builder.finish().unwrap();
        let (_, cells) = builder.level_data(1).unwrap();
        assert!(cells.iter().all(|&x| x == 5.0), "uniform leaves fold to 5.0");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn adaptive_codec_classes_accounted_per_write() {
        // a collective write whose chunks differ in character: the report
        // and the metrics must attribute every chunk to its storage class
        let p = tmp("codec_classes");
        let mut f = H5File::create(&p, 1).unwrap();
        // 4 chunks of 8 rows × 1024 f32
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[32, 1024], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let mut s = 0xDEAD_BEEFu64;
        let mut noise_f32 = || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            // every byte plane random — truly incompressible bit patterns
            f32::from_bits((s >> 16) as u32)
        };
        // ranks 0/1 carry smooth rows (chunks 0-1), ranks 2/3 noise
        let bufs: Vec<Vec<u8>> = (0..4u64)
            .map(|r| {
                let v: Vec<f32> = (0..8 * 1024)
                    .map(|i| {
                        if r < 2 {
                            1.0 + ((r as usize * 8192 + i) as f32 * 1e-3).sin() * 0.25
                        } else {
                            noise_f32()
                        }
                    })
                    .collect();
                codec::f32s_to_bytes(&v)
            })
            .collect();
        let writes = make_writes(&ds, &bufs, 8);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let rep = io.collective_write(&f, &writes, 1, 32).unwrap();
        let c = rep.codec_chunks;
        assert_eq!(c.store + c.lz + c.rc + c.tans, 4, "{c:?}");
        assert!(c.rc + c.tans >= 1, "smooth chunks must take an entropy stage: {c:?}");
        assert!(c.store >= 1, "noise chunks must store raw: {c:?}");
        assert_eq!(io.metrics.counter("pario.chunks_store"), c.store);
        assert_eq!(io.metrics.counter("pario.chunks_lz"), c.lz);
        assert_eq!(io.metrics.counter("pario.chunks_rc"), c.rc);
        assert_eq!(io.metrics.counter("pario.chunks_tans"), c.tans);
        // round trip through the mixed per-chunk codecs
        let back = f.read_rows(&ds, 0, 32).unwrap();
        assert_eq!(back, bufs.concat());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_rewrites_reclaim_space_through_the_free_list() {
        // a second collective write over the same chunked rows retires the
        // first write's extents into the free-space manager, and the report,
        // the metrics and the model estimate all account the bytes
        let p = tmp("reclaim");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 16], 8, Codec::SHUFFLE_DELTA_LZ)
            .unwrap();
        let bufs = smooth_bufs(4, 4, 16);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let first = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 16)
            .unwrap();
        assert_eq!(first.reclaimed_bytes, 0, "first write abandons nothing");
        let second = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 16)
            .unwrap();
        assert_eq!(
            second.reclaimed_bytes, first.stored_bytes,
            "every extent of the first write must be retired"
        );
        assert_eq!(second.modelled.reclaimed_bytes, second.reclaimed_bytes);
        assert_eq!(
            io.metrics.counter("pario.bytes_reclaimed"),
            second.reclaimed_bytes
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn paged_backend_reports_flush_activity_and_direct_reports_none() {
        let bufs = smooth_bufs(8, 4, 16);

        // direct backend: writes are synchronous, the flush fields are inert
        let p = tmp("flush_direct");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::F32, &[32, 16]).unwrap();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let rep = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 32)
            .unwrap();
        assert_eq!(rep.flush_seconds, 0.0);
        assert_eq!(rep.flush_backlog_bytes, 0);
        assert!(rep.modelled.t_stream > 0.0, "direct pricing streams inline");
        std::fs::remove_file(&p).ok();

        // paged backend: the collective write lands in the image, so the
        // report carries a backlog, the gauges see dirty pages, and the
        // model prices the overlapped (commit-return + drain) shape
        let p2 = tmp("flush_paged");
        let mut f2 = H5File::create_backed(&p2, 1, Backing::Paged).unwrap();
        let ds2 = f2.create_dataset("/g", "d", Dtype::F32, &[32, 16]).unwrap();
        let io2 = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let rep2 = io2
            .collective_write(&f2, &make_writes(&ds2, &bufs, 4), 1, 32)
            .unwrap();
        assert!(
            rep2.flush_backlog_bytes > 0,
            "un-barriered image bytes must show as backlog: {rep2:?}"
        );
        assert!(io2.metrics.gauge(names::H5_DIRTY_PAGES) >= 1.0);
        assert_eq!(
            rep2.modelled.t_stream, 0.0,
            "paged pricing moves streaming off the critical path"
        );
        // drain, then confirm the bytes actually landed
        f2.commit().unwrap();
        f2.wait_durable().unwrap();
        assert_eq!(f2.flush_stats().dirty_bytes, 0, "drained after wait_durable");
        assert_eq!(f2.read_rows(&ds2, 0, 32).unwrap(), bufs.concat());
        // a follow-up write refreshes the gauges against the now-active
        // flusher: cumulative flushed bytes and a fresh backlog estimate
        io2.collective_write(&f2, &make_writes(&ds2, &bufs, 4), 1, 32)
            .unwrap();
        assert!(io2.metrics.gauge(names::H5_FLUSH_BYTES) > 0.0);
        assert!(io2.metrics.gauge(names::H5_FLUSH_BACKLOG_SECONDS) > 0.0);
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn publish_accounting_rides_the_report() {
        let bufs = smooth_bufs(8, 4, 16);
        let p = tmp("publish_report");
        let mut f = H5File::create_backed(&p, 1, Backing::Paged).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::F32, &[32, 16]).unwrap();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);

        // no publisher attached: the publish fields are inert
        let rep = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 32)
            .unwrap();
        assert_eq!(rep.publish_seconds, 0.0);
        assert_eq!(rep.publish_backlog_bytes, 0);

        // attach one and commit inside the measured window: the tee's time
        // on the commit path must surface in the report
        let publisher = crate::stream::EpochPublisher::bind(
            "127.0.0.1:0",
            crate::stream::PublisherOptions::default(),
        )
        .unwrap();
        publisher.attach(&f).unwrap();
        io.set_publisher(Some(Arc::clone(&publisher)));
        io.collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 32)
            .unwrap();
        f.commit().unwrap();
        let rep2 = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 32)
            .unwrap();
        let _ = rep2;
        f.commit().unwrap();
        let stats = publisher.stats();
        assert!(
            stats.publish_seconds > 0.0 && stats.published_bytes > 0,
            "commits must run the tee: {stats:?}"
        );
        io.set_publisher(None);
        let rep3 = io
            .collective_write(&f, &make_writes(&ds, &bufs, 4), 1, 32)
            .unwrap();
        assert_eq!(rep3.publish_seconds, 0.0, "detached driver stops reporting");
        drop(f);
        publisher.shutdown();
        std::fs::remove_file(&p).ok();
    }
}
