//! **pario** — the MPI-IO-like parallel I/O middleware with collective
//! buffering (two-phase I/O).
//!
//! This is the layer between the I/O kernel and the file format, playing
//! the role ROMIO/MPI-IO plays under Parallel HDF5 (paper §3, §5.2):
//!
//! * every logical rank contributes hyperslab writes (a dataset, a row
//!   range, bytes);
//! * with **collective buffering** on, ranks are grouped onto *aggregators*
//!   (the bridge nodes of §5.2); each aggregator concatenates its ranks'
//!   slabs — a real memcpy "fill" phase — merges adjacent row ranges into
//!   few large contiguous operations, and streams them to the file from its
//!   own thread;
//! * with collective buffering off, every rank issues its own small write
//!   ops directly (the paper's "severe contention" baseline);
//! * with **file locking** on, a global lock serialises every write op —
//!   the real wall-clock effect of GPFS's conservative byte-range locking
//!   that the paper disables (safe because hyperslabs are disjoint).
//!
//! Every collective write returns an [`IoReport`] with both the *real*
//! measured duration/op-counts on this host and the *modelled* duration on
//! the target [`Machine`] (how long the same byte/op pattern would take on
//! JuQueen/SuperMUC) — benches report the modelled number, EXPERIMENTS.md
//! records both.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::cluster::{IoEstimate, IoTuning, Machine, WriteWorkload};
use crate::h5lite::{Dataset, H5File};
use crate::util::parallel_for;

/// One rank's contribution to a collective dataset write.
pub struct SlabWrite<'a> {
    pub rank: u32,
    pub ds: &'a Dataset,
    pub row_start: u64,
    pub data: &'a [u8],
}

/// Outcome of one collective write: real measurement + machine model.
#[derive(Clone, Copy, Debug)]
pub struct IoReport {
    /// Wall-clock seconds of the real file I/O on this host.
    pub real_seconds: f64,
    /// Real bandwidth achieved on this host (bytes/s).
    pub real_bandwidth: f64,
    /// Payload bytes written.
    pub bytes: u64,
    /// Physical write ops issued after merging.
    pub write_ops: u64,
    /// Modelled cost on the target machine.
    pub modelled: IoEstimate,
}

/// The parallel I/O driver. `n_ranks` is the logical process count (the
/// scale the machine model prices); the real work is spread over this
/// host's cores, one thread per aggregator.
pub struct ParallelIo {
    pub machine: Machine,
    pub tuning: IoTuning,
    pub n_ranks: u64,
    /// Global lock used when `tuning.file_locking` (GPFS token stand-in).
    lock: Mutex<()>,
}

/// An op the fill phase produced: contiguous rows of one dataset.
struct MergedOp {
    ds_offset: u64,
    row_bytes: u64,
    row_start: u64,
    data: Vec<u8>,
}

impl ParallelIo {
    pub fn new(machine: Machine, tuning: IoTuning, n_ranks: u64) -> ParallelIo {
        ParallelIo {
            machine,
            tuning,
            n_ranks,
            lock: Mutex::new(()),
        }
    }

    /// Number of aggregators this driver will use.
    pub fn aggregators(&self) -> u64 {
        if self.tuning.collective_buffering {
            self.machine.aggregators(self.n_ranks)
        } else {
            self.n_ranks
        }
    }

    /// Perform a collective write of many hyperslabs, two-phase when
    /// collective buffering is enabled. `n_datasets`/`n_grids` feed the
    /// machine model (they describe the whole snapshot this write belongs
    /// to).
    pub fn collective_write(
        &self,
        file: &H5File,
        writes: &[SlabWrite],
        n_datasets: u64,
        n_grids: u64,
    ) -> Result<IoReport> {
        let t0 = Instant::now();
        let bytes: u64 = writes.iter().map(|w| w.data.len() as u64).sum();

        // --- phase 1: fill aggregator buffers (real memcpy) -------------
        let aggs = self.aggregators().max(1);
        let mut per_agg: Vec<Vec<&SlabWrite>> = (0..aggs).map(|_| Vec::new()).collect();
        for w in writes {
            let a = (w.rank as u64 * aggs / self.n_ranks.max(1)).min(aggs - 1);
            per_agg[a as usize].push(w);
        }
        let merged: Vec<Vec<MergedOp>> = per_agg
            .iter()
            .map(|slabs| {
                let mut sorted: Vec<&&SlabWrite> = slabs.iter().collect();
                sorted.sort_by_key(|w| (w.ds.offset, w.row_start));
                let mut ops: Vec<MergedOp> = Vec::new();
                for w in sorted {
                    let rb = w.ds.row_bytes();
                    let rows = w.data.len() as u64 / rb.max(1);
                    match ops.last_mut() {
                        Some(last)
                            if self.tuning.collective_buffering
                                && last.ds_offset == w.ds.offset
                                && last.row_start + last.data.len() as u64 / rb.max(1)
                                    == w.row_start =>
                        {
                            // contiguous with previous slab: one big op
                            last.data.extend_from_slice(w.data);
                        }
                        _ => ops.push(MergedOp {
                            ds_offset: w.ds.offset,
                            row_bytes: rb,
                            row_start: w.row_start,
                            data: w.data.to_vec(),
                        }),
                    }
                    let _ = rows;
                }
                ops
            })
            .collect();

        // --- phase 2: stream to the file, one thread per aggregator -----
        let write_ops: u64 = merged.iter().map(|ops| ops.len() as u64).sum();
        let errors = Mutex::new(Vec::new());
        parallel_for(merged.len(), |a| {
            for op in &merged[a] {
                let guard = if self.tuning.file_locking {
                    Some(self.lock.lock().unwrap())
                } else {
                    None
                };
                // reconstruct a dataset view for positional row writes
                let ds = Dataset {
                    dtype: crate::h5lite::Dtype::U8,
                    shape: vec![u64::MAX / op.row_bytes.max(1), op.row_bytes],
                    offset: op.ds_offset,
                };
                if let Err(e) = file.write_rows(&ds, op.row_start, &op.data) {
                    errors.lock().unwrap().push(e);
                }
                drop(guard);
            }
        });
        if let Some(e) = errors.into_inner().unwrap().pop() {
            return Err(e);
        }

        let real_seconds = t0.elapsed().as_secs_f64().max(1e-9);
        let modelled = self.machine.estimate_write(
            &WriteWorkload {
                ranks: self.n_ranks,
                total_bytes: bytes,
                n_datasets,
                n_grids,
            },
            &self.tuning,
        );
        Ok(IoReport {
            real_seconds,
            real_bandwidth: bytes as f64 / real_seconds,
            bytes,
            write_ops,
            modelled,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5lite::{codec, Dtype};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pario_test_{}_{}", std::process::id(), name));
        p
    }

    fn make_writes<'a>(
        ds: &'a Dataset,
        bufs: &'a [Vec<u8>],
        rows_per_rank: u64,
    ) -> Vec<SlabWrite<'a>> {
        bufs.iter()
            .enumerate()
            .map(|(r, b)| SlabWrite {
                rank: r as u32,
                ds,
                row_start: r as u64 * rows_per_rank,
                data: b,
            })
            .collect()
    }

    #[test]
    fn collective_write_lands_all_bytes() {
        let p = tmp("all");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U64, &[32, 2]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..8u64)
            .map(|r| codec::u64s_to_bytes(&(0..8).map(|i| r * 100 + i).collect::<Vec<_>>()))
            .collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let rep = io.collective_write(&f, &writes, 1, 32).unwrap();
        assert_eq!(rep.bytes, 8 * 8 * 8);
        let all = f.read_all_u64(&ds).unwrap();
        assert_eq!(all[0], 0);
        assert_eq!(all[8], 100);
        assert_eq!(all[63], 707);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn merging_reduces_write_ops() {
        let p = tmp("merge");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[64, 4]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..16).map(|r| vec![r as u8; 16]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        // collective: 16 contiguous rank slabs merge into few agg-sized ops
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
        let rep = io.collective_write(&f, &writes, 1, 64).unwrap();
        assert!(rep.write_ops <= io.aggregators());
        // independent: one op per rank slab
        let io2 = ParallelIo::new(
            Machine::local(),
            IoTuning {
                collective_buffering: false,
                ..IoTuning::default()
            },
            16,
        );
        let rep2 = io2.collective_write(&f, &writes, 1, 64).unwrap();
        assert_eq!(rep2.write_ops, 16);
        assert!(rep.write_ops < rep2.write_ops);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn disjoint_slabs_same_dataset_correct_under_locking() {
        let p = tmp("lock");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[128, 8]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..32).map(|r| vec![r as u8; 32]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(
            Machine::local(),
            IoTuning {
                file_locking: true,
                ..IoTuning::default()
            },
            32,
        );
        io.collective_write(&f, &writes, 1, 128).unwrap();
        let back = f.read_rows(&ds, 0, 128).unwrap();
        for r in 0..32usize {
            assert!(back[r * 32..(r + 1) * 32].iter().all(|&b| b == r as u8));
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn multi_dataset_writes_do_not_merge_across_datasets() {
        let p = tmp("multids");
        let mut f = H5File::create(&p, 1).unwrap();
        let d1 = f.create_dataset("/g", "a", Dtype::U8, &[8, 4]).unwrap();
        let d2 = f.create_dataset("/g", "b", Dtype::U8, &[8, 4]).unwrap();
        let b1 = vec![1u8; 32];
        let b2 = vec![2u8; 32];
        let writes = vec![
            SlabWrite {
                rank: 0,
                ds: &d1,
                row_start: 0,
                data: &b1,
            },
            SlabWrite {
                rank: 0,
                ds: &d2,
                row_start: 0,
                data: &b2,
            },
        ];
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 1);
        let rep = io.collective_write(&f, &writes, 2, 8).unwrap();
        assert_eq!(rep.write_ops, 2);
        assert!(f.read_rows(&d1, 0, 8).unwrap().iter().all(|&b| b == 1));
        assert!(f.read_rows(&d2, 0, 8).unwrap().iter().all(|&b| b == 2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn report_contains_model_estimate() {
        let p = tmp("model");
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::U8, &[16, 4]).unwrap();
        let bufs: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; 16]).collect();
        let writes = make_writes(&ds, &bufs, 4);
        let io = ParallelIo::new(Machine::juqueen(), IoTuning::default(), 2048);
        let rep = io.collective_write(&f, &writes, 7, 16).unwrap();
        assert!(rep.modelled.seconds > 0.0);
        assert!(rep.real_bandwidth > 0.0);
        std::fs::remove_file(&p).ok();
    }
}
