//! **Computational steering & Time Reversible Steering (TRS)** — paper §4.
//!
//! Classical steering: the front end issues commands against the *running*
//! simulation — altered boundary conditions, moved geometry, refinement or
//! coarsening of the simulation space.
//!
//! TRS extends this with the I/O kernel's time axis: any written snapshot
//! can be reloaded ("reverse in time"), steered, and resumed — each
//! rollback creating a **branching file** so the original trajectory stays
//! intact (Fig 5's branching simulation paths).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Simulation;
use crate::h5lite::H5File;
use crate::iokernel;
use crate::nbs::{Face, NeighbourhoodServer};
use crate::pario::ParallelIo;
use crate::physics::bc::{self, FaceBc};
use crate::tree::dgrid::{CellType, DGrid};
use crate::tree::{sfc, BBox};

/// A steering command, as issued by the front end (paper §4: "the ordering
/// of refinements or coarsenings of the simulation space, or the altering
/// of boundary conditions, for example moving geometry or influencing
/// velocity constraints").
#[derive(Clone, Debug)]
pub enum SteerCommand {
    /// Replace the boundary condition of one domain face.
    SetFaceBc { face: Face, bc: FaceBc },
    /// Insert solid geometry: a sphere (or a cylinder when `ignore_axis`
    /// projects the distance). `temp` makes it a heated solid.
    AddObstacle {
        centre: [f64; 3],
        radius: f64,
        temp: Option<f32>,
        ignore_axis: Option<usize>,
    },
    /// Remove all solid cells (geometry will be re-voxelised by subsequent
    /// AddObstacle commands — this is how "moving geometry" works).
    ClearObstacles,
    /// Refine every leaf grid intersecting the region (one level).
    Refine { region: BBox },
    /// Set the temperature of all currently heated solids (lamp steering
    /// in the operation-theatre scenario).
    SetHeatedSolidTemp { temp: f32 },
}

/// Apply a steering command to the live simulation.
pub fn apply(sim: &mut Simulation, cmd: &SteerCommand) {
    match cmd {
        SteerCommand::SetFaceBc { face, bc } => {
            *sim.bc.face_mut(*face) = *bc;
        }
        SteerCommand::AddObstacle {
            centre,
            radius,
            temp,
            ignore_axis,
        } => {
            let kind = if temp.is_some() {
                CellType::HeatedSolid
            } else {
                CellType::Solid
            };
            let nodes: Vec<(u32, BBox)> = sim
                .nbs
                .tree
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| (i as u32, n.bbox))
                .collect();
            for (i, bbox) in nodes {
                bc::voxelise_sphere(
                    &mut sim.grids[i as usize],
                    &bbox,
                    *centre,
                    *radius,
                    kind,
                    *temp,
                    *ignore_axis,
                );
            }
            sim.has_solids = true;
        }
        SteerCommand::ClearObstacles => {
            for g in &mut sim.grids {
                bc::clear_solids(g);
            }
            sim.has_solids = false;
        }
        SteerCommand::Refine { region } => {
            refine_region(sim, region);
        }
        SteerCommand::SetHeatedSolidTemp { temp } => {
            use crate::tree::dgrid::pidx;
            use crate::var;
            for g in &mut sim.grids {
                for i in 0..crate::DGRID_N {
                    for j in 0..crate::DGRID_N {
                        for k in 0..crate::DGRID_N {
                            if g.cell_type(i, j, k) == CellType::HeatedSolid {
                                let p = pidx(i + 1, j + 1, k + 1);
                                g.cur.var_mut(var::T)[p] = *temp;
                                g.prev.var_mut(var::T)[p] = *temp;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Refine all leaves intersecting `region` by one level: the tree grows,
/// new d-grids receive piecewise-constant prolongations of their parents'
/// data, and the domain is repartitioned along the Lebesgue curve.
fn refine_region(sim: &mut Simulation, region: &BBox) {
    let to_refine: Vec<u32> = sim
        .nbs
        .tree
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| {
            n.is_leaf() && n.bbox.intersects(region) && n.depth() < crate::tree::uid::MAX_DEPTH
        })
        .map(|(i, _)| i as u32)
        .collect();
    if to_refine.is_empty() {
        return;
    }
    let mut tree = std::mem::take(&mut sim.nbs.tree);
    for idx in to_refine {
        tree.refine(idx);
    }
    tree.balance();
    // extend the grid arena for new nodes; prolong parent data into them
    let n_ranks = sim.part.n_ranks;
    while sim.grids.len() < tree.len() {
        let idx = sim.grids.len();
        let node = &tree.nodes[idx];
        let mut g = DGrid::new(node.uid());
        prolong_from_parent(&tree, &sim.grids, idx as u32, &mut g, node.parent);
        sim.grids.push(g);
    }
    sim.part = sfc::partition(&mut tree, n_ranks);
    // refresh UIDs after repartition
    for (i, n) in tree.nodes.iter().enumerate() {
        sim.grids[i].uid = n.uid();
    }
    sim.nbs = NeighbourhoodServer::new(tree);
}

/// Fill a freshly created child d-grid from its parent's octant (all three
/// generations + cell types) — piecewise-constant prolongation.
fn prolong_from_parent(
    tree: &crate::tree::SpaceTree,
    grids: &[DGrid],
    child_idx: u32,
    child: &mut DGrid,
    parent_idx: u32,
) {
    use crate::tree::dgrid::{iidx, pidx};
    let n = crate::DGRID_N;
    let m = n / 2;
    let oct = tree.nodes[child_idx as usize].loc.octant();
    let (oi, oj, ok) = (
        ((oct >> 2) & 1) as usize,
        ((oct >> 1) & 1) as usize,
        (oct & 1) as usize,
    );
    let parent = &grids[parent_idx as usize];
    for v in 0..crate::NVAR {
        for (pgen, cgen) in [
            (&parent.cur, &mut child.cur),
            (&parent.prev, &mut child.prev),
            (&parent.temp, &mut child.temp),
        ] {
            let pf = pgen.var(v);
            let cf = cgen.var_mut(v);
            for i in 0..n {
                for j in 0..n {
                    for k in 0..n {
                        let val =
                            pf[pidx(oi * m + i / 2 + 1, oj * m + j / 2 + 1, ok * m + k / 2 + 1)];
                        cf[pidx(i + 1, j + 1, k + 1)] = val;
                    }
                }
            }
        }
    }
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                child.cell_type[iidx(i, j, k)] =
                    parent.cell_type[iidx(oi * m + i / 2, oj * m + j / 2, ok * m + k / 2)];
            }
        }
    }
}

/// The TRS session: tracks the active output file and its branch ancestry.
pub struct TrsSession {
    /// Path of the file currently receiving snapshots.
    pub active_path: PathBuf,
    pub file: H5File,
    /// Branch counter for generated file names.
    branches: u32,
    /// Pool behind [`TrsSession::reader`]: front-end sessions opened on the
    /// same `(timestep, epoch)` share one parsed topology/`LodIndex` and
    /// one decoded-chunk cache. Keys include a path hash and the pinned
    /// epoch, so cores opened before a [`TrsSession::rollback`] or a later
    /// commit simply age out once their sessions drop.
    readers: crate::window::ReaderPool,
    /// In-transit publisher teeing this session's commits, if
    /// [`TrsSession::publish`] was called.
    publisher: Option<Arc<crate::stream::EpochPublisher>>,
}

impl TrsSession {
    /// Start a session writing to `path` (creates the file + /common).
    pub fn create(
        path: &Path,
        sim: &Simulation,
        alignment: u64,
    ) -> Result<TrsSession> {
        TrsSession::create_backed(path, sim, alignment, crate::h5lite::Backing::Direct)
    }

    /// [`TrsSession::create`] on an explicit storage backend. The paged
    /// backend is what in-transit publishing tees — a session that intends
    /// to [`TrsSession::publish`] must be created with
    /// [`crate::h5lite::Backing::Paged`].
    pub fn create_backed(
        path: &Path,
        sim: &Simulation,
        alignment: u64,
        backing: crate::h5lite::Backing,
    ) -> Result<TrsSession> {
        let mut file = H5File::create_backed(path, alignment, backing)?;
        iokernel::write_common(&mut file, &sim.params, &sim.nbs.tree, sim.part.n_ranks as u64)?;
        Ok(TrsSession {
            active_path: path.to_path_buf(),
            file,
            branches: 0,
            readers: crate::window::ReaderPool::new(crate::h5lite::DEFAULT_CHUNK_CACHE_BYTES),
            publisher: None,
        })
    }

    /// Publish this session's committed epochs in transit: bind an
    /// [`crate::stream::EpochPublisher`] on `addr` and tee the active
    /// file's flush batches through it, so remote viewers can follow the
    /// steered run file-lessly ([`crate::stream::StreamSubscriber`] /
    /// [`crate::window::Collector::spawn_follower`]). Needs a session
    /// created on the paged backend ([`TrsSession::create_backed`]).
    ///
    /// Publishing covers the *active* file only: a
    /// [`TrsSession::rollback`] branches into a fresh file, ending the
    /// stream (subscribers' mirrors are of the old path) — call `publish`
    /// again on the branch to resume.
    pub fn publish<A: std::net::ToSocketAddrs>(
        &mut self,
        addr: A,
        opts: crate::stream::PublisherOptions,
    ) -> Result<Arc<crate::stream::EpochPublisher>> {
        let publisher = crate::stream::EpochPublisher::bind(addr, opts)?;
        publisher
            .attach(&self.file)
            .context("trs: publish needs a paged-backed session")?;
        self.publisher = Some(Arc::clone(&publisher));
        Ok(publisher)
    }

    /// The active publisher, if [`TrsSession::publish`] was called (and no
    /// rollback ended it since) — lag/backlog stats for the steering loop.
    pub fn publisher(&self) -> Option<&Arc<crate::stream::EpochPublisher>> {
        self.publisher.as_ref()
    }

    /// Write a snapshot of the simulation at its current time.
    pub fn checkpoint(&mut self, sim: &Simulation, io: &ParallelIo) -> Result<()> {
        iokernel::write_snapshot(
            &mut self.file,
            io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            sim.t,
        )?;
        Ok(())
    }

    /// Snapshots available for rollback.
    pub fn timesteps(&self) -> Vec<f64> {
        iokernel::list_timesteps(&self.file)
    }

    /// Open an epoch-pinned [`crate::window::SnapshotReader`] session over
    /// the active file's snapshot at `t` — the front end's read path while
    /// the steered run keeps checkpointing and rewriting. The session
    /// keeps serving byte-identical data across later commits (the pin
    /// parks retired extents) and even across a [`TrsSession::rollback`]
    /// branch switch: it holds its own descriptor on the file it opened.
    ///
    /// Sessions are pooled ([`crate::window::ReaderPool`]): concurrent
    /// front-end viewers of the same `(t, epoch)` share the parsed indexes
    /// and the decoded-chunk cache. Pooling on the writer's *own* handle is
    /// what makes the pins sound under SWMR — they park retired extents in
    /// the same descriptor family the rewrites retire them from.
    pub fn reader(&self, t: f64) -> Result<crate::window::SnapshotReader> {
        self.readers.open(&self.file, t)
    }

    /// The session pool behind [`TrsSession::reader`] (shared-cache stats,
    /// live-core count).
    pub fn reader_pool(&self) -> &crate::window::ReaderPool {
        &self.readers
    }

    /// **The time reversal**: reload the snapshot at `t`, branch the output
    /// into a new file (`<stem>.branch<N>.h5`), and return the restored
    /// simulation positioned at `t`. The previous file is left complete —
    /// branching simulation paths, Fig 5.
    pub fn rollback(&mut self, t: f64, io: &ParallelIo, bc: crate::physics::bc::DomainBc) -> Result<Simulation> {
        self.branches += 1;
        let branch_path = self
            .active_path
            .with_extension(format!("branch{}.h5", self.branches));
        let branch = iokernel::branch_file(&self.file, t, &branch_path, io)
            .context("trs: rollback branch")?;
        let snap = iokernel::read_snapshot(&branch, t)?;
        if let Some(p) = self.publisher.take() {
            // the stream follows the *file*, and the branch is a new one:
            // end the old stream cleanly (subscribers see EOF and can
            // reconnect to a fresh publish on the branch)
            p.shutdown();
        }
        self.file = branch;
        self.active_path = branch_path;
        let mut sim = Simulation::from_snapshot(snap, bc);
        sim.t = t;
        Ok(sim)
    }
}

/// Follow a remote steered run file-lessly: subscribe to its publisher at
/// `addr` (catching up from `source`, the run's snapshot file, into the
/// local `mirror`), and spawn a [`crate::window::Collector`] serving
/// window/LOD sessions from the mirror's latest applied epoch — the
/// viewer-side composition of [`TrsSession::publish`]. After a disconnect,
/// drop the collector and call this again: reconnect-resync is a fresh
/// file catch-up.
pub fn follow_run<A: std::net::ToSocketAddrs>(
    addr: A,
    source: &Path,
    mirror: &Path,
    t: f64,
    opts: &crate::window::CollectorOptions,
) -> Result<crate::window::Collector> {
    let sub = crate::stream::StreamSubscriber::connect(addr, source, mirror)
        .context("steering: follow subscribe")?;
    crate::window::Collector::spawn_follower(sub, t, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::physics::bc::DomainBc;
    use crate::physics::{Params, RustBackend};
    use crate::tree::SpaceTree;
    use crate::var;

    fn sim() -> Simulation {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut s = Simulation::new(
            tree,
            2,
            DomainBc::channel(1.0, 300.0),
            Params {
                dt: 0.002,
                h: 1.0 / 32.0,
                nu: 0.01,
                alpha: 0.01,
                beta_g: 0.0,
                t_inf: 300.0,
                q_int: 0.0,
                rho: 1.0,
                omega: 1.0,
            },
        );
        s.init_temperature(300.0);
        s
    }

    #[test]
    fn set_face_bc_takes_effect() {
        let mut s = sim();
        apply(
            &mut s,
            &SteerCommand::SetFaceBc {
                face: Face::XM,
                bc: FaceBc::inflow(2.5, 310.0),
            },
        );
        use crate::physics::bc::VarBc;
        assert_eq!(
            s.bc.face(Face::XM).per_var[var::U],
            VarBc::Dirichlet(2.5)
        );
    }

    #[test]
    fn add_and_clear_obstacle() {
        let mut s = sim();
        apply(
            &mut s,
            &SteerCommand::AddObstacle {
                centre: [0.5, 0.5, 0.5],
                radius: 0.1,
                temp: None,
                ignore_axis: None,
            },
        );
        assert!(s.has_solids);
        let solid_cells: usize = s
            .grids
            .iter()
            .map(|g| {
                g.cell_type
                    .iter()
                    .filter(|&&c| CellType::from_u8(c).is_solid())
                    .count()
            })
            .sum();
        assert!(solid_cells > 0);
        apply(&mut s, &SteerCommand::ClearObstacles);
        assert!(!s.has_solids);
    }

    #[test]
    fn obstacle_blocks_flow() {
        let mut s = sim();
        apply(
            &mut s,
            &SteerCommand::AddObstacle {
                centre: [0.5, 0.5, 0.5],
                radius: 0.15,
                temp: None,
                ignore_axis: Some(2),
            },
        );
        for _ in 0..3 {
            s.step(&RustBackend);
        }
        // centre cell velocity stays zero (solid), near-inlet fluid moves
        let centre_grid = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.5, 0.5, 0.5]))
            .unwrap();
        let g = &s.grids[centre_grid];
        use crate::tree::dgrid::pidx;
        // find a solid cell and assert zero velocity
        let mut found = false;
        for i in 0..crate::DGRID_N {
            for j in 0..crate::DGRID_N {
                if g.cell_type(i, j, 8) == CellType::Solid {
                    assert_eq!(g.cur.var(var::U)[pidx(i + 1, j + 1, 9)], 0.0);
                    found = true;
                }
            }
        }
        assert!(found, "no solid cells in centre grid");
    }

    #[test]
    fn refine_region_grows_tree_and_preserves_data() {
        let mut s = sim();
        // paint recognisable temperature into the corner grid
        let corner = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.01, 0.01, 0.01]))
            .unwrap();
        let tdata = vec![333.0f32; crate::DGRID_CELLS];
        s.grids[corner].cur.set_interior(var::T, &tdata);
        let before = s.nbs.tree.len();
        apply(
            &mut s,
            &SteerCommand::Refine {
                region: BBox {
                    min: [0.0; 3],
                    max: [0.4, 0.4, 0.4],
                },
            },
        );
        assert!(s.nbs.tree.len() > before);
        assert_eq!(s.grids.len(), s.nbs.tree.len());
        // a child of the refined corner carries the prolonged 333 K
        let child = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.depth() == 2 && n.bbox.contains_point([0.01; 3]))
            .unwrap();
        let mut buf = vec![0.0f32; crate::DGRID_CELLS];
        s.grids[child].cur.extract_interior(var::T, &mut buf);
        assert_eq!(buf[0], 333.0);
        // simulation still steps
        s.step(&RustBackend);
    }

    #[test]
    fn trs_rollback_branches_and_restores() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("trs_test_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 2);
        let mut s = sim();
        let mut trs = TrsSession::create(&path, &s, 1).unwrap();
        // run + checkpoint at t≈0.002 and t≈0.004
        s.step(&RustBackend);
        trs.checkpoint(&s, &io).unwrap();
        let t1 = s.t;
        s.step(&RustBackend);
        trs.checkpoint(&s, &io).unwrap();
        assert_eq!(trs.timesteps().len(), 2);
        let ke_at_t1 = {
            // reference: what the state looked like at t1
            let snap = iokernel::read_snapshot(&trs.file, t1).unwrap();
            let sim_ref = Simulation::from_snapshot(snap, DomainBc::channel(1.0, 300.0));
            sim_ref.kinetic_energy()
        };
        // rollback to t1 on a branch
        let rolled = trs
            .rollback(t1, &io, DomainBc::channel(1.0, 300.0))
            .unwrap();
        assert!((rolled.t - t1).abs() < 1e-9);
        assert!((rolled.kinetic_energy() - ke_at_t1).abs() < 1e-12);
        assert!(trs.active_path.to_string_lossy().contains("branch1"));
        // the branch file carries exactly the rolled-back snapshot
        // (timestep group names are rounded to 1e-6)
        let ts = trs.timesteps();
        assert_eq!(ts.len(), 1);
        assert!((ts[0] - t1).abs() < 1e-6, "{ts:?} vs {t1}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&trs.active_path).ok();
    }

    #[test]
    fn trs_reader_session_survives_later_checkpoints() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("trs_reader_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 2);
        let mut s = sim();
        let mut trs = TrsSession::create(&path, &s, 1).unwrap();
        s.step(&RustBackend);
        trs.checkpoint(&s, &io).unwrap();
        let t1 = s.t;
        // the front end opens a read session on the first checkpoint…
        let reader = trs.reader(t1).unwrap();
        let before = reader.window(&BBox::unit(), 64).unwrap();
        assert!(!before.is_empty());
        // …and the run keeps stepping and checkpointing underneath it
        s.step(&RustBackend);
        trs.checkpoint(&s, &io).unwrap();
        let after = reader.window(&BBox::unit(), 64).unwrap();
        assert_eq!(before.len(), after.len());
        for (a, b) in before.iter().zip(&after) {
            assert_eq!(a.uid.0, b.uid.0);
            assert_eq!(a.data, b.data, "session view drifted across commits");
        }
        // a fresh session sees the newer checkpoint too
        assert!(trs.reader(s.t).is_ok());
        assert!(trs.file.verify().unwrap().ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn heated_solid_temp_steering() {
        let mut s = sim();
        apply(
            &mut s,
            &SteerCommand::AddObstacle {
                centre: [0.5, 0.5, 0.9],
                radius: 0.08,
                temp: Some(324.66),
                ignore_axis: None,
            },
        );
        apply(&mut s, &SteerCommand::SetHeatedSolidTemp { temp: 374.66 });
        let mut max_t = 0.0f32;
        for g in &s.grids {
            for (i, &c) in g.cell_type.iter().enumerate() {
                if CellType::from_u8(c) == CellType::HeatedSolid {
                    use crate::tree::dgrid::pidx;
                    let (x, y, z) = (
                        i / (crate::DGRID_N * crate::DGRID_N),
                        (i / crate::DGRID_N) % crate::DGRID_N,
                        i % crate::DGRID_N,
                    );
                    max_t = max_t.max(g.cur.var(var::T)[pidx(x + 1, y + 1, z + 1)]);
                }
            }
        }
        assert_eq!(max_t, 374.66);
    }
}
