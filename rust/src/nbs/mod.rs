//! The **neighbourhood server** (paper §2.2) — a topological repository.
//!
//! *"A dedicated process called neighbourhood server stores the entire
//! logical structure, the l-grids, in order to answer topological queries,
//! while all computational processes solely store the d-grids assigned to
//! them."*
//!
//! This module is that repository: it owns a (rank-assigned) [`SpaceTree`]
//! and answers
//!
//! * residence queries — which rank owns a grid,
//! * face-neighbour queries for the ghost-layer update (same level, one
//!   coarser, or one finer thanks to the 2:1 balance),
//! * region queries with a level-of-detail budget — the server-side half of
//!   the sliding window (§2.3): starting from the root, descend until the
//!   finest resolution fits the window's data budget.


use crate::tree::uid::{LocCode, Uid};
use crate::tree::{BBox, SpaceTree};

/// One of the six faces of a d-grid, in `(axis, direction)` form.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Face {
    XM,
    XP,
    YM,
    YP,
    ZM,
    ZP,
}

pub const ALL_FACES: [Face; 6] = [Face::XM, Face::XP, Face::YM, Face::YP, Face::ZM, Face::ZP];

impl Face {
    pub fn axis(self) -> usize {
        match self {
            Face::XM | Face::XP => 0,
            Face::YM | Face::YP => 1,
            Face::ZM | Face::ZP => 2,
        }
    }

    pub fn dir(self) -> i64 {
        match self {
            Face::XM | Face::YM | Face::ZM => -1,
            _ => 1,
        }
    }

    pub fn opposite(self) -> Face {
        match self {
            Face::XM => Face::XP,
            Face::XP => Face::XM,
            Face::YM => Face::YP,
            Face::YP => Face::YM,
            Face::ZM => Face::ZP,
            Face::ZP => Face::ZM,
        }
    }
}

/// A resolved face neighbour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Neighbour {
    /// Physical domain boundary — apply boundary conditions.
    Boundary,
    /// Neighbour at the same tree level.
    Same { idx: u32 },
    /// Neighbour is one level coarser (this grid sits on a refinement edge).
    Coarser { idx: u32 },
    /// Neighbour is refined: the four children touching the shared face.
    Finer { idx: [u32; 4] },
}

/// The neighbourhood server. Wraps the logical tree; all methods are queries
/// (the tree is mutated only through steering operations which rebuild the
/// server's view). `Sync` so the online sliding-window collector can query
/// it from its socket thread while the simulation runs.
#[derive(Debug, Default)]
pub struct NeighbourhoodServer {
    pub tree: SpaceTree,
    /// Messages answered since construction (server-load metric).
    pub queries_served: std::sync::atomic::AtomicU64,
}

impl NeighbourhoodServer {
    pub fn new(tree: SpaceTree) -> NeighbourhoodServer {
        NeighbourhoodServer {
            tree,
            queries_served: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn count(&self) {
        self.queries_served
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Total queries answered (server-load metric).
    pub fn query_count(&self) -> u64 {
        self.queries_served.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Which rank hosts the grid at `loc`?
    pub fn owner_of(&self, loc: LocCode) -> Option<u32> {
        self.count();
        self.tree.lookup(loc).map(|i| self.tree.node(i).rank)
    }

    /// UID of the grid at `loc`.
    pub fn uid_of(&self, loc: LocCode) -> Option<Uid> {
        self.count();
        self.tree.lookup(loc).map(|i| self.tree.node(i).uid())
    }

    /// Resolve the face neighbour of node `idx` for the ghost-layer update.
    ///
    /// With 2:1 balance the answer is exactly one of: domain boundary, a
    /// same-level node (leaf or not — interior nodes carry restricted data),
    /// a one-coarser leaf, or the 4 face-touching children of a same-level
    /// node that is refined.
    pub fn neighbour(&self, idx: u32, face: Face) -> Neighbour {
        self.count();
        let node = self.tree.node(idx);
        let d = node.depth();
        let (i, j, k) = node.loc.coords();
        let mut c = [i as i64, j as i64, k as i64];
        c[face.axis()] += face.dir();
        let side = 1i64 << d;
        if c[face.axis()] < 0 || c[face.axis()] >= side {
            return Neighbour::Boundary;
        }
        let (ni, nj, nk) = (c[0] as u32, c[1] as u32, c[2] as u32);
        if let Some(nb) = self
            .tree
            .lookup(LocCode::from_coords(d, ni, nj, nk).unwrap())
        {
            let nbn = self.tree.node(nb);
            // Same-level exchange whenever the neighbour exists at our level
            // and either side still carries authoritative data there: leaves
            // exchange with leaves, and interior nodes exchange with interior
            // nodes level-by-level (they hold the restricted averages).
            if nbn.is_leaf() || !self.tree.node(idx).is_leaf() {
                return Neighbour::Same { idx: nb };
            }
            // This node is a leaf but the neighbour is refined: the ghost
            // layer comes from the 4 children touching the shared face
            // (their face is the opposite one).
            let mut kids = [0u32; 4];
            let mut n = 0;
            for &ch in &nbn.children {
                if self.child_touches_face(ch, face.opposite()) {
                    kids[n] = ch;
                    n += 1;
                }
            }
            debug_assert_eq!(n, 4);
            return Neighbour::Finer { idx: kids };
        }
        // No same-level node: walk up — with 2:1 balance the parent level
        // must contain it.
        if d == 0 {
            return Neighbour::Boundary;
        }
        if let Some(loc) = LocCode::from_coords(d - 1, ni / 2, nj / 2, nk / 2) {
            if let Some(nb) = self.tree.lookup(loc) {
                return Neighbour::Coarser { idx: nb };
            }
        }
        Neighbour::Boundary
    }

    /// Does child node `ch` touch `face` of its parent?
    fn child_touches_face(&self, ch: u32, face: Face) -> bool {
        let oct = self.tree.node(ch).loc.octant();
        let bit = (oct >> (2 - face.axis())) & 1;
        (face.dir() < 0 && bit == 0) || (face.dir() > 0 && bit == 1)
    }

    /// Sliding-window region query (paper §2.3, §3.2): descend from the root
    /// and return the deepest set of grids that (a) intersect `window` and
    /// (b) number at most `budget` — "the finest possible resolution fitting
    /// into a given limit of bandwidth and visualisation window".
    ///
    /// Returned indices form a non-overlapping cover of the window at a
    /// single resolution per subtree (coarser where descent would burst the
    /// budget).
    pub fn select_window(&self, window: &BBox, budget: usize) -> Vec<u32> {
        self.count();
        let mut current: Vec<u32> = if self.tree.node(0).bbox.intersects(window) {
            vec![0]
        } else {
            Vec::new()
        };
        loop {
            // try to descend one level everywhere possible
            let mut next = Vec::with_capacity(current.len() * 4);
            let mut descended = false;
            for &idx in &current {
                let n = self.tree.node(idx);
                if n.is_leaf() {
                    next.push(idx);
                } else {
                    let kids: Vec<u32> = n
                        .children
                        .iter()
                        .copied()
                        .filter(|&c| self.tree.node(c).bbox.intersects(window))
                        .collect();
                    if kids.is_empty() {
                        next.push(idx);
                    } else {
                        descended = true;
                        next.extend(kids);
                    }
                }
            }
            if !descended || next.len() > budget {
                return current;
            }
            current = next;
        }
    }

    /// All ranks owning grids in `sel` (deduplicated) — step (3) of the
    /// sliding-window query, informing the computational processes.
    pub fn ranks_of(&self, sel: &[u32]) -> Vec<u32> {
        let mut ranks: Vec<u32> = sel.iter().map(|&i| self.tree.node(i).rank).collect();
        ranks.sort_unstable();
        ranks.dedup();
        ranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::sfc;

    fn server(depth: u32, ranks: u32) -> NeighbourhoodServer {
        let mut t = SpaceTree::full(BBox::unit(), depth);
        sfc::partition(&mut t, ranks);
        NeighbourhoodServer::new(t)
    }

    #[test]
    fn boundary_detected() {
        let s = server(1, 1);
        let idx = s.tree.lookup(LocCode::ROOT.child(0)).unwrap();
        assert_eq!(s.neighbour(idx, Face::XM), Neighbour::Boundary);
        assert_eq!(s.neighbour(idx, Face::YM), Neighbour::Boundary);
        assert!(matches!(s.neighbour(idx, Face::XP), Neighbour::Same { .. }));
    }

    #[test]
    fn same_level_neighbour_coords() {
        let s = server(2, 1);
        let a = s
            .tree
            .lookup(LocCode::from_coords(2, 1, 2, 3).unwrap())
            .unwrap();
        match s.neighbour(a, Face::XP) {
            Neighbour::Same { idx } => {
                assert_eq!(s.tree.node(idx).loc.coords(), (2, 2, 3));
            }
            other => panic!("expected Same, got {other:?}"),
        }
    }

    #[test]
    fn root_has_no_neighbours() {
        let s = server(0, 1);
        for f in ALL_FACES {
            assert_eq!(s.neighbour(0, f), Neighbour::Boundary);
        }
    }

    #[test]
    fn finer_neighbour_returns_face_children() {
        // adaptive: one child of root refined, its sibling sees Finer
        let mut t = SpaceTree::root_only(BBox::unit());
        t.refine(0);
        let c0 = t.lookup(LocCode::ROOT.child(0)).unwrap();
        t.refine(c0);
        sfc::partition(&mut t, 1);
        let s = NeighbourhoodServer::new(t);
        let c4 = s.tree.lookup(LocCode::ROOT.child(0b100)).unwrap(); // +x sibling
        match s.neighbour(c4, Face::XM) {
            Neighbour::Finer { idx } => {
                // all four children returned touch the +x face of c0
                for ch in idx {
                    let oct = s.tree.node(ch).loc.octant();
                    assert_eq!((oct >> 2) & 1, 1);
                }
            }
            other => panic!("expected Finer, got {other:?}"),
        }
        // and the refined child sees its coarser sibling ... at same level
        let c0_again = s.tree.lookup(LocCode::ROOT.child(0)).unwrap();
        assert!(matches!(
            s.neighbour(c0_again, Face::XP),
            Neighbour::Same { .. }
        ));
    }

    #[test]
    fn coarser_neighbour_across_refinement_edge() {
        let mut t = SpaceTree::root_only(BBox::unit());
        t.refine(0);
        let c0 = t.lookup(LocCode::ROOT.child(0)).unwrap();
        t.refine(c0);
        sfc::partition(&mut t, 1);
        let s = NeighbourhoodServer::new(t);
        // a depth-2 grid at the +x face of c0 looks right into the coarser c4
        let g = s
            .tree
            .lookup(LocCode::from_coords(2, 1, 0, 0).unwrap())
            .unwrap();
        match s.neighbour(g, Face::XP) {
            Neighbour::Coarser { idx } => {
                assert_eq!(s.tree.node(idx).loc, LocCode::ROOT.child(0b100));
            }
            other => panic!("expected Coarser, got {other:?}"),
        }
    }

    #[test]
    fn owner_queries() {
        let s = server(2, 8);
        let loc = LocCode::from_coords(2, 3, 3, 3).unwrap();
        let idx = s.tree.lookup(loc).unwrap();
        assert_eq!(s.owner_of(loc), Some(s.tree.node(idx).rank));
        assert_eq!(s.uid_of(loc).unwrap().loc(), loc);
        assert!(s.owner_of(LocCode::from_coords(3, 0, 0, 0).unwrap()).is_none());
        assert!(s.query_count() >= 3);
    }

    #[test]
    fn window_full_domain_coarse() {
        let s = server(3, 4);
        // budget 1: only the root fits
        let sel = s.select_window(&BBox::unit(), 1);
        assert_eq!(sel, vec![0]);
        // budget 8: exactly depth 1
        let sel = s.select_window(&BBox::unit(), 8);
        assert_eq!(sel.len(), 8);
        assert!(sel.iter().all(|&i| s.tree.node(i).depth() == 1));
    }

    #[test]
    fn window_zoom_increases_detail() {
        let s = server(3, 4);
        let small = BBox {
            min: [0.0; 3],
            max: [0.3, 0.3, 0.3],
        };
        let sel = s.select_window(&small, 64);
        // a small window with the same budget reaches deeper levels
        assert!(sel.iter().all(|&i| s.tree.node(i).bbox.intersects(&small)));
        let max_d = sel.iter().map(|&i| s.tree.node(i).depth()).max().unwrap();
        assert!(max_d >= 2, "window should zoom to depth ≥ 2, got {max_d}");
    }

    #[test]
    fn window_budget_respected() {
        let s = server(3, 4);
        for budget in [1usize, 7, 8, 9, 63, 64, 65, 512] {
            let sel = s.select_window(&BBox::unit(), budget);
            assert!(sel.len() <= budget.max(1), "budget {budget}: {}", sel.len());
        }
    }

    #[test]
    fn window_outside_domain_empty() {
        let s = server(2, 1);
        let far = BBox {
            min: [2.0; 3],
            max: [3.0; 3],
        };
        assert!(s.select_window(&far, 100).is_empty());
    }

    #[test]
    fn ranks_of_dedupes() {
        let s = server(2, 4);
        let sel = s.select_window(&BBox::unit(), 64);
        let ranks = s.ranks_of(&sel);
        let mut sorted = ranks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(ranks, sorted);
    }
}
