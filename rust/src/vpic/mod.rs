//! **VPIC-IO** — the reference I/O kernel the paper compares against (§5.3).
//!
//! VPIC-IO (from ExaHDF5's Parallel I/O Kernel suite, used in the
//! trillion-particle "Hero I/O" run on Hopper) writes a *particle* dump:
//! eight float32 properties per particle (x, y, z, px, py, pz and two id
//! words), each as one flat 1-D dataset in a shared HDF5 file, every rank
//! writing one contiguous hyperslab per dataset.
//!
//! Compared with the mpfluid kernel its data structure is much lighter —
//! no topology datasets, no hierarchical grids, eight equal flat arrays —
//! which is exactly why the paper uses it as the architecture-independent
//! yardstick: *"scaling the total amount of data for both kernels to be
//! equal"* (§5.3), the same optimisations applied. This module reproduces
//! that setup on the same [`crate::pario`] + [`crate::cluster`] substrate.

use anyhow::Result;

use crate::cluster::{IoTuning, Machine, WriteWorkload};
use crate::h5lite::{Dtype, H5File};
use crate::pario::{IoReport, ParallelIo, SlabWrite};
use crate::util::rng::Rng;

/// The eight per-particle properties of the VPIC dump.
pub const PROPS: [&str; 8] = ["x", "y", "z", "px", "py", "pz", "id1", "id2"];

/// Bytes per particle across all property datasets.
pub const BYTES_PER_PARTICLE: u64 = 8 * 4;

/// Particle count that makes a VPIC dump byte-equal to an mpfluid
/// checkpoint of `total_bytes`.
pub fn particles_for_bytes(total_bytes: u64) -> u64 {
    total_bytes / BYTES_PER_PARTICLE
}

/// Report of one VPIC-IO dump.
#[derive(Clone, Copy, Debug)]
pub struct VpicReport {
    pub io: IoReport,
    pub particles: u64,
}

/// Write a synthetic VPIC particle dump of `particles` particles from
/// `n_ranks` logical ranks into `/Step#0` of `file` (H5Part-style layout).
pub fn write_dump(
    file: &mut H5File,
    io: &ParallelIo,
    particles: u64,
    seed: u64,
) -> Result<VpicReport> {
    let n_ranks = io.n_ranks;
    let per_rank = particles / n_ranks;
    let particles = per_rank * n_ranks; // trim remainder, keeps slabs equal
    let group = "/Step#0";
    let datasets: Vec<_> = PROPS
        .iter()
        .map(|p| file.create_dataset(group, p, Dtype::F32, &[particles]))
        .collect::<Result<_>>()?;

    // synthesise per-rank property buffers (deterministic)
    let mut buffers: Vec<Vec<Vec<u8>>> = Vec::with_capacity(n_ranks as usize);
    for r in 0..n_ranks {
        let mut rng = Rng::new(seed ^ (r * 2654435761));
        let mut per_prop = Vec::with_capacity(PROPS.len());
        for _ in &PROPS {
            let mut v = vec![0.0f32; per_rank as usize];
            rng.fill_f32(&mut v, -1.0, 1.0);
            per_prop.push(crate::h5lite::codec::f32s_to_bytes(&v));
        }
        buffers.push(per_prop);
    }
    let mut writes = Vec::with_capacity((n_ranks as usize) * PROPS.len());
    for (r, per_prop) in buffers.iter().enumerate() {
        for (d, buf) in per_prop.iter().enumerate() {
            writes.push(SlabWrite {
                rank: r as u32,
                ds: &datasets[d],
                row_start: r as u64 * per_rank,
                data: buf,
            });
        }
    }
    let report = io.collective_write(file, &writes, PROPS.len() as u64, particles)?;
    file.commit()?;
    Ok(VpicReport {
        io: report,
        particles,
    })
}

/// Model-only estimate of a VPIC dump on a target machine (for the Fig 8
/// series at scales we cannot materialise): same byte volume as the
/// mpfluid checkpoint, 8 datasets, one row per particle *block* (VPIC
/// slabs are per-rank, so the row count the lock/messaging terms see is
/// `ranks`, not per-cell).
pub fn estimate(machine: &Machine, ranks: u64, total_bytes: u64, tuning: &IoTuning) -> f64 {
    let est = machine.estimate_write(
        &WriteWorkload {
            ranks,
            total_bytes,
            n_datasets: PROPS.len() as u64,
            n_grids: ranks, // one contiguous block per rank per dataset
        },
        tuning,
    );
    est.bandwidth
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("vpic_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn dump_writes_all_property_datasets() {
        let p = tmp("dump");
        let mut f = H5File::create(&p, 1).unwrap();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 4);
        let rep = write_dump(&mut f, &io, 1000, 7).unwrap();
        assert_eq!(rep.particles, 1000);
        assert_eq!(rep.io.bytes, 1000 * BYTES_PER_PARTICLE);
        for prop in PROPS {
            let ds = f.dataset("/Step#0", prop).unwrap();
            assert_eq!(ds.shape, vec![1000]);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn byte_equality_with_mpfluid_checkpoint() {
        let bytes = 337u64 * (1 << 30);
        let particles = particles_for_bytes(bytes);
        assert_eq!(particles * BYTES_PER_PARTICLE, bytes);
    }

    #[test]
    fn vpic_and_mpfluid_comparable_on_juqueen_model() {
        // Fig 8a: "excellent performance for both kernels", similar curves.
        let m = Machine::juqueen();
        let tuning = IoTuning::default();
        for ranks in [2048u64, 8192, 16384] {
            let w = crate::cluster::paper_depth6_workload(ranks);
            let mp = m.estimate_write(&w, &tuning).bandwidth;
            let vp = estimate(&m, ranks, w.total_bytes, &tuning);
            let ratio = mp / vp;
            assert!(
                (0.5..2.0).contains(&ratio),
                "ranks {ranks}: mpfluid {mp:.2e} vs vpic {vp:.2e}"
            );
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let p1 = tmp("det1");
        let p2 = tmp("det2");
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 2);
        let mut f1 = H5File::create(&p1, 1).unwrap();
        let mut f2 = H5File::create(&p2, 1).unwrap();
        write_dump(&mut f1, &io, 64, 42).unwrap();
        write_dump(&mut f2, &io, 64, 42).unwrap();
        let d1 = f1.dataset("/Step#0", "x").unwrap();
        let d2 = f2.dataset("/Step#0", "x").unwrap();
        assert_eq!(
            f1.read_rows(&d1, 0, 64).unwrap(),
            f2.read_rows(&d2, 0, 64).unwrap()
        );
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }
}
