//! `mpfluid` — CLI for the massively parallel CFD code + HDF5-style I/O
//! kernel reproduction.
//!
//! ```text
//! mpfluid run     --scenario channel --depth 1 --steps 100 --out run.h5
//!                 [--config cfg.json] [--backend pjrt|rust] [--collector]
//! mpfluid restart --file run.h5 [--t <time>] --steps 50
//! mpfluid info    --file run.h5
//! mpfluid window  --file run.h5 --t <time> [--min x,y,z --max x,y,z] [--budget N]
//! mpfluid window  --addr 127.0.0.1:PORT  [--min ... --max ...] (online)
//! ```
//!
//! (Hand-rolled argument parsing — no CLI crates in the offline registry.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::coordinator::Simulation;
use mpfluid::h5lite::H5File;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::steering::TrsSession;
use mpfluid::sync::{LockRank, OrderedRwLock};
use mpfluid::tree::BBox;
use mpfluid::util::fmt_gbps;
use mpfluid::{iokernel, window};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "restart" => cmd_restart(&flags),
        "info" => cmd_info(&flags),
        "window" => cmd_window(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (run|restart|info|window)"),
    }
}

fn print_usage() {
    eprintln!(
        "mpfluid — massively parallel CFD with an HDF5-style parallel I/O kernel\n\
         commands:\n\
         \x20 run     --scenario channel|theatre|cavity --depth D --steps N --out FILE\n\
         \x20         [--config FILE.json] [--backend pjrt|rust] [--ranks R] [--collector]\n\
         \x20 restart --file FILE [--t TIME] --steps N [--backend pjrt|rust]\n\
         \x20 info    --file FILE\n\
         \x20 window  --file FILE --t TIME [--min x,y,z --max x,y,z] [--budget N]\n\
         \x20 window  --addr HOST:PORT [--min x,y,z --max x,y,z] [--budget N]"
    );
}

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument '{a}'");
        };
        if key == "collector" {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
        } else {
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
    }
    Ok(flags)
}

fn pick_backend(flags: &HashMap<String, String>) -> Result<Box<dyn ComputeBackend>> {
    match flags.get("backend").map(|s| s.as_str()).unwrap_or("pjrt") {
        "rust" => Ok(Box::new(RustBackend)),
        "pjrt" => match PjrtBackend::load_default() {
            Ok(b) => {
                eprintln!("backend: pjrt ({} artifacts)", b.manifest.entries.len());
                Ok(Box::new(b))
            }
            Err(e) => {
                eprintln!("backend: pjrt unavailable ({e}); falling back to rust oracle");
                Ok(Box::new(RustBackend))
            }
        },
        other => bail!("unknown backend '{other}'"),
    }
}

fn run_loop(
    sim: Arc<OrderedRwLock<Simulation>>,
    backend: &dyn ComputeBackend,
    steps: u64,
    checkpoint_every: u64,
    trs: &mut TrsSession,
    io: &ParallelIo,
) -> Result<()> {
    for s in 0..steps {
        let rep = sim.write().unwrap().step(backend);
        if s % 10 == 0 || s + 1 == steps {
            eprintln!(
                "step {:>5}  t={:.4}  div_rms={:.3e}  solve[{} cycles, r={:.2e}]  {:.0} ms",
                rep.step,
                rep.t,
                rep.div_rms,
                rep.solve.cycles,
                rep.solve.final_residual,
                rep.seconds * 1e3
            );
        }
        if checkpoint_every > 0 && (s + 1) % checkpoint_every == 0 {
            let sim_r = sim.read().unwrap();
            let t0 = std::time::Instant::now();
            trs.checkpoint(&sim_r, io)?;
            let n = sim_r.nbs.tree.len();
            let bytes = (n * mpfluid::tree::dgrid::DGrid::checkpoint_bytes()) as u64;
            let modelled = io
                .machine
                .estimate_write(
                    &mpfluid::cluster::WriteWorkload {
                        ranks: io.n_ranks,
                        total_bytes: bytes,
                        n_datasets: 7,
                        n_grids: n as u64,
                    },
                    &io.tuning,
                )
                .seconds;
            eprintln!(
                "checkpoint @ t={:.4}: {n} grids, {:.1} ms real (modelled on {}: {})",
                sim_r.t,
                t0.elapsed().as_secs_f64() * 1e3,
                io.machine.name,
                fmt_gbps(bytes as f64, modelled)
            );
        }
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let scenario = if let Some(cfg) = flags.get("config") {
        let doc = std::fs::read_to_string(cfg).with_context(|| format!("read {cfg}"))?;
        Scenario::from_json(&doc)?
    } else {
        let name = flags.get("scenario").map(|s| s.as_str()).unwrap_or("cavity");
        let depth: u32 = flags.get("depth").map(|s| s.parse()).transpose()?.unwrap_or(1);
        let mut sc = Scenario::by_name(name, depth)?;
        if let Some(r) = flags.get("ranks") {
            sc.ranks = r.parse()?;
        }
        if let Some(s) = flags.get("steps") {
            sc.steps = s.parse()?;
        }
        sc
    };
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.h5", scenario.name));
    let backend = pick_backend(flags)?;
    let sim = scenario.build();
    eprintln!(
        "scenario '{}': depth {}, {} grids ({} cells), {} ranks",
        scenario.name,
        scenario.depth,
        sim.nbs.tree.len(),
        sim.n_cells(),
        scenario.ranks
    );
    let io = ParallelIo::new(scenario.machine.clone(), scenario.tuning, scenario.ranks as u64);
    let mut trs = TrsSession::create(std::path::Path::new(&out), &sim, scenario.alignment)?;
    let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, sim));
    let _collector = if flags.contains_key("collector") {
        let c = window::Collector::spawn(shared.clone())?;
        eprintln!("collector listening on {}", c.addr);
        Some(c)
    } else {
        None
    };
    run_loop(
        shared.clone(),
        backend.as_ref(),
        scenario.steps,
        scenario.checkpoint_every,
        &mut trs,
        &io,
    )?;
    eprintln!("output file: {} ({} snapshots)", out, trs.timesteps().len());
    Ok(())
}

fn cmd_restart(flags: &HashMap<String, String>) -> Result<()> {
    let path = flags.get("file").ok_or_else(|| anyhow!("--file required"))?;
    let file = H5File::open(path)?;
    let times = iokernel::list_timesteps(&file);
    if times.is_empty() {
        bail!("no snapshots in {path}");
    }
    let t: f64 = match flags.get("t") {
        Some(s) => s.parse()?,
        None => *times.last().unwrap(),
    };
    let steps: u64 = flags.get("steps").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let backend = pick_backend(flags)?;
    let snap = iokernel::read_snapshot(&file, t)?;
    eprintln!(
        "restarting from {path} @ t={t} ({} grids, {} ranks)",
        snap.tree.len(),
        snap.part.n_ranks
    );
    // default all-walls BCs; scenario-specific restarts go through examples
    let bc = mpfluid::physics::bc::DomainBc::all_walls();
    let sim = Simulation::from_snapshot(snap, bc);
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sim.part.n_ranks as u64);
    let branch_path = std::path::Path::new(path).with_extension("restart.h5");
    let mut trs = TrsSession::create(&branch_path, &sim, file.alignment)?;
    let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, sim));
    run_loop(shared, backend.as_ref(), steps, 25, &mut trs, &io)?;
    eprintln!("branch written to {}", branch_path.display());
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let path = flags.get("file").ok_or_else(|| anyhow!("--file required"))?;
    let file = H5File::open(path)?;
    let (params, n_ranks) = iokernel::read_common(&file)?;
    println!("file: {path}");
    println!("alignment: {} B", file.alignment);
    println!("payload: {} B", file.data_bytes());
    println!("ranks: {n_ranks}");
    println!(
        "params: dt={} nu={} alpha={} beta_g={} rho={}",
        params.dt, params.nu, params.alpha, params.beta_g, params.rho
    );
    let times = iokernel::list_timesteps(&file);
    println!("snapshots: {}", times.len());
    for t in times {
        let g = file.group(&iokernel::ts_group(t))?;
        let n = g
            .datasets
            .get("grid_property")
            .map(|d| d.shape[0])
            .unwrap_or(0);
        println!("  t={t:.6}  {n} grids");
    }
    Ok(())
}

fn parse_vec3(s: &str) -> Result<[f64; 3]> {
    let parts: Vec<f64> = s
        .split(',')
        .map(|p| p.trim().parse())
        .collect::<Result<Vec<f64>, _>>()?;
    if parts.len() != 3 {
        bail!("expected x,y,z");
    }
    Ok([parts[0], parts[1], parts[2]])
}

fn cmd_window(flags: &HashMap<String, String>) -> Result<()> {
    let min = flags
        .get("min")
        .map(|s| parse_vec3(s))
        .transpose()?
        .unwrap_or([0.0; 3]);
    let max = flags
        .get("max")
        .map(|s| parse_vec3(s))
        .transpose()?
        .unwrap_or([1.0; 3]);
    let bbox = BBox { min, max };
    let budget: u32 = flags.get("budget").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let grids = if let Some(addr) = flags.get("addr") {
        window::WindowClient::connect(addr.parse()?)?.window(&bbox, budget)?
    } else {
        let path = flags
            .get("file")
            .ok_or_else(|| anyhow!("--file or --addr required"))?;
        let file = H5File::open(path)?;
        let t: f64 = match flags.get("t") {
            Some(s) => s.parse()?,
            None => *iokernel::list_timesteps(&file)
                .last()
                .ok_or_else(|| anyhow!("no snapshots"))?,
        };
        window::SnapshotReader::open(&file, t)?.window(&bbox, budget as usize)?
    };
    println!("{} grids in window (budget {budget})", grids.len());
    for g in &grids {
        // summarise: mean |velocity| and T range per grid
        let n = mpfluid::DGRID_CELLS;
        let (u, v, w) = (&g.data[0..n], &g.data[n..2 * n], &g.data[2 * n..3 * n]);
        let speed: f32 = u
            .iter()
            .zip(v)
            .zip(w)
            .map(|((a, b), c)| (a * a + b * b + c * c).sqrt())
            .sum::<f32>()
            / n as f32;
        let t_slice = &g.data[4 * n..5 * n];
        let (tmin, tmax) = t_slice
            .iter()
            .fold((f32::MAX, f32::MIN), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        println!(
            "  depth {} bbox [{:.3},{:.3},{:.3}]-[{:.3},{:.3},{:.3}]  mean|u|={speed:.4}  T in [{tmin:.1},{tmax:.1}]",
            g.depth, g.bbox.min[0], g.bbox.min[1], g.bbox.min[2],
            g.bbox.max[0], g.bbox.max[1], g.bbox.max[2]
        );
    }
    Ok(())
}
