//! Cluster + parallel-file-system **simulation substrate**.
//!
//! The paper's evaluation ran on JuQueen (BG/Q) and SuperMUC — hardware we
//! do not have. Per the substitution rule (DESIGN.md §3) this module models
//! exactly the topology properties the paper's analysis attributes its
//! results to:
//!
//! * **JuQueen** (§5.1): 16 ranks/node, 1024 nodes/rack, one I/O drawer of
//!   8 I/O nodes per rack (4 available per half-rack partition), 4 GB/s of
//!   raw PCIe throughput per I/O node into the torus but only 2×10 GbE
//!   (≈2 GB/s) from each I/O node to GPFS → 16 GB/s per drawer; very fast
//!   5-D torus intra-rack.
//! * **SuperMUC** (§5.1): 16 ranks/node, islands of 512 nodes, no I/O
//!   forwarding layer (every node talks GPFS directly), 200 GB/s combined
//!   file-system bandwidth, pruned-tree interconnect.
//!
//! [`Machine::estimate_write`] prices a collective checkpoint write with an
//! explicit phase breakdown (dataset wind-up, aggregation fill, lock
//! serialisation, FS streaming). The constants are calibrated so the
//! *shapes* of the paper's Fig 8a/8b and the §5.3 SuperMUC series hold:
//! flat near-peak bandwidth while the I/O resources are constant, a modest
//! (~20 %) gain when the drawer doubles, decline once per-rank messaging
//! overhead dominates, and SuperMUC's monotone decline 21.4 → 14.9 →
//! 4.6 GB/s. Absolute numbers are *modelled*, and every estimate says so in
//! its breakdown — the real byte movement happens in [`crate::pario`]
//! against real files.

use std::fmt;

use crate::h5lite::codec::{Codec, Entropy};

/// Per-aggregator chunk-codec throughput (bytes/s of raw input), one
/// calibration entry per codec v2 pipeline class: the LZ-family pipelines
/// (hash-chain matcher + filters), the LZ + range-coder pipelines (which
/// trade ~2.5× the core time for the extra ratio), and the LZ + tANS
/// pipelines (table-driven shift/add coding, ~2× the range coder's
/// throughput for nearly the same ratio). Three entries is the contract:
/// [`CompressBw::for_codec`] dispatches on [`Codec::entropy`], so adding
/// an entropy backend means adding a calibration entry here.
/// `f64::INFINITY` = not modelled (the local machine measures the real
/// codec instead).
#[derive(Clone, Copy, Debug)]
pub struct CompressBw {
    /// `LZ` / `SHUFFLE_LZ` / `SHUFFLE_DELTA_LZ`.
    pub lz: f64,
    /// `LZ_RC` / `SHUFFLE_LZ_RC` / `SHUFFLE_DELTA_LZ_RC`.
    pub rc: f64,
    /// `LZ_TANS` / `SHUFFLE_LZ_TANS` / `SHUFFLE_DELTA_LZ_TANS`.
    pub tans: f64,
}

impl CompressBw {
    /// The calibration entry pricing `codec`'s pipeline class.
    pub fn for_codec(&self, codec: Codec) -> f64 {
        match codec.entropy() {
            Entropy::None => self.lz,
            Entropy::RangeCoder => self.rc,
            Entropy::Tans => self.tans,
        }
    }

    /// Real-measurement machines model no codec cost.
    pub fn unmodelled() -> CompressBw {
        CompressBw {
            lz: f64::INFINITY,
            rc: f64::INFINITY,
            tans: f64::INFINITY,
        }
    }
}

/// What a checkpoint write looks like to the machine model.
#[derive(Clone, Copy, Debug)]
pub struct WriteWorkload {
    /// Participating MPI ranks.
    pub ranks: u64,
    /// Total payload bytes (all datasets of the snapshot).
    pub total_bytes: u64,
    /// Number of datasets written (each has wind-up/wind-down cost).
    pub n_datasets: u64,
    /// Total grids (dataset rows) in the domain.
    pub n_grids: u64,
}

/// What a fan-out read — many concurrent viewers pulling the same snapshot
/// timestep through one collector node — looks like to the machine model.
#[derive(Clone, Copy, Debug)]
pub struct ReadWorkload {
    /// Concurrent viewer sessions.
    pub clients: u64,
    /// Raw payload bytes served to each client.
    pub bytes_per_client: u64,
    /// Fraction of chunk reads answered by the shared decoded-chunk cache
    /// (`0` = every session decodes privately, the pre-pool behaviour;
    /// `(N−1)/N` = perfectly overlapping traffic under single-flight
    /// coalescing — each chunk decoded exactly once).
    pub shared_hit_rate: f64,
}

/// Cost breakdown of one estimated fan-out read.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReadEstimate {
    /// End-to-end seconds.
    pub seconds: f64,
    /// Raw payload bytes served per second across all clients.
    pub bandwidth: f64,
    /// Chunk-decode time on the server node's cores (cache misses only).
    pub t_decode: f64,
    /// Serving time through the node's interconnect injection link.
    pub t_serve: f64,
    /// Bytes that actually ran the decoder (total − shared-cache hits).
    pub decoded_bytes: u64,
}

/// What in-transit epoch delivery — live subscribers following a writer's
/// committed flush batches (`crate::stream`) — looks like to the machine
/// model, against the file-polling baseline it replaces.
#[derive(Clone, Copy, Debug)]
pub struct StreamWorkload {
    /// Live subscribers following the run.
    pub subscribers: u64,
    /// Payload bytes one committed epoch publishes (the batch's dirty
    /// ranges: stored extents + chunk-index/footer bytes + superblock).
    pub epoch_bytes: u64,
    /// Ranks of the writing job (sizes the FS partition for the baseline).
    pub ranks: u64,
    /// The baseline's poll period: how often a file-following viewer stats
    /// and re-opens the snapshot looking for a new epoch (seconds).
    pub poll_interval: f64,
}

/// Cost breakdown of one estimated epoch delivery, stream vs. file.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamEstimate {
    /// Epoch latency via the stream: commit → applied on every subscriber.
    pub stream_seconds: f64,
    /// Epoch latency via the file: commit → flushed → polled → read back.
    pub file_seconds: f64,
    /// Writer-side tee cost (the commit-return slowdown input).
    pub t_publish: f64,
    /// Fan-out through the writer node's injection link.
    pub t_fanout: f64,
    /// Baseline's flush-to-disk leg (0 on machines with unmodelled flush).
    pub t_flush: f64,
    /// `file_seconds / stream_seconds` — >1 means streaming wins.
    pub speedup: f64,
}

/// Tuning knobs of §5.2 — the ablation axes of `benches/ablations.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IoTuning {
    /// Two-phase collective buffering through aggregator nodes.
    pub collective_buffering: bool,
    /// GPFS byte-range locking on every write (the paper disables this).
    pub file_locking: bool,
    /// Dataset alignment to the FS block size.
    pub alignment: bool,
}

impl Default for IoTuning {
    /// The paper's tuned configuration.
    fn default() -> IoTuning {
        IoTuning {
            collective_buffering: true,
            file_locking: false,
            alignment: true,
        }
    }
}

/// Cost breakdown of one estimated collective write.
#[derive(Clone, Copy, Debug, Default)]
pub struct IoEstimate {
    /// End-to-end seconds.
    pub seconds: f64,
    /// Effective bandwidth in bytes/s of *raw* payload (the paper's
    /// reported metric; with compression this can exceed the physical
    /// streaming peak — the Jin et al. multiplier).
    pub bandwidth: f64,
    /// Streaming time through the narrowest I/O stage (prices the bytes
    /// that physically hit the file system — stored, not raw).
    pub t_stream: f64,
    /// Aggregation-fill time (two-phase I/O, overlapped with streaming).
    pub t_aggregate: f64,
    /// Per-chunk codec time on the aggregator cores (overlapped with the
    /// fill and the stream; 0 when compression is off).
    pub t_compress: f64,
    /// LOD-pyramid fold time on the aggregator cores (overlapped like the
    /// codec; 0 when the write carries no fold sink). Filled in by
    /// [`crate::pario::ParallelIo::collective_write_lod`] from
    /// [`Machine::estimate_fold`], never by the base estimators.
    pub t_fold: f64,
    /// Per-rank messaging overhead (grows with rank count).
    pub t_messages: f64,
    /// Dataset wind-up/wind-down.
    pub t_wind: f64,
    /// Lock-serialisation penalty (0 when locking disabled).
    pub t_lock: f64,
    /// Misalignment penalty (0 when aligned).
    pub t_align: f64,
    /// Bytes that physically hit the file system (== raw bytes unless the
    /// write was compressed).
    pub stored_bytes: u64,
    /// Bytes handed back to the file's free-space manager by chunk
    /// rewrites during this write (h5lite v2.1). Zero for a modelled-only
    /// estimate; filled in from the real measurement by
    /// [`crate::pario::ParallelIo::collective_write`] so steady-state file
    /// size is derivable: growth per write ≈ stored − reclaimed.
    pub reclaimed_bytes: u64,
    /// Background-flusher drain time of the stored bytes on the paged
    /// storage backend; 0 for direct-backend estimates. Only
    /// [`Machine::estimate_write_paged`] fills this in — there the exposed
    /// wall-clock is `max(fill+codec+overheads, flush)` because commit
    /// returns at image speed and the flush overlaps the next step.
    pub t_flush: f64,
}

impl fmt::Display for IoEstimate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:.2} GB/s ({:.1}s: stream {:.1} agg {:.1} comp {:.1} fold {:.1} msg {:.1} wind {:.1} lock {:.1} align {:.1} flush {:.1})",
            self.bandwidth / 1e9,
            self.seconds,
            self.t_stream,
            self.t_aggregate,
            self.t_compress,
            self.t_fold,
            self.t_messages,
            self.t_wind,
            self.t_lock,
            self.t_align,
            self.t_flush
        )
    }
}

/// I/O-subsystem topology of a machine.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub ranks_per_node: u64,
    pub nodes_per_rack: u64,
    /// I/O nodes per rack (0 = no forwarding layer, GPFS direct).
    pub io_nodes_per_rack: u64,
    /// FS-side bandwidth per I/O node (bytes/s).
    pub io_node_fs_bw: f64,
    /// Aggregator ingest bandwidth over the interconnect (bytes/s/agg).
    pub torus_node_bw: f64,
    /// Hard cap of the parallel file system (bytes/s).
    pub fs_total_bw: f64,
    /// FS bandwidth share visible to a single job on direct-GPFS machines.
    pub job_fs_bw: f64,
    /// Per rank-dataset message cost in the collective fill (seconds).
    pub msg_cost: f64,
    /// Cubic-contention scale for direct-GPFS machines (ranks at which
    /// client contention halves throughput; 0 = no such term).
    pub contention_ranks: f64,
    /// Wind-up/wind-down per dataset (seconds).
    pub wind_per_dataset: f64,
    /// Lock acquisition+release cost per write op when locking is on.
    pub lock_cost: f64,
    /// Fractional penalty for unaligned writes.
    pub misalign_penalty: f64,
    /// Throughput divisor per writer sharing one I/O link when collective
    /// buffering is off (independent I/O contention).
    pub indep_contention: f64,
    /// Per-aggregator chunk-codec throughput (bytes/s of raw input) when
    /// per-chunk compression is enabled, calibrated per codec v2 pipeline
    /// class (see [`CompressBw`]).
    pub compress_bw: CompressBw,
    /// Per-aggregator LOD-pyramid fold throughput (bytes/s of source cell
    /// data): a memory-bound 8:1 averaging pass. `f64::INFINITY` = not
    /// modelled (the local machine measures the real fold instead).
    pub fold_bw: f64,
    /// Cap on the paged backend's background-flusher drain (bytes/s): the
    /// flusher streams the dirty image to the file system while the next
    /// step computes, at the *minimum* of the partition's streaming
    /// bandwidth and this cap (a throttled or contended background drain).
    /// `f64::INFINITY` = not modelled (the local machine measures the real
    /// flusher instead).
    pub flush_bw: f64,
}

impl Machine {
    /// JuQueen (Blue Gene/Q at JSC) — paper §5.1.
    pub fn juqueen() -> Machine {
        Machine {
            name: "JuQueen",
            ranks_per_node: 16,
            nodes_per_rack: 1024,
            io_nodes_per_rack: 8,
            io_node_fs_bw: 2.0e9,  // 2×10GbE per I/O node
            torus_node_bw: 2.0e9,  // 5-D torus link
            fs_total_bw: 200e9,    // JUST GPFS scratch aggregate
            job_fs_bw: 200e9,      // unused (forwarding layer in front)
            msg_cost: 0.15e-3,
            contention_ranks: 0.0, // forwarding layer absorbs client count
            wind_per_dataset: 1.0,
            lock_cost: 0.8e-3,
            misalign_penalty: 0.07,
            indep_contention: 0.012,
            // one A2 core: hash-chain LZ pipeline, the binary range coder
            // at ~2.6× the core time per raw byte, and tANS at half the
            // coder's cost (table lookups + shifts, no multiplies/renorm
            // branches — kind to the in-order A2)
            compress_bw: CompressBw {
                lz: 0.9e9,
                rc: 0.35e9,
                tans: 0.7e9,
            },
            fold_bw: 2.0e9, // memory-bound 8:1 averaging on an A2 core
            // the flusher drains through the same I/O-drawer links the
            // synchronous path streams through — no extra throttle
            flush_bw: 200e9,
        }
    }

    /// SuperMUC (LRZ) thin-node islands — paper §5.1.
    pub fn supermuc() -> Machine {
        Machine {
            name: "SuperMUC",
            ranks_per_node: 16,
            nodes_per_rack: 512, // an "island"
            io_nodes_per_rack: 0,
            io_node_fs_bw: 0.0,
            torus_node_bw: 5.0e9, // FDR10 infiniband
            fs_total_bw: 200e9,
            job_fs_bw: 30e9, // single-job share of the combined 200 GB/s
            msg_cost: 0.05e-3,
            contention_ranks: 5000.0, // GPFS client contention knee
            wind_per_dataset: 0.3,
            lock_cost: 0.5e-3,
            misalign_penalty: 0.05,
            indep_contention: 0.004,
            // Sandy Bridge core: LZ pipeline, the range coder at ~2.5×
            // the per-byte cost, tANS at twice the coder's throughput
            compress_bw: CompressBw {
                lz: 2.5e9,
                rc: 1.0e9,
                tans: 2.0e9,
            },
            fold_bw: 6.0e9, // Sandy Bridge core, streaming averages
            flush_bw: 30e9, // drains at the job's GPFS share
        }
    }

    /// A small "local" machine for real end-to-end runs on this host (no
    /// modelled overheads — timings come from actual file I/O instead).
    pub fn local() -> Machine {
        Machine {
            name: "local",
            ranks_per_node: 8,
            nodes_per_rack: 1,
            io_nodes_per_rack: 1,
            io_node_fs_bw: 2.0e9,
            torus_node_bw: 10.0e9,
            fs_total_bw: 2.0e9,
            job_fs_bw: 2.0e9,
            msg_cost: 0.0,
            contention_ranks: 0.0,
            wind_per_dataset: 0.0,
            lock_cost: 0.0,
            misalign_penalty: 0.0,
            indep_contention: 0.0,
            compress_bw: CompressBw::unmodelled(), // real codec timings
            fold_bw: f64::INFINITY,                // real fold timings
            flush_bw: f64::INFINITY,               // real flusher timings
        }
    }

    /// Nodes occupied by `ranks` ranks.
    pub fn nodes_used(&self, ranks: u64) -> u64 {
        ranks.div_ceil(self.ranks_per_node)
    }

    /// I/O nodes reachable from a partition of `ranks` ranks (paper: four
    /// I/O nodes serve a half-rack; a full drawer of eight serves a rack).
    pub fn io_nodes_available(&self, ranks: u64) -> u64 {
        if self.io_nodes_per_rack == 0 {
            return 0;
        }
        let nodes = self.nodes_used(ranks);
        let half_rack = (self.nodes_per_rack / 2).max(1);
        let half_racks = nodes.div_ceil(half_rack);
        (half_racks * self.io_nodes_per_rack / 2).max((self.io_nodes_per_rack / 2).max(1))
    }

    /// Aggregators used for collective buffering: the bridge nodes with
    /// direct links to the I/O drawer (§5.2), 8 per available I/O node, but
    /// never more than one per compute node. Direct-GPFS machines use one
    /// aggregator per node.
    pub fn aggregators(&self, ranks: u64) -> u64 {
        let nodes = self.nodes_used(ranks);
        if self.io_nodes_per_rack == 0 {
            return nodes.max(1);
        }
        (self.io_nodes_available(ranks) * 8).min(nodes).max(1)
    }

    /// Available FS-side streaming bandwidth for this partition.
    pub fn stream_bw(&self, ranks: u64) -> f64 {
        if self.io_nodes_per_rack == 0 {
            // Direct GPFS: a single job sees a flat share of the combined
            // file-system bandwidth, degraded by client contention (cubic
            // knee — GPFS token management cost grows superlinearly with
            // the number of clients hammering one file).
            let mut bw = self.job_fs_bw.min(self.fs_total_bw);
            if self.contention_ranks > 0.0 {
                let x = ranks as f64 / self.contention_ranks;
                bw /= 1.0 + x * x * x;
            }
            bw
        } else {
            (self.io_nodes_available(ranks) as f64 * self.io_node_fs_bw)
                .min(self.fs_total_bw)
        }
    }

    /// Price a collective snapshot write (see module docs). The phases:
    ///
    /// * `t_stream` — payload through the narrowest stage (I/O nodes → FS).
    /// * `t_aggregate` — filling aggregator buffers over the interconnect;
    ///   overlapped with streaming (two-phase I/O pipelines them), so only
    ///   the excess over `t_stream` costs wall-clock.
    /// * `t_messages` — per rank-dataset fixed costs in the fill (this is
    ///   the term the paper blames for the ≥16k-rank degradation).
    /// * `t_wind` — dataset open/close ("wind up and wind down", §5.3).
    /// * `t_lock` — per-write-op lock serialisation when enabled.
    /// * `t_align` — fractional penalty when alignment is off.
    pub fn estimate_write(&self, w: &WriteWorkload, tuning: &IoTuning) -> IoEstimate {
        self.price_write(w, tuning, None)
    }

    /// [`Machine::estimate_write`] for a chunk-compressed write: only
    /// `stored_bytes` hit the file system, but the aggregators also run the
    /// codec over the full raw volume (`t_compress`), priced through the
    /// per-codec calibration entry for `codec`'s pipeline class (the
    /// entropy stage costs ~2.5× the LZ pipeline per raw byte).
    /// Compression is deeply integrated in the fill phase (Jin et al.
    /// 2022), so the fill, codec and stream stages pipeline — the exposed
    /// cost is their maximum, and the *effective* bandwidth (raw bytes /
    /// seconds) rises when the data compresses faster than the narrowest
    /// stage streams.
    pub fn estimate_write_compressed(
        &self,
        w: &WriteWorkload,
        tuning: &IoTuning,
        stored_bytes: u64,
        codec: Codec,
    ) -> IoEstimate {
        self.price_write(w, tuning, Some((stored_bytes, self.compress_bw.for_codec(codec))))
    }

    /// Price a collective write on the **paged** storage backend: writes
    /// land in the in-memory image, so commit returns after the fill (and
    /// codec) phases plus the fixed overheads, while the background flusher
    /// drains `stored_bytes` to the file system at [`Machine::flush_bw`]
    /// overlapped with the next step's fill. The exposed wall-clock per
    /// steady-state step is therefore
    /// `max(fill+codec+overheads, flush) = commit_return + residual drain`,
    /// with the residual charged only when the flusher is slower than the
    /// compute-side pipeline. Pass `stored_bytes == w.total_bytes` for an
    /// uncompressed write.
    pub fn estimate_write_paged(
        &self,
        w: &WriteWorkload,
        tuning: &IoTuning,
        stored_bytes: u64,
        codec: Codec,
    ) -> IoEstimate {
        let mut est = if stored_bytes < w.total_bytes {
            self.estimate_write_compressed(w, tuning, stored_bytes, codec)
        } else {
            self.estimate_write(w, tuning)
        };
        let t_flush = if self.flush_bw.is_infinite() {
            0.0 // real measurement machine: the flusher is timed, not modelled
        } else {
            stored_bytes as f64 / self.stream_bw(w.ranks).min(self.flush_bw)
        };
        // commit-return latency: the image absorbs the stream phase, so
        // only fill/codec (pipelined) plus the fixed overheads remain
        let t_fill = est.t_aggregate.max(est.t_compress);
        let commit_return = t_fill + est.t_messages + est.t_wind + est.t_lock + est.t_align;
        let drain = (t_flush - commit_return).max(0.0);
        est.t_flush = t_flush;
        est.t_stream = 0.0;
        est.seconds = commit_return + drain;
        est.bandwidth = if est.seconds > 0.0 {
            w.total_bytes as f64 / est.seconds
        } else {
            f64::INFINITY
        };
        est
    }

    /// Price the LOD-pyramid fold of `raw_bytes` of source cell data,
    /// spread over the collective write's aggregator threads. The fold
    /// pipelines behind the fill/codec/stream stages, so callers charge
    /// only its excess over the slowest stage (see
    /// [`crate::pario::ParallelIo::collective_write_lod`]).
    pub fn estimate_fold(&self, raw_bytes: u64, ranks: u64) -> f64 {
        raw_bytes as f64 / (self.aggregators(ranks) as f64 * self.fold_bw)
    }

    fn price_write(
        &self,
        w: &WriteWorkload,
        tuning: &IoTuning,
        compressed: Option<(u64, f64)>,
    ) -> IoEstimate {
        let bytes = w.total_bytes as f64;
        let stored_bytes = compressed.map(|(s, _)| s).unwrap_or(w.total_bytes);
        let stored = stored_bytes as f64;
        let mut e = IoEstimate {
            stored_bytes,
            ..IoEstimate::default()
        };

        if tuning.collective_buffering {
            let aggs = self.aggregators(w.ranks) as f64;
            e.t_stream = stored / self.stream_bw(w.ranks);
            e.t_aggregate = bytes / (aggs * self.torus_node_bw);
            if let Some((_, codec_bw)) = compressed {
                e.t_compress = bytes / (aggs * codec_bw);
            }
            e.t_messages = w.ranks as f64 * w.n_datasets as f64 * self.msg_cost;
            e.t_wind = w.n_datasets as f64 * self.wind_per_dataset;
            // GPFS byte-range locking: every row write acquires a lock;
            // aggregators issue them concurrently but the token server
            // serialises conflicts on the shared file.
            if tuning.file_locking {
                e.t_lock =
                    w.n_grids as f64 * w.n_datasets as f64 * self.lock_cost / aggs;
            }
        } else {
            // independent I/O: every rank writes on its own through the
            // scarce I/O links — per-writer contention collapses throughput
            let writers_per_io = if self.io_nodes_per_rack > 0 {
                w.ranks as f64 / self.io_nodes_available(w.ranks) as f64
            } else {
                w.ranks as f64 / self.nodes_used(w.ranks) as f64
            };
            let eff = self.stream_bw(w.ranks)
                / (1.0 + self.indep_contention * writers_per_io * w.ranks as f64 / 64.0);
            e.t_stream = stored / eff.max(1e6);
            if let Some((_, codec_bw)) = compressed {
                // every rank compresses its own slabs before writing
                e.t_compress = bytes / (w.ranks.max(1) as f64 * codec_bw);
            }
            e.t_wind = w.n_datasets as f64 * self.wind_per_dataset;
            e.t_messages = 0.0;
            if tuning.file_locking {
                e.t_lock = w.ranks as f64 * w.n_datasets as f64 * self.lock_cost;
            }
        }
        if !tuning.alignment {
            e.t_align = self.misalign_penalty * e.t_stream;
        }
        // With collective buffering, fill, codec and stream pipeline — only
        // the slowest stage is exposed (t_stream + excess in the
        // uncompressed two-stage case). Independent I/O has no aggregator
        // threads to pipeline behind: each rank compresses its slab and
        // then writes it, so the codec cost is serial.
        let pipeline = if tuning.collective_buffering {
            e.t_stream.max(e.t_aggregate).max(e.t_compress)
        } else {
            e.t_stream + e.t_compress
        };
        e.seconds = pipeline + e.t_messages + e.t_wind + e.t_lock + e.t_align;
        e.bandwidth = bytes / e.seconds;
        e
    }

    /// Price a fan-out snapshot read: `w.clients` concurrent viewers each
    /// pulling `w.bytes_per_client` of raw payload through one collector
    /// node (the paper's "fast (random) access … for visual processing"
    /// scaled to many viewers). The shared decoded-chunk cache turns
    /// overlapping traffic into hits, so only the miss fraction runs the
    /// codec; decode and serve pipeline across the node's cores, so the
    /// exposed cost is their maximum. LZ *decode* runs ~3× the encode
    /// calibration (match copy vs. match search); the range coder is
    /// roughly symmetric, so its entry is used as-is; tANS decode is the
    /// backend's fast direction (a table walk with no divisions), priced
    /// at 2× its encode entry — the asymmetry the adaptive selector's
    /// decode-speed preference banks on.
    pub fn estimate_fanout_read(
        &self,
        w: &ReadWorkload,
        codec: Option<Codec>,
    ) -> ReadEstimate {
        let total = (w.clients * w.bytes_per_client) as f64;
        let hit = w.shared_hit_rate.clamp(0.0, 1.0);
        let decoded = total * (1.0 - hit);
        let decode_bw = match codec.map(|c| c.entropy()) {
            Some(Entropy::RangeCoder) => self.compress_bw.rc,
            Some(Entropy::Tans) => self.compress_bw.tans * 2.0,
            Some(Entropy::None) => self.compress_bw.lz * 3.0,
            None => f64::INFINITY,
        };
        let cores = self.ranks_per_node.max(1) as f64;
        let mut e = ReadEstimate {
            decoded_bytes: decoded as u64,
            ..ReadEstimate::default()
        };
        e.t_decode = decoded / (decode_bw * cores);
        e.t_serve = total / self.torus_node_bw;
        e.seconds = e.t_decode.max(e.t_serve);
        e.bandwidth = if e.seconds > 0.0 {
            total / e.seconds
        } else {
            f64::INFINITY
        };
        e
    }

    /// Price one epoch of in-transit delivery (`crate::stream`) against the
    /// file-polling baseline it replaces.
    ///
    /// Stream path: the writer tees the batch once (a memory copy on the
    /// commit path, charged at fold bandwidth — it is a touch-every-byte
    /// pass like the fold, not an FS transfer) and fans it out to every
    /// subscriber through its node's injection link; the epoch is applied
    /// as soon as the last subscriber drains it.
    ///
    /// File path: the batch first drains to the file system (the narrower
    /// of the partition's FS bandwidth and the flusher's disk bandwidth),
    /// a poller then detects the new epoch after half a poll period on
    /// average, and every viewer reads the epoch back through the same FS
    /// partition. On machines with unmodelled flush (`flush_bw = ∞` and no
    /// modelled FS share) the flush leg is 0 — the poll latency and
    /// read-back still stand, which is exactly why streaming wins even on
    /// a machine with infinitely fast disks.
    pub fn estimate_stream(&self, w: &StreamWorkload) -> StreamEstimate {
        let bytes = w.epoch_bytes as f64;
        let subs = w.subscribers.max(1) as f64;
        let mut e = StreamEstimate::default();
        e.t_publish = bytes / self.fold_bw;
        e.t_fanout = subs * bytes / self.torus_node_bw;
        e.stream_seconds = e.t_publish + e.t_fanout;
        let drain_bw = self.stream_bw(w.ranks).min(self.flush_bw);
        e.t_flush = if drain_bw.is_finite() { bytes / drain_bw } else { 0.0 };
        let read_bw = self.stream_bw(w.ranks);
        let t_read = if read_bw.is_finite() { subs * bytes / read_bw } else { 0.0 };
        e.file_seconds = e.t_flush + 0.5 * w.poll_interval.max(0.0) + t_read;
        e.speedup = if e.stream_seconds > 0.0 {
            e.file_seconds / e.stream_seconds
        } else {
            f64::INFINITY
        };
        e
    }

    /// Price one full ghost-layer exchange (for Fig 2a): cross-rank bytes
    /// through per-node injection bandwidth plus message latency, assuming
    /// traffic spreads evenly (the Lebesgue partition keeps it local).
    pub fn estimate_exchange(&self, ranks: u64, cross_bytes: u64, messages: u64) -> f64 {
        let nodes = self.nodes_used(ranks).max(1) as f64;
        let bw = nodes * self.torus_node_bw;
        // per-message software overhead (MPI stack), serial per rank
        let msg_sw = 50.0e-6;
        let sync = (ranks.max(2) as f64).log2() * 5.0e-6; // barrier tree
        cross_bytes as f64 / bw + (messages as f64 / ranks.max(1) as f64) * msg_sw + sync
    }
}

/// The depth-6 test case of §5.3 (1024³ cells, ~300k grids, 337 GB).
pub fn paper_depth6_workload(ranks: u64) -> WriteWorkload {
    WriteWorkload {
        ranks,
        total_bytes: 337 * (1 << 30),
        n_datasets: 7,
        n_grids: 299_593, // Σ 8^d, d=0..6
    }
}

/// The depth-7 test case of §5.3 (2048³ cells, ~2.4M grids, 2.7 TB).
pub fn paper_depth7_workload(ranks: u64) -> WriteWorkload {
    WriteWorkload {
        ranks,
        total_bytes: 2700 * (1 << 30),
        n_datasets: 7,
        n_grids: 2_396_745, // Σ 8^d, d=0..7
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbps(m: &Machine, w: WriteWorkload) -> f64 {
        m.estimate_write(&w, &IoTuning::default()).bandwidth / 1e9
    }

    #[test]
    fn juqueen_io_nodes_scale_with_partition() {
        let m = Machine::juqueen();
        assert_eq!(m.io_nodes_available(2048), 4); // 128 nodes ≤ half rack
        assert_eq!(m.io_nodes_available(8192), 4); // 512 nodes = half rack
        assert_eq!(m.io_nodes_available(16384), 8); // full rack
        assert_eq!(m.io_nodes_available(32768), 16); // two racks
    }

    #[test]
    fn fig8a_shape_flat_then_bump_then_drop() {
        // Fig 8a: 2048–8192 flat near peak; +~20 % at 16384 despite 2× I/O
        // nodes; worse again at 32768.
        let m = Machine::juqueen();
        let b: Vec<f64> = [2048u64, 4096, 8192, 16384, 32768]
            .iter()
            .map(|&r| gbps(&m, paper_depth6_workload(r)))
            .collect();
        // flat region within 15 %
        assert!((b[0] - b[2]).abs() / b[0] < 0.15, "{b:?}");
        // bump at 16384: between +5 % and +45 % over the flat region
        assert!(b[3] > b[2] * 1.05 && b[3] < b[2] * 1.45, "{b:?}");
        // 32768 loses against 16384
        assert!(b[4] < b[3], "{b:?}");
        // and the flat region sits close to (but below) the 8 GB/s peak
        assert!(b[0] > 4.5 && b[0] < 8.0, "{b:?}");
    }

    #[test]
    fn fig8b_larger_problem_keeps_scaling() {
        // Fig 8b: the 2.7 TB case shows adequate scaling 8192 → 32768.
        let m = Machine::juqueen();
        let b: Vec<f64> = [8192u64, 16384, 32768]
            .iter()
            .map(|&r| gbps(&m, paper_depth7_workload(r)))
            .collect();
        assert!(b[1] > b[0] * 1.5, "{b:?}");
        assert!(b[2] > b[1] * 1.3, "{b:?}");
    }

    #[test]
    fn supermuc_series_monotone_decline() {
        // §5.3: 21.4 GB/s @2048, 14.92 @4096, 4.64 @8192.
        let m = Machine::supermuc();
        let b: Vec<f64> = [2048u64, 4096, 8192]
            .iter()
            .map(|&r| gbps(&m, paper_depth6_workload(r)))
            .collect();
        assert!(b[0] > b[1] && b[1] > b[2], "{b:?}");
        assert!(b[0] > 15.0 && b[0] < 28.0, "{b:?}");
        assert!(b[1] > 10.0 && b[1] < 20.0, "{b:?}");
        assert!(b[2] > 2.5 && b[2] < 9.0, "{b:?}");
    }

    #[test]
    fn supermuc_beats_juqueen_at_low_rank_counts() {
        // §5.3: "The higher bandwidth at a lower node count in comparison to
        // the JuQueen is attributable to the different network topology."
        let j = Machine::juqueen();
        let s = Machine::supermuc();
        let w = paper_depth6_workload(2048);
        assert!(gbps(&s, w) > 2.0 * gbps(&j, w));
    }

    #[test]
    fn disabling_collective_buffering_is_catastrophic() {
        // §5.2: independent I/O over the scarce links ⇒ "minuscule".
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let on = m.estimate_write(&w, &IoTuning::default());
        let off = m.estimate_write(
            &w,
            &IoTuning {
                collective_buffering: false,
                ..IoTuning::default()
            },
        );
        assert!(on.bandwidth > 10.0 * off.bandwidth, "{on} vs {off}");
    }

    #[test]
    fn enabling_file_locking_hurts_a_lot() {
        // §5.2: disabling locking ⇒ "tremendous increase in performance".
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let unlocked = m.estimate_write(&w, &IoTuning::default());
        let locked = m.estimate_write(
            &w,
            &IoTuning {
                file_locking: true,
                ..IoTuning::default()
            },
        );
        assert!(
            unlocked.bandwidth > 1.3 * locked.bandwidth,
            "{unlocked} vs {locked}"
        );
    }

    #[test]
    fn alignment_is_a_small_effect() {
        // §5.2: alignment brings "comparably small improvements".
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let aligned = m.estimate_write(&w, &IoTuning::default());
        let unaligned = m.estimate_write(
            &w,
            &IoTuning {
                alignment: false,
                ..IoTuning::default()
            },
        );
        let ratio = aligned.bandwidth / unaligned.bandwidth;
        assert!(ratio > 1.0 && ratio < 1.15, "ratio {ratio}");
    }

    #[test]
    fn estimate_breakdown_sums() {
        let m = Machine::juqueen();
        let w = paper_depth6_workload(4096);
        let e = m.estimate_write(&w, &IoTuning::default());
        let pipeline = e.t_stream.max(e.t_aggregate).max(e.t_compress);
        let sum = pipeline + e.t_messages + e.t_wind + e.t_lock + e.t_align;
        assert!((e.seconds - sum).abs() < 1e-9);
        assert!(e.bandwidth > 0.0);
        assert_eq!(e.t_compress, 0.0);
        assert_eq!(e.stored_bytes, w.total_bytes);
    }

    #[test]
    fn compression_raises_effective_bandwidth() {
        // a 2.5:1 chunk-compressed checkpoint streams 2.5× fewer bytes
        // through the scarce I/O drawer — effective bandwidth must rise and
        // can exceed the physical peak (the Jin et al. multiplier)
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let raw = m.estimate_write(&w, &IoTuning::default());
        let comp = m.estimate_write_compressed(
            &w,
            &IoTuning::default(),
            w.total_bytes * 2 / 5,
            Codec::SHUFFLE_DELTA_LZ,
        );
        assert!(comp.bandwidth > raw.bandwidth, "{comp} vs {raw}");
        assert_eq!(comp.stored_bytes, w.total_bytes * 2 / 5);
        assert!(comp.t_compress > 0.0);
        assert!(comp.t_stream < raw.t_stream);
    }

    #[test]
    fn independent_io_pays_codec_cost_serially() {
        // without aggregator threads the codec cannot pipeline behind the
        // stream: compressed independent writes must cost at least the
        // codec time on top of streaming
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let t = IoTuning {
            collective_buffering: false,
            ..IoTuning::default()
        };
        let raw = m.estimate_write(&w, &t);
        let comp =
            m.estimate_write_compressed(&w, &t, w.total_bytes * 2 / 5, Codec::SHUFFLE_DELTA_LZ);
        assert!(comp.t_compress > 0.0);
        // serial: seconds includes both the (smaller) stream and the codec
        let expect = comp.t_stream + comp.t_compress + comp.t_wind;
        assert!((comp.seconds - expect).abs() < 1e-9, "{comp}");
        // and compression still wins overall here (stream dominates)
        assert!(comp.seconds < raw.seconds, "{comp} vs {raw}");
    }

    #[test]
    fn fold_estimate_scales_with_the_aggregator_pool() {
        let m = Machine::juqueen();
        let bytes = 337u64 * (1 << 30);
        let half_rack = m.estimate_fold(bytes, 8192);
        let full_rack = m.estimate_fold(bytes, 16384);
        assert!(full_rack < half_rack, "{full_rack} !< {half_rack}");
        assert!(full_rack > 0.0);
        // the local machine measures the real fold instead of modelling it
        assert_eq!(Machine::local().estimate_fold(1 << 30, 8), 0.0);
    }

    #[test]
    fn incompressible_data_pays_codec_overhead_only_when_exposed() {
        // stored == raw: the codec ran for nothing. While it pipelines
        // behind the stream it is free; the estimate must never be *better*
        // than the uncompressed write.
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let raw = m.estimate_write(&w, &IoTuning::default());
        let comp = m.estimate_write_compressed(
            &w,
            &IoTuning::default(),
            w.total_bytes,
            Codec::SHUFFLE_DELTA_LZ,
        );
        assert!(comp.seconds >= raw.seconds - 1e-12, "{comp} vs {raw}");
    }

    #[test]
    fn entropy_codec_priced_slower_per_byte() {
        // per-codec calibration: the entropy pipeline burns more aggregator
        // core time per raw byte, so at equal stored bytes its t_compress
        // must exceed the LZ pipeline's — and the bandwidth only drops when
        // the codec becomes the pipeline bottleneck
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let t = IoTuning::default();
        let stored = w.total_bytes / 2;
        let lz = m.estimate_write_compressed(&w, &t, stored, Codec::SHUFFLE_DELTA_LZ);
        let ent = m.estimate_write_compressed(&w, &t, stored, Codec::SHUFFLE_DELTA_LZ_RC);
        assert!(ent.t_compress > 2.0 * lz.t_compress, "{ent} vs {lz}");
        assert!(ent.seconds >= lz.seconds, "{ent} vs {lz}");
        // tANS sits between: ~2× the coder's throughput, still above LZ cost
        let tans = m.estimate_write_compressed(&w, &t, stored, Codec::SHUFFLE_DELTA_LZ_TANS);
        assert!(tans.t_compress > lz.t_compress, "{tans} vs {lz}");
        assert!(
            (ent.t_compress / tans.t_compress - 2.0).abs() < 0.1,
            "{ent} vs {tans}"
        );
        assert_eq!(m.compress_bw.for_codec(Codec::LZ_RC), m.compress_bw.rc);
        assert_eq!(m.compress_bw.for_codec(Codec::LZ_TANS), m.compress_bw.tans);
        assert_eq!(m.compress_bw.for_codec(Codec::LZ), m.compress_bw.lz);
        // and when the entropy stage buys a better ratio, the effective
        // bandwidth can still come out ahead despite the slower codec
        let lz_ratio = m.estimate_write_compressed(&w, &t, w.total_bytes / 2, Codec::SHUFFLE_DELTA_LZ);
        let ent_ratio = m.estimate_write_compressed(
            &w,
            &t,
            (w.total_bytes as f64 * 0.43) as u64,
            Codec::SHUFFLE_DELTA_LZ_RC,
        );
        assert!(
            ent_ratio.bandwidth > 0.0 && lz_ratio.bandwidth > 0.0,
            "sanity"
        );
    }

    #[test]
    fn paged_backend_overlap_never_loses_to_synchronous() {
        // the paged estimate hides the stream phase behind the next step's
        // fill: steady-state seconds = max(fill+codec+overheads, flush), so
        // it can never exceed the synchronous estimate for the same work
        let m = Machine::juqueen();
        let w = paper_depth6_workload(8192);
        let t = IoTuning::default();
        let sync = m.estimate_write(&w, &t);
        let paged = m.estimate_write_paged(&w, &t, w.total_bytes, Codec::SHUFFLE_DELTA_LZ);
        assert!(paged.seconds <= sync.seconds + 1e-9, "{paged} vs {sync}");
        assert!(paged.bandwidth >= sync.bandwidth - 1e-9, "{paged} vs {sync}");
        assert_eq!(paged.t_stream, 0.0, "the image absorbs the stream phase");
        assert!(paged.t_flush > 0.0);
        // commit_return + residual drain == seconds by construction
        let t_fill = paged.t_aggregate.max(paged.t_compress);
        let commit_return =
            t_fill + paged.t_messages + paged.t_wind + paged.t_lock + paged.t_align;
        let expect = commit_return + (paged.t_flush - commit_return).max(0.0);
        assert!((paged.seconds - expect).abs() < 1e-9, "{paged}");
        // JuQueen's scarce I/O drawer makes this workload flush-bound: the
        // residual drain is what the overlap cannot hide
        assert!(paged.t_flush > commit_return, "{paged}");
        // compression shrinks the flushed volume, so the paged-compressed
        // estimate beats paged-raw on a flush-bound machine
        let comp =
            m.estimate_write_paged(&w, &t, w.total_bytes * 2 / 5, Codec::SHUFFLE_DELTA_LZ);
        assert!(comp.seconds < paged.seconds, "{comp} vs {paged}");
    }

    #[test]
    fn local_machine_models_no_flush_cost() {
        // the local machine measures the real flusher, so the paged
        // estimate is purely fill-bound with zero modelled flush time
        let m = Machine::local();
        let w = WriteWorkload {
            ranks: 8,
            total_bytes: 1 << 30,
            n_datasets: 7,
            n_grids: 100,
        };
        let paged = m.estimate_write_paged(&w, &IoTuning::default(), 1 << 30, Codec::LZ);
        assert_eq!(paged.t_flush, 0.0);
        assert!((paged.seconds - paged.t_aggregate).abs() < 1e-12, "{paged}");
    }

    #[test]
    fn fanout_read_prices_shared_hits() {
        let m = Machine::juqueen();
        let w0 = ReadWorkload {
            clients: 64,
            bytes_per_client: 1 << 28,
            shared_hit_rate: 0.0,
        };
        let cold = m.estimate_fanout_read(&w0, Some(Codec::SHUFFLE_DELTA_LZ));
        let warm = m.estimate_fanout_read(
            &ReadWorkload {
                shared_hit_rate: 63.0 / 64.0,
                ..w0
            },
            Some(Codec::SHUFFLE_DELTA_LZ),
        );
        // perfectly overlapping traffic decodes each chunk once, not 64×
        assert!(
            (cold.t_decode / warm.t_decode - 64.0).abs() < 1e-6,
            "{cold:?} vs {warm:?}"
        );
        assert_eq!(warm.decoded_bytes, 1 << 28);
        assert!(warm.seconds <= cold.seconds);
        assert!(warm.bandwidth >= cold.bandwidth);
        // the entropy pipelines burn more core time per decoded byte than
        // the LZ fast path, and tANS decodes well ahead of the range coder
        let ent = m.estimate_fanout_read(&w0, Some(Codec::SHUFFLE_DELTA_LZ_RC));
        assert!(ent.t_decode > cold.t_decode, "{ent:?} vs {cold:?}");
        let tans = m.estimate_fanout_read(&w0, Some(Codec::SHUFFLE_DELTA_LZ_TANS));
        assert!(
            tans.t_decode * 2.0 <= ent.t_decode,
            "{tans:?} vs {ent:?}"
        );
        assert!(tans.t_decode > cold.t_decode * 0.1, "tans decode still modelled");
        // uncompressed snapshots and the local machine model no decode cost
        assert_eq!(m.estimate_fanout_read(&w0, None).t_decode, 0.0);
        assert_eq!(
            Machine::local()
                .estimate_fanout_read(&w0, Some(Codec::LZ))
                .t_decode,
            0.0
        );
    }

    #[test]
    fn exchange_estimate_scales_down_with_ranks() {
        // Fig 2a: more processes ⇒ more aggregate injection bandwidth ⇒ a
        // full exchange of fixed total volume gets faster.
        let m = Machine::juqueen();
        let t1 = m.estimate_exchange(1024, 1 << 36, 1 << 20);
        let t2 = m.estimate_exchange(16384, 1 << 36, 1 << 20);
        assert!(t2 < t1);
        // and lands in the right magnitude: ~0.1 s for the 4096³ domain on
        // 140k ranks (paper §2.2)
        let t = m.estimate_exchange(140_000, 707_000_000_000 / 64, 20_000_000);
        assert!(t > 0.005 && t < 1.0, "t={t}");
    }

    #[test]
    fn local_machine_has_no_modelled_overheads() {
        let m = Machine::local();
        let w = WriteWorkload {
            ranks: 8,
            total_bytes: 1 << 30,
            n_datasets: 7,
            n_grids: 100,
        };
        let e = m.estimate_write(&w, &IoTuning::default());
        assert_eq!(e.t_wind, 0.0);
        assert_eq!(e.t_messages, 0.0);
    }

    #[test]
    fn stream_delivery_beats_file_polling() {
        // JuQueen, 4k ranks, a 64 MB epoch, a 1 s poller, 4 viewers: the
        // file path pays flush + detection + FS read-back, the stream path
        // only the tee and the fan-out — streaming must win comfortably.
        let m = Machine::juqueen();
        let w = StreamWorkload {
            subscribers: 4,
            epoch_bytes: 64 << 20,
            ranks: 4096,
            poll_interval: 1.0,
        };
        let e = m.estimate_stream(&w);
        assert!(e.t_flush > 0.0, "JuQueen's flush leg is modelled");
        assert!(e.speedup > 1.0, "speedup={}", e.speedup);
        assert!((e.stream_seconds - (e.t_publish + e.t_fanout)).abs() < 1e-12);
    }

    #[test]
    fn stream_fanout_grows_linearly_with_subscribers() {
        let m = Machine::supermuc();
        let base = StreamWorkload {
            subscribers: 1,
            epoch_bytes: 64 << 20,
            ranks: 1024,
            poll_interval: 0.5,
        };
        let e1 = m.estimate_stream(&base);
        let e8 = m.estimate_stream(&StreamWorkload { subscribers: 8, ..base });
        assert!((e8.t_fanout / e1.t_fanout - 8.0).abs() < 1e-9);
        // ...and enough subscribers eventually saturate the injection link
        // past what the file system serves: the break-even is finite
        let big = m.estimate_stream(&StreamWorkload { subscribers: 4096, ..base });
        assert!(big.speedup < e1.speedup);
    }

    #[test]
    fn stream_estimate_guards_unmodelled_flush() {
        // the local measurement machine leaves the flusher to be timed, not
        // modelled — the baseline still pays poll detection latency
        let m = Machine::local();
        let w = StreamWorkload {
            subscribers: 2,
            epoch_bytes: 1 << 20,
            ranks: 8,
            poll_interval: 0.2,
        };
        let e = m.estimate_stream(&w);
        assert!(e.file_seconds >= 0.1, "poll latency survives the guard");
        assert!(e.stream_seconds > 0.0 && e.stream_seconds.is_finite());
    }
}
