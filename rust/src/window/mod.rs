//! The **sliding window** (paper §2.3, §3.1): selective, level-of-detail
//! bounded visualisation access — online against the running simulation,
//! offline against any snapshot in the h5lite file.
//!
//! The key property in both modes: the data volume returned is bounded by
//! the grid *budget*, not by the domain size. Large windows come back at a
//! coarse level of detail (the interior d-grids hold the bottom-up averaged
//! values), small windows descend to the finest grids — "zooming into the
//! data" — so even a trillion-cell domain is explorable over a fixed-rate
//! link.
//!
//! ## The read session: [`SnapshotReader`]
//!
//! The documented hot path for "fast (random) access when retrieving the
//! data for visual processing" is a **session**: open one
//! [`SnapshotReader`] per `(file, timestep)` and issue every query of the
//! exploration through it.
//!
//! * **open** — parses the snapshot's topology (UID→row map, bounding
//!   boxes, child links) and its [`crate::lod`] pyramid index once, pins
//!   the file's current commit **epoch** ([`H5File::pin_epoch`]) and opens
//!   a private descriptor with its own byte-budgeted decoded-chunk cache
//!   ([`SnapshotReaderOptions::cache_bytes`]).
//! * **query*** — [`SnapshotReader::window`] (fixed grid count),
//!   [`SnapshotReader::budgeted`] (byte budget over the pyramid) and
//!   [`SnapshotReader::progressive`] (coarse-to-fine streaming) all serve
//!   from the in-memory indexes; only the selected cell rows touch disk,
//!   and repeats hit the session cache. Per-session counters
//!   ([`crate::metrics::names`]) plus [`SnapshotReader::read_stats`]
//!   expose the amortisation.
//! * **drop** — releases the epoch pin: extents the writer retired while
//!   the session lived return to the free-space manager.
//!
//! The epoch pin is the session's consistency contract: on a
//! [`crate::h5lite::ReusePolicy::AfterCommit`] file (the default), a
//! session keeps reading **byte-identical** data across any number of
//! writer commits — steering rewrites retire the session's extents, but
//! the generation-tagged retire queue parks them until the pin drops.
//! Fresh sessions always see the latest committed state.
//!
//! Sessions are the *only* read surface since the PR-5 redesign: the
//! pre-session free functions (`offline_window` and friends) lived on as
//! deprecated shims for one release and are now gone — a caller that
//! wants one-shot semantics opens a throwaway session, and pays the
//! index-parse cost visibly rather than behind a free function.
//!
//! ## Multi-tenant fan-out: [`ReaderPool`]
//!
//! N viewers of one timestep must not parse the topology and decode the
//! same chunks N times. A [`ReaderPool`] deduplicates both:
//!
//! * sessions opened through [`ReaderPool::open`] share one parsed
//!   topology + `LodIndex` core per `(file, timestep, epoch)` — open is
//!   O(1) after the first ([`crate::metrics::names::READER_SHARED_OPENS`]);
//! * every pooled session reads through one process-wide
//!   [`SharedChunkCache`], keyed `(file, epoch, dataset, chunk)` under a
//!   global byte budget, so a chunk decoded for one viewer serves them
//!   all — and **concurrent** misses on one chunk coalesce onto a single
//!   decode ([`crate::metrics::names::READER_COALESCED`]).
//!
//! The epoch in both keys is what keeps sharing sound: a writer commit
//! moves fresh sessions to a new epoch (new cores, new cache keys), while
//! pinned sessions keep their byte-identical view — the same contract as a
//! private session, now shared.
//!
//! ## Online path (paper Fig 3)
//!
//! 1. the front-end client connects a [`WindowClient`] **session** to the
//!    **collector**'s TCP socket;
//! 2. the collector forwards each query to the neighbourhood server, which
//!    selects the relevant d-grids at the right level of detail;
//! 3. + 4. the owning processes (here: the shared domain state) provide the
//!    selected grid data to the collector;
//! 5. the collector streams the response back to the client — and the
//!    connection stays up for the next query of the zoom sequence.
//!
//! The [`Collector`] runs **one server-side session per connection** over
//! a **bounded worker pool** ([`CollectorOptions::workers`]): accepted
//! connections queue ([`CollectorOptions::backlog`] deep, after which the
//! accept loop exerts backpressure by leaving further connections in the
//! kernel backlog) and each worker runs a connection-long session loop
//! serving any mix of the fixed-count (`SWIN`) and byte-budgeted (`SWLD`)
//! wire protocols. Responses are serialised *after* the simulation read
//! guard is dropped, so a slow client can never block the writer's solver
//! step, and a stalled client hits [`CollectorOptions::write_timeout`]
//! instead of parking a worker forever. [`Collector::spawn_snapshot`]
//! serves a snapshot file instead of live state, with all sessions pooled
//! through one [`ReaderPool`]. One-shot queries are sessions of length
//! one: connect, ask, drop (the deprecated `query`/`query_budgeted` free
//! functions that wrapped exactly that are gone since PR 9).
//!
//! ## Byte-budgeted queries over the LOD pyramid
//!
//! [`SnapshotReader::budgeted`] takes a **byte** budget and serves the
//! region of interest from the finest [`crate::lod`] pyramid level whose
//! cover fits it — a whole-domain query over a huge snapshot comes back as
//! a handful of coarse grids instead of every leaf, and zooming in
//! automatically lands on finer levels. [`SnapshotReader::progressive`]
//! streams the same answer coarse-to-fine for immediate first paint.
//! Pyramid-less files (pre-LOD, or written with
//! `SnapshotOptions { lod: false, .. }`) fall back to the classic
//! traversal transparently. Chunk-compressed snapshots decompress
//! transparently inside [`H5File::read_rows`], each chunk through its own
//! recorded codec.

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Weak};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Simulation;
use crate::h5lite::{
    codec, Dataset, EpochPin, H5File, ReadStats, SharedCacheStats, SharedChunkCache,
    DEFAULT_CHUNK_CACHE_BYTES,
};
use crate::iokernel::{self, ROW_BYTES, ROW_ELEMS};
use crate::lod::{self, LodIndex};
use crate::metrics::{names, Metrics};
use crate::stream::StreamSubscriber;
use crate::sync::{LockRank, OrderedCondvar, OrderedMutex, OrderedRwLock};
use crate::tree::uid::{LocCode, Uid};
use crate::tree::BBox;
use crate::{DGRID_CELLS, NVAR};

/// One grid's worth of visualisation data.
#[derive(Clone, Debug)]
pub struct WindowGrid {
    pub uid: Uid,
    pub depth: u32,
    pub bbox: BBox,
    /// `NVAR · 16³` values: all variables' interiors, variable-major.
    pub data: Vec<f32>,
}

/// Answer of a byte-budgeted window query.
#[derive(Debug)]
pub struct LodWindow {
    pub grids: Vec<WindowGrid>,
    /// Pyramid level served: 0 = full resolution (the tree's leaves),
    /// `max` = the single root grid. Adaptive trees may mix in coarser
    /// ancestors where nothing finer is stored — each grid carries its own
    /// depth/bbox.
    pub level: u32,
    /// Cell-data payload bytes fetched to answer (the budget's currency;
    /// the topology/location indexes add a few KiB on top, paid once per
    /// session).
    pub bytes_read: u64,
    /// True when the answer came from stored pyramid levels; false on the
    /// full-resolution or fallback paths.
    pub from_pyramid: bool,
}

// ---------------------------------------------------------------------------
// the offline read session
// ---------------------------------------------------------------------------

/// Tuning for a [`SnapshotReader`] session.
#[derive(Clone, Copy, Debug)]
pub struct SnapshotReaderOptions {
    /// Byte budget of the session's private decoded-chunk cache
    /// ([`DEFAULT_CHUNK_CACHE_BYTES`] by default). Size it to the working
    /// set of the zoom sequence the session serves; `0` disables caching
    /// (useful in tests that must observe on-disk bytes).
    pub cache_bytes: u64,
}

impl Default for SnapshotReaderOptions {
    fn default() -> SnapshotReaderOptions {
        SnapshotReaderOptions {
            cache_bytes: DEFAULT_CHUNK_CACHE_BYTES,
        }
    }
}

/// The immutable, shareable heart of a read session: a private descriptor
/// on the file, the parsed topology and [`LodIndex`], and the
/// [`EpochPin`] that keeps every referenced byte immutable. Built once per
/// `(file, timestep, epoch)` — privately by [`SnapshotReader::open_with`],
/// shared across sessions by a [`ReaderPool`]. All reads are `&self` and
/// may run concurrently from many threads.
struct ReaderCore {
    /// Core-private handle: parsed from the last *committed* footer at
    /// build, never refreshed — the snapshot-isolation the epoch pin keeps
    /// byte-valid. Pooled cores attach it to the pool's
    /// [`SharedChunkCache`] at the pinned epoch.
    file: H5File,
    pin: EpochPin,
    t: f64,
    /// Domain box from `/common` (absent on files without it; only the
    /// pyramid level selection needs it).
    domain: Option<BBox>,
    /// Packed UID per snapshot row.
    uids: Vec<u64>,
    /// Bounding box per snapshot row.
    bboxes: Vec<BBox>,
    /// Child *rows* per snapshot row (empty = leaf).
    children: Vec<Vec<u64>>,
    ds_cur: Dataset,
    lod: Option<LodIndex>,
}

impl ReaderCore {
    /// Pin-then-parse. The caller supplies the pin (taken on *its* handle
    /// family, where the writer's retired extents park); `shared` routes
    /// the descriptor's chunk reads through a process-wide cache at the
    /// pinned epoch, `None` gives it a private cache of `cache_bytes`.
    /// Returns the core and the index bytes read to build it.
    fn build(
        file: &H5File,
        t: f64,
        pin: EpochPin,
        shared: Option<&Arc<SharedChunkCache>>,
        cache_bytes: u64,
    ) -> Result<(ReaderCore, u64)> {
        let mut rf = H5File::open(&file.path)?;
        match shared {
            Some(cache) => rf.attach_shared_cache(cache, pin.epoch()),
            None => rf.set_chunk_cache_budget(cache_bytes),
        }
        let group = iokernel::ts_group(t);
        let ds_prop = rf.dataset(&group, "grid_property")?;
        let ds_sub = rf.dataset(&group, "subgrid_uid")?;
        let ds_bbox = rf.dataset(&group, "bounding_box")?;
        let ds_cur = rf.dataset(&group, "current_cell_data")?;
        let uids = rf.read_all_u64(&ds_prop)?;
        if uids.is_empty() {
            bail!("window: empty snapshot at t={t}");
        }
        // UID → row index (the offline analogue of the neighbourhood
        // server), resolved once into per-row child links
        let row_of: HashMap<u64, u64> = uids
            .iter()
            .enumerate()
            .map(|(r, &u)| (u, r as u64))
            .collect();
        let bbox_raw = rf.read_all_f64(&ds_bbox)?;
        let bboxes: Vec<BBox> = bbox_raw
            .chunks_exact(6)
            .map(|b| BBox {
                min: [b[0], b[1], b[2]],
                max: [b[3], b[4], b[5]],
            })
            .collect();
        let subs = rf.read_all_u64(&ds_sub)?;
        let children: Vec<Vec<u64>> = subs
            .chunks_exact(8)
            .map(|c| {
                c.iter()
                    .filter(|&&u| u != 0)
                    .filter_map(|u| row_of.get(u).copied())
                    .collect()
            })
            .collect();
        if bboxes.len() != uids.len() || children.len() != uids.len() {
            bail!("window: snapshot topology datasets disagree on row count");
        }
        let domain = iokernel::read_domain(&rf).ok();
        let lod = LodIndex::open(&rf, &group)?;
        // everything read so far is index, paid once per core
        let index_bytes = rf.read_stats().read_bytes;
        Ok((
            ReaderCore {
                file: rf,
                pin,
                t,
                domain,
                uids,
                bboxes,
                children,
                ds_cur,
                lod,
            },
            index_bytes,
        ))
    }
}

/// A long-lived, epoch-pinned read session over one snapshot — the
/// documented hot-path read API (see the [`crate::window`] module docs
/// for the open → query* → drop lifecycle and the consistency contract).
///
/// The session is a handle on a [`ReaderCore`]: privately owned when
/// opened with [`SnapshotReader::open`]/[`SnapshotReader::open_with`],
/// shared with every concurrent session of the same `(file, timestep,
/// epoch)` when opened through a [`ReaderPool`]. All queries are `&self`
/// and may run concurrently from many threads.
pub struct SnapshotReader {
    core: Arc<ReaderCore>,
    /// Per-session counters ([`crate::metrics::names`]): index builds and
    /// bytes (paid once at open; a pooled open served from a live core
    /// counts [`names::READER_SHARED_OPENS`] instead), queries, grids and
    /// payload served.
    pub metrics: Metrics,
}

impl SnapshotReader {
    /// Open a session on the snapshot at time `t` with default options.
    pub fn open(file: &H5File, t: f64) -> Result<SnapshotReader> {
        SnapshotReader::open_with(file, t, &SnapshotReaderOptions::default())
    }

    /// Open a session on the snapshot at time `t`: pin `file`'s current
    /// commit epoch, open a private descriptor on its path (landing on the
    /// last committed state) and parse the topology + LOD indexes once.
    pub fn open_with(
        file: &H5File,
        t: f64,
        opts: &SnapshotReaderOptions,
    ) -> Result<SnapshotReader> {
        // pin before the fresh open: a commit racing the open can only
        // move the opened state *past* the pinned epoch, so the pin is
        // conservative (it may park slightly more, never less)
        let pin = file.pin_epoch();
        let (core, index_bytes) = ReaderCore::build(file, t, pin, None, opts.cache_bytes)?;
        let metrics = Metrics::new();
        metrics.add(names::READER_INDEX_BUILDS, 1);
        metrics.add(names::READER_INDEX_BYTES, index_bytes);
        Ok(SnapshotReader {
            core: Arc::new(core),
            metrics,
        })
    }

    /// Elapsed time of the snapshot this session serves.
    pub fn t(&self) -> f64 {
        self.core.t
    }

    /// Number of grids (rows) in the snapshot.
    pub fn n_grids(&self) -> usize {
        self.core.uids.len()
    }

    /// True when the snapshot stores a LOD pyramid.
    pub fn has_pyramid(&self) -> bool {
        self.core.lod.is_some()
    }

    /// The commit epoch this session pinned at open (diagnostics).
    pub fn pinned_epoch(&self) -> u64 {
        self.core.pin.epoch()
    }

    /// Physical-read accounting of the session's *core* handle: bytes
    /// actually read from disk and the chunk-cache hit/miss/coalesced
    /// split. Pooled sessions share a core, so these counters aggregate
    /// over every session of the same `(file, timestep, epoch)`.
    pub fn read_stats(&self) -> ReadStats {
        self.core.file.read_stats()
    }

    fn note_query(&self, grids: usize) {
        self.metrics.add(names::READER_QUERIES, 1);
        self.metrics.add(names::READER_GRIDS, grids as u64);
        self.metrics
            .add(names::READER_PAYLOAD_BYTES, grids as u64 * ROW_BYTES);
    }

    /// Sliding-window query bounded by a grid-count `budget`: large
    /// windows come back coarse, small windows descend to the leaves.
    pub fn window(&self, window: &BBox, budget: usize) -> Result<Vec<WindowGrid>> {
        let grids = self.core.classic(window, budget)?;
        self.note_query(grids.len());
        Ok(grids)
    }
}

impl ReaderCore {
    fn read_grid(&self, row: u64) -> Result<WindowGrid> {
        let data = codec::bytes_to_f32s(&self.file.read_rows(&self.ds_cur, row, 1)?);
        let uid = Uid(self.uids[row as usize]);
        Ok(WindowGrid {
            uid,
            depth: uid.loc().depth(),
            bbox: self.bboxes[row as usize],
            data,
        })
    }

    /// The classic LOD descent from the root (row 0) over the in-memory
    /// topology index — identical to `NeighbourhoodServer::select_window`
    /// but over snapshot rows; only the selected rows' cell data touches
    /// the file.
    fn classic(&self, window: &BBox, budget: usize) -> Result<Vec<WindowGrid>> {
        let mut current: Vec<u64> = if self.bboxes[0].intersects(window) {
            vec![0]
        } else {
            Vec::new()
        };
        loop {
            let mut next = Vec::with_capacity(current.len() * 4);
            let mut descended = false;
            for &row in &current {
                let kids = &self.children[row as usize];
                if kids.is_empty() {
                    next.push(row);
                } else {
                    let hits: Vec<u64> = kids
                        .iter()
                        .copied()
                        .filter(|&k| self.bboxes[k as usize].intersects(window))
                        .collect();
                    if hits.is_empty() {
                        next.push(row);
                    } else {
                        descended = true;
                        next.extend(hits);
                    }
                }
            }
            if !descended || next.len() > budget {
                break;
            }
            current = next;
        }
        current.into_iter().map(|row| self.read_grid(row)).collect()
    }

    /// The level-selection work behind [`SnapshotReader::budgeted`].
    fn budgeted(&self, window: &BBox, budget_bytes: u64) -> Result<LodWindow> {
        let row_bytes = ROW_BYTES;
        let Some(idx) = &self.lod else {
            let budget_grids = (budget_bytes / row_bytes).max(1) as usize;
            let grids = self.classic(window, budget_grids)?;
            return Ok(LodWindow {
                bytes_read: grids.len() as u64 * row_bytes,
                grids,
                level: 0,
                from_pyramid: false,
            });
        };
        let domain = self.domain.ok_or_else(|| {
            anyhow!("window: snapshot stores a pyramid but /common carries no domain box")
        })?;
        let d_max = idx.max_level();
        // finest level whose whole-cover byte count fits the budget (the
        // count is an O(1) upper bound, so the chosen level never bursts
        // it); the root level is the floor — an answer is always
        // affordable
        let mut chosen = d_max;
        for l in 0..=d_max {
            if lod::intersect_count(&domain, d_max - l, window) * row_bytes <= budget_bytes {
                chosen = l;
                break;
            }
        }
        if chosen == 0 {
            let grids = self.classic(window, usize::MAX)?;
            Ok(LodWindow {
                bytes_read: grids.len() as u64 * row_bytes,
                grids,
                level: 0,
                from_pyramid: false,
            })
        } else {
            self.read_pyramid_level(idx, &domain, chosen, window)
        }
    }

    /// Read the cover of `window` at pyramid level `l ≥ 1`. Coordinates an
    /// adaptive tree never stored resolve to their nearest stored ancestor
    /// (deduplicated), so the cover is complete at mixed depth.
    fn read_pyramid_level(
        &self,
        idx: &LodIndex,
        domain: &BBox,
        l: u32,
        window: &BBox,
    ) -> Result<LodWindow> {
        let row_bytes = ROW_BYTES;
        let d_max = idx.max_level();
        let depth = idx
            .level(l)
            .ok_or_else(|| anyhow!("window: no lod level {l}"))?
            .depth;
        let [ri, rj, rk] = lod::coord_range(domain, depth, window);
        let mut picked: BTreeSet<(u32, u64)> = BTreeSet::new();
        for i in ri.0..ri.1 {
            for j in rj.0..rj.1 {
                for k in rk.0..rk.1 {
                    let (mut lc, mut c) = (l, (i, j, k));
                    loop {
                        let lvl = idx.level(lc).unwrap();
                        let row = LocCode::from_coords(lvl.depth, c.0, c.1, c.2)
                            .and_then(|loc| lvl.row_of(loc));
                        if let Some(row) = row {
                            picked.insert((lc, row));
                            break;
                        }
                        if lc >= d_max {
                            bail!("window: lod pyramid misses an ancestor for ({i},{j},{k})");
                        }
                        lc += 1;
                        c = (c.0 / 2, c.1 / 2, c.2 / 2);
                    }
                }
            }
        }
        let mut grids = Vec::with_capacity(picked.len());
        let mut bytes_read = 0u64;
        for &(lc, row) in &picked {
            let lvl = idx.level(lc).unwrap();
            let data = lvl.read_row(&self.file, row)?;
            bytes_read += row_bytes;
            let loc = lvl.locs[row as usize];
            let (i, j, k) = loc.coords();
            grids.push(WindowGrid {
                uid: Uid::new(0, 0, loc),
                depth: loc.depth(),
                bbox: lod::grid_bbox(domain, loc.depth(), i, j, k),
                data,
            });
        }
        Ok(LodWindow {
            grids,
            level: l,
            bytes_read,
            from_pyramid: true,
        })
    }

    /// The coarse-to-fine cascade behind [`SnapshotReader::progressive`].
    fn progressive(&self, window: &BBox, total_budget_bytes: u64) -> Result<Vec<LodWindow>> {
        let row_bytes = ROW_BYTES;
        let Some(idx) = &self.lod else {
            return Ok(vec![self.budgeted(window, total_budget_bytes)?]);
        };
        let domain = self.domain.ok_or_else(|| {
            anyhow!("window: snapshot stores a pyramid but /common carries no domain box")
        })?;
        let d_max = idx.max_level();
        let mut out: Vec<LodWindow> = Vec::new();
        let mut spent = 0u64;
        for l in (0..=d_max).rev() {
            let cost = lod::intersect_count(&domain, d_max - l, window) * row_bytes;
            if !out.is_empty() && spent + cost > total_budget_bytes {
                break;
            }
            let step = if l == 0 {
                let grids = self.classic(window, usize::MAX)?;
                LodWindow {
                    bytes_read: grids.len() as u64 * row_bytes,
                    grids,
                    level: 0,
                    from_pyramid: false,
                }
            } else {
                self.read_pyramid_level(idx, &domain, l, window)?
            };
            spent += step.bytes_read;
            out.push(step);
        }
        Ok(out)
    }
}

impl SnapshotReader {
    /// Sliding-window query under a **byte budget**: serve `window` from
    /// the finest resolution whose cover fits `budget_bytes`, using the
    /// snapshot's LOD pyramid when it has one. Level 0 (full resolution)
    /// reads the tree's leaf grids; coarser levels read the pyramid
    /// datasets — a whole-domain overview costs one grid row, not the
    /// whole snapshot. The answer always holds at least one grid, even
    /// under a sub-grid budget. A pyramid-less snapshot falls back to the
    /// classic grid-count traversal with the budget converted to grids.
    pub fn budgeted(&self, window: &BBox, budget_bytes: u64) -> Result<LodWindow> {
        let out = self.core.budgeted(window, budget_bytes)?;
        self.note_query(out.grids.len());
        Ok(out)
    }

    /// Progressive refinement: stream `window` coarse-to-fine — the root
    /// level first (immediate first paint), then each finer level while
    /// the *cumulative* bytes stay within `total_budget_bytes`. The last
    /// element is the finest affordable answer; the first is always
    /// emitted so the viewer never starves. Falls back to a single
    /// budgeted answer on pyramid-less snapshots.
    pub fn progressive(
        &self,
        window: &BBox,
        total_budget_bytes: u64,
    ) -> Result<Vec<LodWindow>> {
        let out = self.core.progressive(window, total_budget_bytes)?;
        self.note_query(out.iter().map(|s| s.grids.len()).sum());
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// the multi-tenant reader pool
// ---------------------------------------------------------------------------

/// A multi-tenant session factory: deduplicates the parsed
/// topology/[`LodIndex`] per `(file, timestep, epoch)` and routes every
/// pooled session's chunk reads through one process-wide
/// [`SharedChunkCache`] — N concurrent viewers of one timestep parse once
/// and decode each chunk once (see the module docs).
///
/// Dead cores are pruned on every open: when the last session of a
/// `(file, timestep, epoch)` drops, its core — and the epoch pin holding
/// that epoch's extents — goes with it; only the decoded bytes linger in
/// the cache until evicted.
pub struct ReaderPool {
    cache: Arc<SharedChunkCache>,
    cores: OrderedMutex<HashMap<(u64, u64, u64), Weak<ReaderCore>>>,
    /// Pool-wide counters: index builds/bytes (one per distinct core),
    /// shared opens, and — synced from the cache on [`ReaderPool::metrics`]
    /// — coalesced reads.
    metrics: Metrics,
    /// Cache-coalesce count already folded into `metrics`.
    coalesced_seen: AtomicU64,
}

impl ReaderPool {
    /// A pool whose shared cache holds up to `cache_bytes` decoded bytes
    /// (`0` keeps nothing resident — sessions still share parsed cores and
    /// coalesce concurrent decodes; useful in tests that must observe
    /// on-disk bytes).
    pub fn new(cache_bytes: u64) -> ReaderPool {
        ReaderPool {
            cache: SharedChunkCache::new(cache_bytes),
            cores: OrderedMutex::new(LockRank::ReaderPoolCores, HashMap::new()),
            metrics: Metrics::new(),
            coalesced_seen: AtomicU64::new(0),
        }
    }

    /// Open a session on the snapshot at time `t`, sharing the parsed core
    /// with every live session of the same `(file, timestep, epoch)` —
    /// O(1) after the first. Like [`SnapshotReader::open`], the epoch pin
    /// is taken on `file`'s handle family *before* anything is read, so
    /// the session's consistency contract is unchanged.
    pub fn open(&self, file: &H5File, t: f64) -> Result<SnapshotReader> {
        let pin = file.pin_epoch();
        let key = (self.cache.file_key(&file.path), t.to_bits(), pin.epoch());
        let mut cores = self.cores.lock().unwrap();
        cores.retain(|_, w| w.strong_count() > 0);
        if let Some(core) = cores.get(&key).and_then(Weak::upgrade) {
            // the fresh pin duplicates the live core's — drop it
            drop(pin);
            self.metrics.add(names::READER_SHARED_OPENS, 1);
            let metrics = Metrics::new();
            metrics.add(names::READER_SHARED_OPENS, 1);
            return Ok(SnapshotReader { core, metrics });
        }
        // Build with the map locked: concurrent first-opens of one key
        // coalesce onto a single parse — deliberate; a build is rare,
        // bounded (index datasets only), and the alternative is N
        // identical parses racing to insert.
        let (core, index_bytes) = ReaderCore::build(file, t, pin, Some(&self.cache), 0)?;
        let core = Arc::new(core);
        cores.insert(key, Arc::downgrade(&core));
        self.metrics.add(names::READER_INDEX_BUILDS, 1);
        self.metrics.add(names::READER_INDEX_BYTES, index_bytes);
        let metrics = Metrics::new();
        metrics.add(names::READER_INDEX_BUILDS, 1);
        metrics.add(names::READER_INDEX_BYTES, index_bytes);
        Ok(SnapshotReader { core, metrics })
    }

    /// Counter snapshot of the pool's shared chunk cache.
    pub fn cache_stats(&self) -> SharedCacheStats {
        self.cache.stats()
    }

    /// Distinct `(file, timestep, epoch)` cores currently kept alive by at
    /// least one session.
    pub fn live_cores(&self) -> usize {
        self.cores
            .lock()
            .unwrap()
            .values()
            .filter(|w| w.strong_count() > 0)
            .count()
    }

    /// Pool-wide counters, with [`names::READER_COALESCED`] synced from
    /// the shared cache's single-flight accounting.
    pub fn metrics(&self) -> &Metrics {
        let now = self.cache.stats().coalesced;
        let seen = self.coalesced_seen.swap(now, Ordering::Relaxed);
        if now > seen {
            self.metrics.add(names::READER_COALESCED, now - seen);
        }
        &self.metrics
    }
}

// ---------------------------------------------------------------------------
// online window: collector process + client sessions
// ---------------------------------------------------------------------------

const REQ_MAGIC: u32 = 0x5357_494E; // "SWIN"
/// Budget-aware request: bbox + byte budget, answered at the finest
/// level-of-detail whose cover fits (the online twin of the pyramid —
/// interior d-grids hold the restricted averages the bottom-up step
/// maintains).
const LOD_REQ_MAGIC: u32 = 0x5357_4C44; // "SWLD"
/// Wire length of one grid record: uid, depth, bbox, cell data.
const REC_LEN: usize = 8 + 4 + 48 + ROW_ELEMS * 4;

/// Tuning for a [`Collector`]'s bounded worker-pool connection model.
#[derive(Clone, Copy, Debug)]
pub struct CollectorOptions {
    /// Worker threads serving connection sessions. This bounds the
    /// collector's thread count for its whole lifetime — the old model
    /// spawned one thread per accept and only reaped finished ones when a
    /// *new* connection arrived.
    pub workers: usize,
    /// Accepted-but-unclaimed connections to hold; at the cap the accept
    /// loop pauses, leaving further clients in the kernel's own accept
    /// backlog (connect succeeds, first response waits) — backpressure
    /// instead of unbounded thread growth.
    pub backlog: usize,
    /// Per-write socket timeout: a stalled client that never drains its
    /// response frees its worker after at most this long.
    pub write_timeout: Duration,
    /// Byte budget of the snapshot backend's shared decoded-chunk cache
    /// (ignored by the live backend, which reads no file).
    pub cache_bytes: u64,
}

impl Default for CollectorOptions {
    fn default() -> CollectorOptions {
        CollectorOptions {
            workers: 8,
            backlog: 16,
            write_timeout: Duration::from_secs(5),
            cache_bytes: 4 * DEFAULT_CHUNK_CACHE_BYTES,
        }
    }
}

/// What a [`Collector`] serves its sessions from.
enum Backend {
    /// The running simulation's shared state (the paper's Fig 3 path).
    Live(Arc<OrderedRwLock<Simulation>>),
    /// A snapshot timestep in an h5lite file; every connection session is
    /// opened through one [`ReaderPool`], so all viewers share the parsed
    /// topology and the decoded-chunk cache.
    Snapshot { file: H5File, t: f64, pool: ReaderPool },
    /// A live remote run, followed file-lessly over a
    /// [`crate::stream::StreamSubscriber`]'s mirror.
    Follower(FollowerState),
}

/// The subscriber-backed backend: sessions are served from the stream
/// mirror, re-opened whenever the subscriber has applied new epochs since
/// the last open — a viewer connecting is at most one applied epoch behind
/// the wire.
struct FollowerState {
    sub: StreamSubscriber,
    t: f64,
    pool: ReaderPool,
    /// Mirror handle of the last re-open, tagged with the applied-epoch
    /// count it was opened at.
    cur: OrderedMutex<Option<(u64, H5File)>>,
}

impl FollowerState {
    /// Open a session on the latest applied epoch: refresh the mirror
    /// handle if the stream has applied new epochs, then open through the
    /// pool (keys include the commit epoch, so sessions of one epoch share
    /// a core and a new epoch builds a fresh one). A session holds its
    /// epoch for its whole life; following means opening a new session.
    ///
    /// Caveat, as with any cross-handle-family reader: the apply thread
    /// keeps rewriting the mirror underneath open sessions, and
    /// writer-side extent reuse cannot see subscriber-side epoch pins —
    /// a session outliving the writer's reuse cadence can observe torn
    /// chunk payloads, so follower sessions should stay short-lived
    /// (the serve path opens one per connection).
    fn open_session(&self) -> Result<SnapshotReader> {
        if let Some(why) = self.sub.dead() {
            bail!("collector: stream ended ({why}) — reconnect the follower");
        }
        let applied = self.sub.progress().epochs_applied;
        let mut cur = self.cur.lock().unwrap();
        if !matches!(&*cur, Some((at, _)) if *at >= applied) {
            let f = self.sub.open_file()?;
            *cur = Some((applied, f));
        }
        let (_, f) = cur.as_ref().unwrap();
        self.pool.open(f, self.t)
    }
}

/// Shared state between the accept loop and the worker pool.
struct Dispatcher {
    /// Accepted connections waiting for a worker.
    queue: OrderedMutex<VecDeque<TcpStream>>,
    cv: OrderedCondvar,
    stop: AtomicBool,
    /// Connections currently being served (the live-session gauge the old
    /// un-reaped `Vec<JoinHandle>` could only over-report).
    active: AtomicUsize,
    /// [`names::COLLECTOR_SESSIONS`] / [`names::COLLECTOR_QUERIES`].
    metrics: Metrics,
    write_timeout: Duration,
    backlog: usize,
}

/// Handle to a running collector: a nonblocking accept loop feeding a
/// **bounded worker pool** ([`CollectorOptions`]).
///
/// Each claimed connection is served as a **session loop**: any number of
/// `SWIN` / `SWLD` requests over one socket until the client hangs up —
/// the online counterpart of the offline [`SnapshotReader`] session. Old
/// one-shot clients are simply sessions of length one, so the wire
/// protocols are unchanged.
pub struct Collector {
    pub addr: SocketAddr,
    dispatcher: Arc<Dispatcher>,
    backend: Arc<Backend>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Collector {
    /// Spawn the collector on an ephemeral localhost port, serving
    /// sliding-window query sessions against the shared simulation state.
    pub fn spawn(sim: Arc<OrderedRwLock<Simulation>>) -> Result<Collector> {
        Collector::spawn_with(sim, &CollectorOptions::default())
    }

    /// [`Collector::spawn`] with explicit pool tuning.
    pub fn spawn_with(
        sim: Arc<OrderedRwLock<Simulation>>,
        opts: &CollectorOptions,
    ) -> Result<Collector> {
        Collector::launch(Backend::Live(sim), opts)
    }

    /// Spawn a collector serving the snapshot at time `t` of `file` — the
    /// fan-out read server: every connection session opens through one
    /// [`ReaderPool`] (shared parsed topology, shared decoded-chunk cache
    /// of [`CollectorOptions::cache_bytes`], coalesced decodes). The
    /// collector owns `file`; sessions pin epochs on it, so if a writer
    /// rewrites the snapshot *through another handle family* fresh
    /// sessions see the new commit only after re-spawning — live SWMR
    /// fan-out belongs to the steering session, which pools readers on
    /// the writer's own handle.
    pub fn spawn_snapshot(file: H5File, t: f64, opts: &CollectorOptions) -> Result<Collector> {
        let pool = ReaderPool::new(opts.cache_bytes);
        Collector::launch(Backend::Snapshot { file, t, pool }, opts)
    }

    /// Spawn a collector serving the snapshot at time `t` from a live
    /// stream subscription — the file-less fan-out path: the viewer-facing
    /// wire protocol is exactly [`Collector::spawn_snapshot`]'s, but the
    /// backing bytes arrive over the [`crate::stream::StreamSubscriber`]'s
    /// mirror instead of a shared file system, and each new connection is
    /// served from the latest epoch the subscriber has applied.
    pub fn spawn_follower(
        sub: StreamSubscriber,
        t: f64,
        opts: &CollectorOptions,
    ) -> Result<Collector> {
        let pool = ReaderPool::new(opts.cache_bytes);
        Collector::launch(
            Backend::Follower(FollowerState {
                sub,
                t,
                pool,
                cur: OrderedMutex::new(LockRank::FollowerCurrent, None),
            }),
            opts,
        )
    }

    fn launch(backend: Backend, opts: &CollectorOptions) -> Result<Collector> {
        let listener = TcpListener::bind("127.0.0.1:0").context("collector bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let dispatcher = Arc::new(Dispatcher {
            queue: OrderedMutex::new(LockRank::CollectorDispatch, VecDeque::new()),
            cv: OrderedCondvar::new(),
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            metrics: Metrics::new(),
            write_timeout: opts.write_timeout,
            backlog: opts.backlog.max(1),
        });
        let backend = Arc::new(backend);
        let d = Arc::clone(&dispatcher);
        let accept = std::thread::spawn(move || {
            let mut saturated = false;
            while !d.stop.load(Ordering::Relaxed) {
                if d.queue.lock().unwrap().len() >= d.backlog {
                    // backpressure: stop accepting until a worker drains
                    // the queue; further clients wait in the kernel backlog.
                    // Count and log the transition into saturation — the
                    // worker pool silently bounding persistent sessions was
                    // the PR-6 caveat, and invisible throttling is how it
                    // bites.
                    if !saturated {
                        saturated = true;
                        d.metrics.add(names::COLLECTOR_SESSIONS_REJECTED, 1);
                        eprintln!(
                            "collector: worker pool saturated ({} workers busy, \
                             {} queued) — pausing accepts, new sessions throttled",
                            d.active.load(Ordering::SeqCst),
                            d.backlog,
                        );
                    }
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                saturated = false;
                match listener.accept() {
                    Ok((stream, _)) => {
                        d.queue.lock().unwrap().push_back(stream);
                        d.cv.notify_one();
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        let workers = (0..opts.workers.max(1))
            .map(|_| {
                let d = Arc::clone(&dispatcher);
                let backend = Arc::clone(&backend);
                std::thread::spawn(move || worker_loop(&d, &backend))
            })
            .collect();
        Ok(Collector {
            addr,
            dispatcher,
            backend,
            accept: Some(accept),
            workers,
        })
    }

    /// Connections currently being served by a worker. Returns to 0 as
    /// soon as the last session ends — no accept required (the old model
    /// only reaped finished session threads when a new connection landed).
    pub fn active_sessions(&self) -> usize {
        self.dispatcher.active.load(Ordering::SeqCst)
    }

    /// Accepted connections waiting for a free worker.
    pub fn queued_connections(&self) -> usize {
        self.dispatcher.queue.lock().unwrap().len()
    }

    /// Collector counters: sessions claimed and queries served.
    pub fn metrics(&self) -> &Metrics {
        &self.dispatcher.metrics
    }

    /// The snapshot backend's reader pool (`None` on a live collector) —
    /// the fan-out dedup accounting: shared opens, coalesced decodes,
    /// cache hit/miss/byte counters.
    pub fn reader_pool(&self) -> Option<&ReaderPool> {
        match &*self.backend {
            Backend::Snapshot { pool, .. } => Some(pool),
            Backend::Follower(f) => Some(&f.pool),
            Backend::Live(_) => None,
        }
    }

    /// The follower backend's stream subscription (`None` on other
    /// backends) — lag/progress visibility for whoever spawned us.
    pub fn follower(&self) -> Option<&StreamSubscriber> {
        match &*self.backend {
            Backend::Follower(f) => Some(&f.sub),
            _ => None,
        }
    }
}

impl Drop for Collector {
    /// Bounded shutdown: stop the accept loop, drop queued-but-unserved
    /// connections, wake idle workers and join them. An in-flight read
    /// observes `stop` within its 25 ms poll; an in-flight write is cut
    /// off by the per-write timeout — so a stalled client delays drop by
    /// at most one [`CollectorOptions::write_timeout`], never forever.
    fn drop(&mut self) {
        self.dispatcher.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.dispatcher.queue.lock().unwrap().clear();
        self.dispatcher.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// One worker: claim connections off the dispatcher queue until shutdown.
fn worker_loop(d: &Dispatcher, backend: &Backend) {
    loop {
        let stream = {
            let mut q = d.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if d.stop.load(Ordering::Relaxed) {
                    break None;
                }
                q = d.cv.wait(q).unwrap();
            }
        };
        let Some(stream) = stream else { return };
        d.active.fetch_add(1, Ordering::SeqCst);
        d.metrics.add(names::COLLECTOR_SESSIONS, 1);
        let _ = serve_session(stream, backend, d);
        d.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Read exactly `buf.len()` bytes, riding out the session socket's read
/// timeout so the thread can observe `stop`. With `eof_ok`, a clean EOF
/// before the first byte returns `Ok(false)` (end of session); EOF
/// mid-record is always an error.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok: bool,
) -> Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            bail!("collector: shutting down");
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 && eof_ok => return Ok(false),
            Ok(0) => bail!("collector: connection closed mid-request"),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// One server-side session (steps (2)–(5) of the Fig 3 query path, looped):
/// serve any mix of fixed-count and byte-budgeted requests over one
/// connection until the client hangs up.
///
/// A snapshot backend opens the session's [`SnapshotReader`] once per
/// connection through the collector's pool — O(1) after the first viewer
/// of the timestep.
fn serve_session(mut stream: TcpStream, backend: &Backend, d: &Dispatcher) -> Result<()> {
    stream.set_nodelay(true).ok();
    // short read timeout so an idle session notices a collector shutdown;
    // a write timeout so a stalled client (never draining its response)
    // cannot park this worker in write_all forever — Collector::drop joins
    // every worker, so an unbounded write would hang the host
    stream.set_read_timeout(Some(Duration::from_millis(25)))?;
    stream.set_write_timeout(Some(d.write_timeout))?;
    enum SessionCtx<'a> {
        Live(&'a Arc<OrderedRwLock<Simulation>>),
        Snapshot(SnapshotReader),
    }
    let ctx = match backend {
        Backend::Live(sim) => SessionCtx::Live(sim),
        Backend::Snapshot { file, t, pool } => SessionCtx::Snapshot(pool.open(file, *t)?),
        Backend::Follower(f) => SessionCtx::Snapshot(f.open_session()?),
    };
    let mut magic = [0u8; 4];
    loop {
        if !read_full(&mut stream, &mut magic, &d.stop, true)? {
            return Ok(()); // clean end of session
        }
        let mut bbox_buf = [0u8; 48];
        read_full(&mut stream, &mut bbox_buf, &d.stop, false)?;
        let window = decode_bbox(&bbox_buf);
        d.metrics.add(names::COLLECTOR_QUERIES, 1);
        let out = match u32::from_le_bytes(magic) {
            REQ_MAGIC => {
                let mut b = [0u8; 4];
                read_full(&mut stream, &mut b, &d.stop, false)?;
                let budget = u32::from_le_bytes(b) as usize;
                let grids = match &ctx {
                    SessionCtx::Live(sim) => select_live(sim, &window, budget)?,
                    SessionCtx::Snapshot(r) => r.window(&window, budget)?,
                };
                encode_records(&grids, None)
            }
            LOD_REQ_MAGIC => {
                let mut b = [0u8; 8];
                read_full(&mut stream, &mut b, &d.stop, false)?;
                let budget_bytes = u64::from_le_bytes(b);
                let grids = match &ctx {
                    SessionCtx::Live(sim) => {
                        // byte budget → grid budget: the server-side level
                        // selection picks the finest depth whose cover fits
                        let budget = (budget_bytes / REC_LEN as u64).max(1) as usize;
                        select_live(sim, &window, budget)?
                    }
                    SessionCtx::Snapshot(r) => r.budgeted(&window, budget_bytes)?.grids,
                };
                // the budgeted protocol reports the finest depth served
                let depth = grids.iter().map(|g| g.depth).max().unwrap_or(0);
                encode_records(&grids, Some(depth))
            }
            _ => bail!("collector: bad request magic"),
        };
        if d.stop.load(Ordering::Relaxed) {
            bail!("collector: shutting down");
        }
        stream.write_all(&out)?;
    }
}

fn decode_bbox(buf: &[u8; 48]) -> BBox {
    let f = |i: usize| f64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().unwrap());
    BBox {
        min: [f(0), f(1), f(2)],
        max: [f(3), f(4), f(5)],
    }
}

/// Steps (2)–(4) of the Fig 3 query path: the neighbourhood server selects
/// the grids at the budget's level of detail and the owning processes
/// provide the data — all under the simulation read guard, which is
/// dropped **before** the response is serialised ([`encode_records`]) or
/// written. The old `respond()` held the guard across the full
/// serialisation, so one slow/large response stalled the writer's solver
/// step for its whole duration.
fn select_live(
    sim: &OrderedRwLock<Simulation>,
    window: &BBox,
    budget: usize,
) -> Result<Vec<WindowGrid>> {
    let sim = sim.read().map_err(|_| anyhow!("collector: lock poisoned"))?;
    let sel = sim.nbs.select_window(window, budget);
    let mut grids = Vec::with_capacity(sel.len());
    let mut interior = vec![0.0f32; DGRID_CELLS];
    for idx in sel {
        let node = sim.nbs.tree.node(idx);
        let mut data = Vec::with_capacity(ROW_ELEMS);
        for v in 0..NVAR {
            sim.grids[idx as usize]
                .cur
                .extract_interior(v, &mut interior);
            data.extend_from_slice(&interior);
        }
        grids.push(WindowGrid {
            uid: node.uid(),
            depth: node.depth(),
            bbox: node.bbox,
            data,
        });
    }
    Ok(grids)
}

/// Serialise grid records for the wire — outside any simulation lock.
/// `lod_depth` prefixes the record stream with the finest tree depth
/// served (the budgeted protocol's level report).
fn encode_records(grids: &[WindowGrid], lod_depth: Option<u32>) -> Vec<u8> {
    let mut out: Vec<u8> = Vec::with_capacity(8 + grids.len() * REC_LEN);
    if let Some(depth) = lod_depth {
        out.extend_from_slice(&depth.to_le_bytes());
    }
    out.extend_from_slice(&(grids.len() as u32).to_le_bytes());
    for g in grids {
        out.extend_from_slice(&g.uid.0.to_le_bytes());
        out.extend_from_slice(&g.depth.to_le_bytes());
        for v in g.bbox.min.iter().chain(g.bbox.max.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for x in &g.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

/// Read `n`-prefixed grid records off the wire (client side).
fn read_grid_records(stream: &mut TcpStream) -> Result<Vec<WindowGrid>> {
    let mut n_buf = [0u8; 4];
    stream.read_exact(&mut n_buf)?;
    let n = u32::from_le_bytes(n_buf) as usize;
    let mut grids = Vec::with_capacity(n);
    let mut rec = vec![0u8; REC_LEN];
    for _ in 0..n {
        stream.read_exact(&mut rec)?;
        let uid = Uid(u64::from_le_bytes(rec[0..8].try_into().unwrap()));
        let depth = u32::from_le_bytes(rec[8..12].try_into().unwrap());
        let f = |i: usize| f64::from_le_bytes(rec[12 + i * 8..20 + i * 8].try_into().unwrap());
        let bbox = BBox {
            min: [f(0), f(1), f(2)],
            max: [f(3), f(4), f(5)],
        };
        let data = codec::bytes_to_f32s(&rec[60..]);
        grids.push(WindowGrid {
            uid,
            depth,
            bbox,
            data,
        });
    }
    Ok(grids)
}

/// Answer of a byte-budgeted online query.
#[derive(Debug)]
pub struct OnlineLodWindow {
    pub grids: Vec<WindowGrid>,
    /// Finest tree depth the collector served.
    pub depth: u32,
    /// Payload bytes received (≤ the requested budget, modulo the
    /// one-grid floor).
    pub bytes: u64,
}

/// Client side of one online session: a persistent connection to the
/// [`Collector`] over which any number of fixed-count and byte-budgeted
/// queries can be issued — the wire twin of the offline
/// [`SnapshotReader`]. Dropping the client ends the server-side session.
pub struct WindowClient {
    stream: TcpStream,
}

impl WindowClient {
    /// Connect one session to a running collector.
    pub fn connect(addr: SocketAddr) -> Result<WindowClient> {
        let stream = TcpStream::connect(addr).context("window client connect")?;
        stream.set_nodelay(true).ok();
        Ok(WindowClient { stream })
    }

    /// Fixed-grid-count sliding-window query (`SWIN`).
    pub fn window(&mut self, window: &BBox, budget: u32) -> Result<Vec<WindowGrid>> {
        let mut req = Vec::with_capacity(56);
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        for v in window.min.iter().chain(window.max.iter()) {
            req.extend_from_slice(&v.to_le_bytes());
        }
        req.extend_from_slice(&budget.to_le_bytes());
        self.stream.write_all(&req)?;
        read_grid_records(&mut self.stream)
    }

    /// Byte-budgeted query (`SWLD`): the collector picks the finest level
    /// of detail whose cover fits `budget_bytes` and reports the depth it
    /// served.
    pub fn budgeted(&mut self, window: &BBox, budget_bytes: u64) -> Result<OnlineLodWindow> {
        let mut req = Vec::with_capacity(60);
        req.extend_from_slice(&LOD_REQ_MAGIC.to_le_bytes());
        for v in window.min.iter().chain(window.max.iter()) {
            req.extend_from_slice(&v.to_le_bytes());
        }
        req.extend_from_slice(&budget_bytes.to_le_bytes());
        self.stream.write_all(&req)?;
        let mut d = [0u8; 4];
        self.stream.read_exact(&mut d)?;
        let depth = u32::from_le_bytes(d);
        let grids = read_grid_records(&mut self.stream)?;
        let bytes = (grids.len() * REC_LEN) as u64;
        Ok(OnlineLodWindow {
            grids,
            depth,
            bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::pario::ParallelIo;
    use crate::physics::bc::DomainBc;
    use crate::physics::Params;
    use crate::tree::SpaceTree;
    use crate::var;

    fn sim(depth: u32) -> Simulation {
        let tree = SpaceTree::full(BBox::unit(), depth);
        let mut s = Simulation::new(
            tree,
            3,
            DomainBc::all_walls(),
            Params::isothermal(0.01, 1.0 / 32.0, 0.01),
        );
        // paint P with the arena index so grids are distinguishable
        for (i, g) in s.grids.iter_mut().enumerate() {
            let f = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &f);
        }
        s
    }

    #[test]
    fn session_window_full_domain_coarse() {
        let p = std::env::temp_dir().join(format!("win_off_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.5).unwrap();
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        assert_eq!(reader.n_grids(), 73);
        // budget 1 → root only (coarsest LOD)
        let w = reader.window(&BBox::unit(), 1).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].depth, 0);
        assert_eq!(w[0].data.len(), ROW_ELEMS);
        // budget 8 → depth 1
        let w = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|g| g.depth == 1));
        // large budget → all 64 leaves
        let w = reader.window(&BBox::unit(), 1000).unwrap();
        assert_eq!(w.len(), 64);
        // the session counted its queries and built the index exactly once
        assert_eq!(reader.metrics.counter(names::READER_QUERIES), 3);
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        assert_eq!(reader.metrics.counter(names::READER_GRIDS), 73);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn session_window_zoom_returns_correct_data() {
        let p = std::env::temp_dir().join(format!("win_zoom_{}.h5", std::process::id()));
        let s = sim(1);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0).unwrap();
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let w = reader.window(&corner, 64).unwrap();
        assert_eq!(w.len(), 1, "one leaf covers the corner window");
        // its pressure payload equals the painted arena index
        let idx = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.01; 3]))
            .unwrap();
        let pslice = &w[0].data[var::P * DGRID_CELLS..(var::P + 1) * DGRID_CELLS];
        assert!(pslice.iter().all(|&x| x == idx as f32));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn session_window_identical_on_compressed_and_raw_snapshots() {
        let p = std::env::temp_dir().join(format!("win_comp_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let comp = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            0.0,
            &iokernel::SnapshotOptions::default(),
        )
        .unwrap();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            1.0,
            &iokernel::SnapshotOptions::uncompressed(),
        )
        .unwrap();
        assert!(comp.io.stored_bytes < comp.io.bytes);
        // every zoom level returns identical grids + payloads on both
        let ra = SnapshotReader::open(&f, 0.0).unwrap();
        let rb = SnapshotReader::open(&f, 1.0).unwrap();
        for budget in [1usize, 8, 1000] {
            let a = ra.window(&BBox::unit(), budget).unwrap();
            let b = rb.window(&BBox::unit(), budget).unwrap();
            assert_eq!(a.len(), b.len(), "budget {budget}");
            for (ga, gb) in a.iter().zip(&b) {
                assert_eq!(ga.uid.0, gb.uid.0);
                assert_eq!(ga.data, gb.data);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    /// Cell-data bytes of one grid row.
    const RB: u64 = ROW_BYTES;

    fn snapshot_file(name: &str, s: &Simulation, t: f64) -> H5File {
        let p = std::env::temp_dir().join(format!("win_{name}_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, t).unwrap();
        f
    }

    #[test]
    fn budgeted_window_serves_pyramid_levels() {
        let s = sim(2);
        let f = snapshot_file("lod_levels", &s, 0.5);
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        assert!(reader.has_pyramid());
        // generous budget → full resolution, same grids as the classic path
        let full = reader.budgeted(&BBox::unit(), u64::MAX).unwrap();
        assert_eq!(full.level, 0);
        assert_eq!(full.grids.len(), 64);
        assert_eq!(full.bytes_read, 64 * RB);
        // an 8-grid budget → pyramid level 1 (the 8 depth-1 folds)
        let mid = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert_eq!(mid.level, 1);
        assert!(mid.from_pyramid);
        assert_eq!(mid.grids.len(), 8);
        assert!(mid.grids.iter().all(|g| g.depth == 1));
        assert_eq!(mid.bytes_read, 8 * RB);
        // the served values are exact folds of the painted leaves: octant 0
        // of a level-1 grid holds its first child's (constant) pressure
        let g1 = &mid.grids[0];
        let child = s.nbs.tree.lookup(g1.uid.loc().child(0)).unwrap();
        assert_eq!(g1.data[var::P * DGRID_CELLS], child as f32);
        // a one-grid budget → the root overview, 1/64 of the full bytes
        let root = reader.budgeted(&BBox::unit(), RB).unwrap();
        assert_eq!(root.level, 2);
        assert_eq!(root.grids.len(), 1);
        assert_eq!(root.grids[0].depth, 0);
        assert_eq!(root.bytes_read, RB);
        // one session, three queries, one index build
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        assert_eq!(reader.metrics.counter(names::READER_QUERIES), 3);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn budgeted_zoom_descends_levels_at_fixed_budget() {
        let s = sim(2);
        let f = snapshot_file("lod_zoom", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let budget = 4 * RB;
        let whole = reader.budgeted(&BBox::unit(), budget).unwrap();
        let octant = reader
            .budgeted(
                &BBox {
                    min: [0.0; 3],
                    max: [0.5; 3],
                },
                budget,
            )
            .unwrap();
        let corner = reader
            .budgeted(
                &BBox {
                    min: [0.0; 3],
                    max: [0.25; 3],
                },
                budget,
            )
            .unwrap();
        // shrinking the window at a fixed byte budget lands on finer levels
        assert_eq!(whole.level, 2);
        assert_eq!(octant.level, 1);
        assert_eq!(corner.level, 0);
        for w in [&whole, &octant, &corner] {
            assert!(w.bytes_read <= budget, "{} > {budget}", w.bytes_read);
            assert!(!w.grids.is_empty());
        }
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn progressive_refinement_streams_coarse_to_fine() {
        let s = sim(2);
        let f = snapshot_file("lod_prog", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        // budget for the whole cascade: 1 + 8 + 64 grids
        let steps = reader.progressive(&BBox::unit(), 73 * RB).unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(
            steps.iter().map(|s| s.level).collect::<Vec<_>>(),
            vec![2, 1, 0]
        );
        assert_eq!(steps[0].grids.len(), 1);
        assert_eq!(steps[2].grids.len(), 64);
        let total: u64 = steps.iter().map(|s| s.bytes_read).sum();
        assert!(total <= 73 * RB);
        // a sub-grid budget still paints the coarsest answer
        let tiny = reader.progressive(&BBox::unit(), 1).unwrap();
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny[0].level, 2);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn pyramid_less_snapshot_falls_back_unchanged() {
        let s = sim(2);
        let p = std::env::temp_dir().join(format!("win_nolod_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let opts = iokernel::SnapshotOptions {
            lod: false,
            ..iokernel::SnapshotOptions::default()
        };
        iokernel::write_snapshot_with(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0, &opts)
            .unwrap();
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        assert!(!reader.has_pyramid());
        // the classic API answers exactly as before the pyramid existed
        let classic = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(classic.len(), 8);
        // and the budgeted API degrades to the grid-count traversal
        let w = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert!(!w.from_pyramid);
        assert_eq!(w.level, 0);
        assert_eq!(w.grids.len(), 8);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn repeated_session_queries_serve_from_the_chunk_cache() {
        // the ROADMAP hot-path item this API closes: repeats through one
        // session rebuild no index and re-read no bytes — everything is
        // already resident
        let s = sim(2);
        let f = snapshot_file("lod_amort", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let roi = BBox {
            min: [0.0; 3],
            max: [0.5; 3],
        };
        reader.budgeted(&roi, 8 * RB).unwrap();
        let after_first = reader.read_stats().read_bytes;
        for _ in 0..3 {
            reader.budgeted(&roi, 8 * RB).unwrap();
        }
        let rs = reader.read_stats();
        assert_eq!(
            rs.read_bytes, after_first,
            "repeat queries re-read bytes: {rs:?}"
        );
        assert!(rs.cache_hits > 0, "{rs:?}");
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn throwaway_sessions_answer_like_long_lived_ones() {
        // the one-shot pattern that replaced the removed PR-5 shims: a
        // fresh session per call answers byte-for-byte like a long-lived
        // session over the same committed state
        let s = sim(2);
        let f = snapshot_file("shims", &s, 0.5);
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        let a = SnapshotReader::open(&f, 0.5)
            .unwrap()
            .window(&BBox::unit(), 8)
            .unwrap();
        let b = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(a.len(), b.len());
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.uid.0, gb.uid.0);
            assert_eq!(ga.data, gb.data);
        }
        let wa = SnapshotReader::open(&f, 0.5)
            .unwrap()
            .budgeted(&BBox::unit(), 8 * RB)
            .unwrap();
        let wb = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert_eq!(wa.level, wb.level);
        assert_eq!(wa.grids.len(), wb.grids.len());
        let pa = SnapshotReader::open(&f, 0.5)
            .unwrap()
            .progressive(&BBox::unit(), 73 * RB)
            .unwrap();
        let pb = reader.progressive(&BBox::unit(), 73 * RB).unwrap();
        assert_eq!(pa.len(), pb.len());
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn online_session_serves_mixed_protocols_on_one_connection() {
        let s = sim(2);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let rec = REC_LEN as u64;
        // one connection, a whole zoom sequence across both protocols
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let coarse = client.budgeted(&BBox::unit(), rec).unwrap();
        assert_eq!(coarse.grids.len(), 1);
        assert_eq!(coarse.depth, 0);
        assert!(coarse.bytes <= rec);
        let mid = client.budgeted(&BBox::unit(), 8 * rec).unwrap();
        assert_eq!(mid.grids.len(), 8);
        assert_eq!(mid.depth, 1);
        assert!(mid.bytes <= 8 * rec);
        // zooming at the same budget reaches the leaves
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let zoom = client.budgeted(&corner, 8 * rec).unwrap();
        assert_eq!(zoom.depth, 2);
        // the fixed-count protocol works on the same socket
        let legacy = client.window(&BBox::unit(), 8).unwrap();
        assert_eq!(legacy.len(), 8);
    }

    #[test]
    fn online_collector_roundtrip() {
        let s = sim(2);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        // full-domain query at budget 8 → the 8 depth-1 grids
        let grids = client.window(&BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
        assert!(grids.iter().all(|g| g.depth == 1));
        assert!(grids.iter().all(|g| g.data.len() == ROW_ELEMS));
        // zoomed query descends deeper
        let corner = BBox {
            min: [0.0; 3],
            max: [0.1; 3],
        };
        let zoom = client.window(&corner, 8).unwrap();
        assert!(zoom.iter().any(|g| g.depth == 2), "{zoom:?} depths");
    }

    #[test]
    fn one_shot_client_sessions_answer() {
        // one-shot clients are sessions of length one — connect, ask,
        // drop; the wire protocol serves them like any other session
        let s = sim(2);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let grids = WindowClient::connect(collector.addr)
            .unwrap()
            .window(&BBox::unit(), 8)
            .unwrap();
        assert_eq!(grids.len(), 8);
        let lod = WindowClient::connect(collector.addr)
            .unwrap()
            .budgeted(&BBox::unit(), REC_LEN as u64)
            .unwrap();
        assert_eq!(lod.grids.len(), 1);
        assert_eq!(lod.depth, 0);
    }

    #[test]
    fn online_window_sees_live_updates() {
        let s = sim(1);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let before = client.window(&BBox::unit(), 1).unwrap();
        // mutate the root grid's pressure
        {
            let mut sim = shared.write().unwrap();
            let f = vec![777.0f32; DGRID_CELLS];
            sim.grids[0].cur.set_interior(var::P, &f);
        }
        // the same session serves the new state
        let after = client.window(&BBox::unit(), 1).unwrap();
        let pr = |w: &[WindowGrid]| w[0].data[var::P * DGRID_CELLS];
        assert_ne!(pr(&before), pr(&after));
        assert_eq!(pr(&after), 777.0);
    }

    #[test]
    fn collector_reaps_sessions_without_a_further_accept() {
        // the thread-leak bug: session state was only reaped inside the
        // accept arm, so an idle collector held every finished session
        // forever. Under the worker pool, the live-session gauge must
        // return to 0 with no further connection arriving.
        let s = sim(1);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared).unwrap();
        for _ in 0..6 {
            let mut client = WindowClient::connect(collector.addr).unwrap();
            assert_eq!(client.window(&BBox::unit(), 1).unwrap().len(), 1);
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while collector.active_sessions() > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(collector.active_sessions(), 0, "sessions not reaped");
        assert_eq!(collector.queued_connections(), 0);
        assert_eq!(collector.metrics().counter(names::COLLECTOR_SESSIONS), 6);
        assert_eq!(collector.metrics().counter(names::COLLECTOR_QUERIES), 6);
    }

    #[test]
    fn stalled_client_hits_write_timeout_and_frees_its_worker() {
        // a client that never drains its response must hit the write
        // timeout and lose its session — it must not park a worker forever
        // or delay Collector::drop
        let s = sim(3); // 512 leaves → a ~42 MB budget-1000 response
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let opts = CollectorOptions {
            workers: 2,
            write_timeout: Duration::from_millis(250),
            ..CollectorOptions::default()
        };
        let collector = Collector::spawn_with(shared, &opts).unwrap();
        // raw socket: send a full-domain request, then never read a byte
        let mut stalled = TcpStream::connect(collector.addr).unwrap();
        let mut req = Vec::with_capacity(56);
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        for v in BBox::unit().min.iter().chain(BBox::unit().max.iter()) {
            req.extend_from_slice(&v.to_le_bytes());
        }
        req.extend_from_slice(&1000u32.to_le_bytes());
        stalled.write_all(&req).unwrap();
        // a well-behaved client is still served while the other worker
        // is wedged against the stalled socket
        let mut ok = WindowClient::connect(collector.addr).unwrap();
        assert_eq!(ok.window(&BBox::unit(), 1).unwrap().len(), 1);
        drop(ok);
        // both sessions end: the polite one on EOF, the stalled one cut
        // off by the write timeout — while its socket stays open
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while collector.active_sessions() > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(collector.active_sessions(), 0, "stalled session never closed");
        // shutdown is bounded by one write timeout, not a wedged join
        let t0 = std::time::Instant::now();
        drop(collector);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "drop took {:?}",
            t0.elapsed()
        );
        drop(stalled);
    }

    #[test]
    fn collector_drop_under_live_sessions_is_bounded() {
        // Shutdown-ordering regression watchdog: dropping a collector
        // while idle sessions are parked in read_full's 25 ms poll must
        // stop the accept loop, wake every worker off the dispatch
        // condvar, and join them all — bounded, never a deadlock.
        let s = sim(1);
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared).unwrap();
        // three live sessions mid-connection (served, then idle in poll)
        let mut clients: Vec<WindowClient> = (0..3)
            .map(|_| WindowClient::connect(collector.addr).unwrap())
            .collect();
        for c in &mut clients {
            assert_eq!(c.window(&BBox::unit(), 1).unwrap().len(), 1);
        }
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            drop(collector);
            tx.send(()).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(10))
            .expect("Collector::drop wedged with live idle sessions");
        drop(clients);
    }

    #[test]
    fn snapshot_collector_serves_pooled_sessions() {
        // the fan-out server: N connections to one snapshot share one
        // parsed core and one decoded-chunk cache, and answer exactly like
        // a private offline session
        let s = sim(2);
        let f = snapshot_file("fanout", &s, 0.5);
        let path = f.path.clone();
        let truth = SnapshotReader::open(&f, 0.5)
            .unwrap()
            .window(&BBox::unit(), 8)
            .unwrap();
        let collector =
            Collector::spawn_snapshot(f, 0.5, &CollectorOptions::default()).unwrap();
        let mut clients: Vec<WindowClient> = (0..3)
            .map(|_| WindowClient::connect(collector.addr).unwrap())
            .collect();
        for c in &mut clients {
            let got = c.window(&BBox::unit(), 8).unwrap();
            assert_eq!(got.len(), truth.len());
            for (a, b) in got.iter().zip(&truth) {
                assert_eq!(a.uid.0, b.uid.0);
                assert_eq!(a.data, b.data, "fan-out served different bytes");
            }
            let lod = c.budgeted(&BBox::unit(), REC_LEN as u64).unwrap();
            assert_eq!(lod.grids.len(), 1);
            assert_eq!(lod.depth, 0);
        }
        let pool = collector.reader_pool().unwrap();
        let pm = pool.metrics();
        assert_eq!(
            pm.counter(names::READER_INDEX_BUILDS),
            1,
            "every session after the first must share the parsed core"
        );
        assert!(pm.counter(names::READER_SHARED_OPENS) >= 2);
        let cs = pool.cache_stats();
        assert!(cs.hits >= 1, "repeat viewers decoded their own chunks: {cs:?}");
        drop(clients);
        drop(collector);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reader_pool_shares_core_and_epoch_isolation() {
        let mut s = sim(2);
        let p = std::env::temp_dir().join(format!("win_pool_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0).unwrap();

        let pool = ReaderPool::new(DEFAULT_CHUNK_CACHE_BYTES);
        let r1 = pool.open(&f, 0.0).unwrap();
        assert_eq!(r1.metrics.counter(names::READER_INDEX_BUILDS), 1);
        let w1 = r1.window(&BBox::unit(), 1000).unwrap();
        let r2 = pool.open(&f, 0.0).unwrap();
        assert_eq!(r2.metrics.counter(names::READER_SHARED_OPENS), 1);
        assert_eq!(r2.metrics.counter(names::READER_INDEX_BUILDS), 0);
        assert_eq!(pool.live_cores(), 1);
        // r2 shares r1's core and cache: repeating the same window does
        // zero physical reads
        let before = r2.read_stats();
        let w2 = r2.window(&BBox::unit(), 1000).unwrap();
        let after = r2.read_stats();
        assert_eq!(after.read_bytes, before.read_bytes, "{after:?}");
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.uid.0, b.uid.0);
            assert_eq!(a.data, b.data);
        }
        // a writer commit moves fresh pooled sessions to a new epoch: a
        // fresh core and fresh cache keys serve the new bytes, while the
        // old sessions keep their pinned view
        for (i, g) in s.grids.iter_mut().enumerate() {
            let fresh = vec![i as f32 + 5000.0; DGRID_CELLS];
            g.cur.set_interior(var::P, &fresh);
        }
        iokernel::rewrite_snapshot_cells(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            0.0,
            &iokernel::SnapshotOptions::default(),
        )
        .unwrap();
        let r3 = pool.open(&f, 0.0).unwrap();
        assert_eq!(
            r3.metrics.counter(names::READER_INDEX_BUILDS),
            1,
            "a new epoch must build a fresh core"
        );
        assert_eq!(pool.live_cores(), 2);
        let w3 = r3.window(&BBox::unit(), 1000).unwrap();
        let p_at = |w: &[WindowGrid]| w[0].data[var::P * DGRID_CELLS];
        assert_ne!(p_at(&w1), p_at(&w3), "new epoch served stale cached bytes");
        let w1_again = r1.window(&BBox::unit(), 1000).unwrap();
        assert_eq!(p_at(&w1), p_at(&w1_again), "pinned session lost its view");
        // dropping every session of a core prunes it at the next open
        drop(r1);
        drop(r2);
        drop(r3);
        let r4 = pool.open(&f, 0.0).unwrap();
        assert_eq!(pool.live_cores(), 1);
        drop(r4);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn online_and_offline_agree() {
        let p = std::env::temp_dir().join(format!("win_agree_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 1.5).unwrap();
        let reader = SnapshotReader::open(&f, 1.5).unwrap();
        let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let win = BBox {
            min: [0.4, 0.4, 0.4],
            max: [0.6, 0.6, 0.6],
        };
        let online = client.window(&win, 16).unwrap();
        let offline = reader.window(&win, 16).unwrap();
        assert_eq!(online.len(), offline.len());
        let key = |g: &WindowGrid| g.uid.loc().0;
        let mut on: Vec<_> = online.iter().map(key).collect();
        let mut off: Vec<_> = offline.iter().map(key).collect();
        on.sort_unstable();
        off.sort_unstable();
        assert_eq!(on, off);
        std::fs::remove_file(&p).ok();
    }
}
