//! The **sliding window** (paper §2.3, §3.1): selective, level-of-detail
//! bounded visualisation access — online against the running simulation,
//! offline against any snapshot in the h5lite file.
//!
//! The key property in both modes: the data volume returned is bounded by
//! the grid *budget*, not by the domain size. Large windows come back at a
//! coarse level of detail (the interior d-grids hold the bottom-up averaged
//! values), small windows descend to the finest grids — "zooming into the
//! data" — so even a trillion-cell domain is explorable over a fixed-rate
//! link.
//!
//! ## Online path (paper Fig 3)
//!
//! 1. the front-end client sends a request to the **collector**'s TCP
//!    socket;
//! 2. the collector forwards the query to the neighbourhood server, which
//!    selects the relevant d-grids at the right level of detail;
//! 3. + 4. the owning processes (here: the shared domain state) provide the
//!    selected grid data to the collector;
//! 5. the collector streams the response back to the client.
//!
//! ## Offline path (paper §3.2)
//!
//! The same traversal over the snapshot datasets: start at the root grid
//! (always row 0 of `grid_property`), follow `subgrid uid` links through a
//! UID→row map, prune by `bounding box`, stop when descending would burst
//! the budget, and read *only the selected rows* of `current_cell_data`.
//! Chunk-compressed snapshots (h5lite format v2) decompress transparently
//! inside [`H5File::read_rows`]; the file's LRU chunk cache keeps the
//! row-at-a-time traversal from re-inflating the same chunk per row, even
//! when a multi-grid query straddles chunk boundaries.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Simulation;
use crate::h5lite::{codec, H5File};
use crate::iokernel::{self, ROW_ELEMS};
use crate::tree::uid::Uid;
use crate::tree::BBox;
use crate::{DGRID_CELLS, NVAR};

/// One grid's worth of visualisation data.
#[derive(Clone, Debug)]
pub struct WindowGrid {
    pub uid: Uid,
    pub depth: u32,
    pub bbox: BBox,
    /// `NVAR · 16³` values: all variables' interiors, variable-major.
    pub data: Vec<f32>,
}

// ---------------------------------------------------------------------------
// offline window
// ---------------------------------------------------------------------------

/// Offline sliding-window query against the snapshot at time `t`.
pub fn offline_window(
    file: &H5File,
    t: f64,
    window: &BBox,
    budget: usize,
) -> Result<Vec<WindowGrid>> {
    let group = iokernel::ts_group(t);
    let ds_prop = file.dataset(&group, "grid_property")?;
    let ds_sub = file.dataset(&group, "subgrid_uid")?;
    let ds_bbox = file.dataset(&group, "bounding_box")?;
    let ds_cur = file.dataset(&group, "current_cell_data")?;
    let uids = file.read_all_u64(&ds_prop)?;
    if uids.is_empty() {
        bail!("window: empty snapshot");
    }
    // UID → row index (the offline analogue of the neighbourhood server)
    let row_of: std::collections::HashMap<u64, u64> = uids
        .iter()
        .enumerate()
        .map(|(r, &u)| (u, r as u64))
        .collect();

    let bbox_of = |row: u64| -> Result<BBox> {
        let b = codec::bytes_to_f64s(&file.read_rows(&ds_bbox, row, 1)?);
        Ok(BBox {
            min: [b[0], b[1], b[2]],
            max: [b[3], b[4], b[5]],
        })
    };
    let children_of = |row: u64| -> Result<Vec<u64>> {
        let subs = codec::bytes_to_u64s(&file.read_rows(&ds_sub, row, 1)?);
        Ok(subs
            .into_iter()
            .filter(|&u| u != 0)
            .filter_map(|u| row_of.get(&u).copied())
            .collect())
    };

    // LOD descent from the root (row 0), identical to
    // NeighbourhoodServer::select_window but over file rows.
    let mut current: Vec<u64> = if bbox_of(0)?.intersects(window) {
        vec![0]
    } else {
        Vec::new()
    };
    loop {
        let mut next = Vec::with_capacity(current.len() * 4);
        let mut descended = false;
        for &row in &current {
            let kids = children_of(row)?;
            if kids.is_empty() {
                next.push(row);
            } else {
                let hits: Vec<u64> = kids
                    .into_iter()
                    .filter(|&k| bbox_of(k).map(|b| b.intersects(window)).unwrap_or(false))
                    .collect();
                if hits.is_empty() {
                    next.push(row);
                } else {
                    descended = true;
                    next.extend(hits);
                }
            }
        }
        if !descended || next.len() > budget {
            break;
        }
        current = next;
    }

    // read only the selected rows
    current
        .into_iter()
        .map(|row| {
            let data = codec::bytes_to_f32s(&file.read_rows(&ds_cur, row, 1)?);
            let uid = Uid(uids[row as usize]);
            Ok(WindowGrid {
                uid,
                depth: uid.loc().depth(),
                bbox: bbox_of(row)?,
                data,
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// online window: collector process + client
// ---------------------------------------------------------------------------

const REQ_MAGIC: u32 = 0x5357_494E; // "SWIN"

/// Handle to a running collector thread.
pub struct Collector {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Collector {
    /// Spawn the collector on an ephemeral localhost port, serving
    /// sliding-window queries against the shared simulation state.
    pub fn spawn(sim: Arc<RwLock<Simulation>>) -> Result<Collector> {
        let listener = TcpListener::bind("127.0.0.1:0").context("collector bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = handle_client(stream, &sim);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Collector {
            addr,
            stop,
            handle: Some(handle),
        })
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_client(mut stream: TcpStream, sim: &Arc<RwLock<Simulation>>) -> Result<()> {
    stream.set_nodelay(true).ok();
    // ---- request: magic, bbox, budget --------------------------------- (1)
    let mut req = [0u8; 4 + 48 + 4];
    stream.read_exact(&mut req)?;
    let magic = u32::from_le_bytes(req[0..4].try_into().unwrap());
    if magic != REQ_MAGIC {
        bail!("collector: bad request magic");
    }
    let f = |i: usize| f64::from_le_bytes(req[4 + i * 8..12 + i * 8].try_into().unwrap());
    let window = BBox {
        min: [f(0), f(1), f(2)],
        max: [f(3), f(4), f(5)],
    };
    let budget = u32::from_le_bytes(req[52..56].try_into().unwrap()) as usize;

    // ---- neighbourhood server selects the grids ------------------------ (2)
    let sim = sim.read().map_err(|_| anyhow!("collector: lock poisoned"))?;
    let sel = sim.nbs.select_window(&window, budget);

    // ---- owning processes provide the data, collector streams it ---- (3-5)
    let mut out: Vec<u8> = Vec::with_capacity(4 + sel.len() * (8 + 4 + 48 + ROW_ELEMS * 4));
    out.extend_from_slice(&(sel.len() as u32).to_le_bytes());
    let mut interior = vec![0.0f32; DGRID_CELLS];
    for idx in sel {
        let node = sim.nbs.tree.node(idx);
        out.extend_from_slice(&node.uid().0.to_le_bytes());
        out.extend_from_slice(&node.depth().to_le_bytes());
        for v in node.bbox.min.iter().chain(node.bbox.max.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in 0..NVAR {
            sim.grids[idx as usize]
                .cur
                .extract_interior(v, &mut interior);
            for x in &interior {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    drop(sim);
    stream.write_all(&out)?;
    Ok(())
}

/// Front-end client: one sliding-window query over TCP.
pub fn query(addr: SocketAddr, window: &BBox, budget: u32) -> Result<Vec<WindowGrid>> {
    let mut stream = TcpStream::connect(addr).context("window client connect")?;
    let mut req = Vec::with_capacity(56);
    req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
    for v in window.min.iter().chain(window.max.iter()) {
        req.extend_from_slice(&v.to_le_bytes());
    }
    req.extend_from_slice(&budget.to_le_bytes());
    stream.write_all(&req)?;

    let mut n_buf = [0u8; 4];
    stream.read_exact(&mut n_buf)?;
    let n = u32::from_le_bytes(n_buf) as usize;
    let mut grids = Vec::with_capacity(n);
    let rec_len = 8 + 4 + 48 + ROW_ELEMS * 4;
    let mut rec = vec![0u8; rec_len];
    for _ in 0..n {
        stream.read_exact(&mut rec)?;
        let uid = Uid(u64::from_le_bytes(rec[0..8].try_into().unwrap()));
        let depth = u32::from_le_bytes(rec[8..12].try_into().unwrap());
        let f = |i: usize| f64::from_le_bytes(rec[12 + i * 8..20 + i * 8].try_into().unwrap());
        let bbox = BBox {
            min: [f(0), f(1), f(2)],
            max: [f(3), f(4), f(5)],
        };
        let data = codec::bytes_to_f32s(&rec[60..]);
        grids.push(WindowGrid {
            uid,
            depth,
            bbox,
            data,
        });
    }
    Ok(grids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::pario::ParallelIo;
    use crate::physics::bc::DomainBc;
    use crate::physics::Params;
    use crate::tree::SpaceTree;
    use crate::var;

    fn sim(depth: u32) -> Simulation {
        let tree = SpaceTree::full(BBox::unit(), depth);
        let mut s = Simulation::new(
            tree,
            3,
            DomainBc::all_walls(),
            Params::isothermal(0.01, 1.0 / 32.0, 0.01),
        );
        // paint P with the arena index so grids are distinguishable
        for (i, g) in s.grids.iter_mut().enumerate() {
            let f = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &f);
        }
        s
    }

    #[test]
    fn offline_window_full_domain_coarse() {
        let p = std::env::temp_dir().join(format!("win_off_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.5).unwrap();
        // budget 1 → root only (coarsest LOD)
        let w = offline_window(&f, 0.5, &BBox::unit(), 1).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].depth, 0);
        assert_eq!(w[0].data.len(), ROW_ELEMS);
        // budget 8 → depth 1
        let w = offline_window(&f, 0.5, &BBox::unit(), 8).unwrap();
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|g| g.depth == 1));
        // large budget → all 64 leaves
        let w = offline_window(&f, 0.5, &BBox::unit(), 1000).unwrap();
        assert_eq!(w.len(), 64);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn offline_window_zoom_returns_correct_data() {
        let p = std::env::temp_dir().join(format!("win_zoom_{}.h5", std::process::id()));
        let s = sim(1);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0).unwrap();
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let w = offline_window(&f, 0.0, &corner, 64).unwrap();
        assert_eq!(w.len(), 1, "one leaf covers the corner window");
        // its pressure payload equals the painted arena index
        let idx = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.01; 3]))
            .unwrap();
        let pslice = &w[0].data[var::P * DGRID_CELLS..(var::P + 1) * DGRID_CELLS];
        assert!(pslice.iter().all(|&x| x == idx as f32));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn offline_window_identical_on_compressed_and_raw_snapshots() {
        let p = std::env::temp_dir().join(format!("win_comp_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let comp = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            0.0,
            &iokernel::SnapshotOptions::default(),
        )
        .unwrap();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            1.0,
            &iokernel::SnapshotOptions::uncompressed(),
        )
        .unwrap();
        assert!(comp.io.stored_bytes < comp.io.bytes);
        // every zoom level returns identical grids + payloads on both
        for budget in [1usize, 8, 1000] {
            let a = offline_window(&f, 0.0, &BBox::unit(), budget).unwrap();
            let b = offline_window(&f, 1.0, &BBox::unit(), budget).unwrap();
            assert_eq!(a.len(), b.len(), "budget {budget}");
            for (ga, gb) in a.iter().zip(&b) {
                assert_eq!(ga.uid.0, gb.uid.0);
                assert_eq!(ga.data, gb.data);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn online_collector_roundtrip() {
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        // full-domain query at budget 8 → the 8 depth-1 grids
        let grids = query(collector.addr, &BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
        assert!(grids.iter().all(|g| g.depth == 1));
        assert!(grids.iter().all(|g| g.data.len() == ROW_ELEMS));
        // zoomed query descends deeper
        let corner = BBox {
            min: [0.0; 3],
            max: [0.1; 3],
        };
        let zoom = query(collector.addr, &corner, 8).unwrap();
        assert!(zoom.iter().any(|g| g.depth == 2), "{zoom:?} depths");
    }

    #[test]
    fn online_window_sees_live_updates() {
        let s = sim(1);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let before = query(collector.addr, &BBox::unit(), 1).unwrap();
        // mutate the root grid's pressure
        {
            let mut sim = shared.write().unwrap();
            let f = vec![777.0f32; DGRID_CELLS];
            sim.grids[0].cur.set_interior(var::P, &f);
        }
        let after = query(collector.addr, &BBox::unit(), 1).unwrap();
        let pr = |w: &[WindowGrid]| w[0].data[var::P * DGRID_CELLS];
        assert_ne!(pr(&before), pr(&after));
        assert_eq!(pr(&after), 777.0);
    }

    #[test]
    fn online_and_offline_agree() {
        let p = std::env::temp_dir().join(format!("win_agree_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 1.5).unwrap();
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let win = BBox {
            min: [0.4, 0.4, 0.4],
            max: [0.6, 0.6, 0.6],
        };
        let online = query(collector.addr, &win, 16).unwrap();
        let offline = offline_window(&f, 1.5, &win, 16).unwrap();
        assert_eq!(online.len(), offline.len());
        let key = |g: &WindowGrid| g.uid.loc().0;
        let mut on: Vec<_> = online.iter().map(key).collect();
        let mut off: Vec<_> = offline.iter().map(key).collect();
        on.sort_unstable();
        off.sort_unstable();
        assert_eq!(on, off);
        std::fs::remove_file(&p).ok();
    }
}
