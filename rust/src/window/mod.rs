//! The **sliding window** (paper §2.3, §3.1): selective, level-of-detail
//! bounded visualisation access — online against the running simulation,
//! offline against any snapshot in the h5lite file.
//!
//! The key property in both modes: the data volume returned is bounded by
//! the grid *budget*, not by the domain size. Large windows come back at a
//! coarse level of detail (the interior d-grids hold the bottom-up averaged
//! values), small windows descend to the finest grids — "zooming into the
//! data" — so even a trillion-cell domain is explorable over a fixed-rate
//! link.
//!
//! ## The read session: [`SnapshotReader`]
//!
//! The documented hot path for "fast (random) access when retrieving the
//! data for visual processing" is a **session**: open one
//! [`SnapshotReader`] per `(file, timestep)` and issue every query of the
//! exploration through it.
//!
//! * **open** — parses the snapshot's topology (UID→row map, bounding
//!   boxes, child links) and its [`crate::lod`] pyramid index once, pins
//!   the file's current commit **epoch** ([`H5File::pin_epoch`]) and opens
//!   a private descriptor with its own byte-budgeted decoded-chunk cache
//!   ([`SnapshotReaderOptions::cache_bytes`]).
//! * **query*** — [`SnapshotReader::window`] (fixed grid count),
//!   [`SnapshotReader::budgeted`] (byte budget over the pyramid) and
//!   [`SnapshotReader::progressive`] (coarse-to-fine streaming) all serve
//!   from the in-memory indexes; only the selected cell rows touch disk,
//!   and repeats hit the session cache. Per-session counters
//!   ([`crate::metrics::names`]) plus [`SnapshotReader::read_stats`]
//!   expose the amortisation.
//! * **drop** — releases the epoch pin: extents the writer retired while
//!   the session lived return to the free-space manager.
//!
//! The epoch pin is the session's consistency contract: on a
//! [`crate::h5lite::ReusePolicy::AfterCommit`] file (the default), a
//! session keeps reading **byte-identical** data across any number of
//! writer commits — steering rewrites retire the session's extents, but
//! the generation-tagged retire queue parks them until the pin drops.
//! Fresh sessions always see the latest committed state.
//!
//! The pre-session free functions ([`offline_window`],
//! [`offline_window_budgeted`], [`offline_window_progressive`]) remain as
//! deprecated shims over a throwaway session: they re-parse every index on
//! every call, which is exactly the cost the session amortises.
//!
//! ## Online path (paper Fig 3)
//!
//! 1. the front-end client connects a [`WindowClient`] **session** to the
//!    **collector**'s TCP socket;
//! 2. the collector forwards each query to the neighbourhood server, which
//!    selects the relevant d-grids at the right level of detail;
//! 3. + 4. the owning processes (here: the shared domain state) provide the
//!    selected grid data to the collector;
//! 5. the collector streams the response back to the client — and the
//!    connection stays up for the next query of the zoom sequence.
//!
//! The [`Collector`] runs **one server-side session per connection**: a
//! connection-long loop serving any mix of the fixed-count (`SWIN`) and
//! byte-budgeted (`SWLD`) wire protocols. The per-query [`query`] /
//! [`query_budgeted`] free functions are deprecated shims (sessions of
//! length one).
//!
//! ## Byte-budgeted queries over the LOD pyramid
//!
//! [`SnapshotReader::budgeted`] takes a **byte** budget and serves the
//! region of interest from the finest [`crate::lod`] pyramid level whose
//! cover fits it — a whole-domain query over a huge snapshot comes back as
//! a handful of coarse grids instead of every leaf, and zooming in
//! automatically lands on finer levels. [`SnapshotReader::progressive`]
//! streams the same answer coarse-to-fine for immediate first paint.
//! Pyramid-less files (pre-LOD, or written with
//! `SnapshotOptions { lod: false, .. }`) fall back to the classic
//! traversal transparently. Chunk-compressed snapshots decompress
//! transparently inside [`H5File::read_rows`], each chunk through its own
//! recorded codec.

use std::collections::{BTreeSet, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Simulation;
use crate::h5lite::{codec, Dataset, EpochPin, H5File, ReadStats, DEFAULT_CHUNK_CACHE_BYTES};
use crate::iokernel::{self, ROW_BYTES, ROW_ELEMS};
use crate::lod::{self, LodIndex};
use crate::metrics::{names, Metrics};
use crate::tree::uid::{LocCode, Uid};
use crate::tree::BBox;
use crate::{DGRID_CELLS, NVAR};

/// One grid's worth of visualisation data.
#[derive(Clone, Debug)]
pub struct WindowGrid {
    pub uid: Uid,
    pub depth: u32,
    pub bbox: BBox,
    /// `NVAR · 16³` values: all variables' interiors, variable-major.
    pub data: Vec<f32>,
}

/// Answer of a byte-budgeted window query.
#[derive(Debug)]
pub struct LodWindow {
    pub grids: Vec<WindowGrid>,
    /// Pyramid level served: 0 = full resolution (the tree's leaves),
    /// `max` = the single root grid. Adaptive trees may mix in coarser
    /// ancestors where nothing finer is stored — each grid carries its own
    /// depth/bbox.
    pub level: u32,
    /// Cell-data payload bytes fetched to answer (the budget's currency;
    /// the topology/location indexes add a few KiB on top, paid once per
    /// session).
    pub bytes_read: u64,
    /// True when the answer came from stored pyramid levels; false on the
    /// full-resolution or fallback paths.
    pub from_pyramid: bool,
}

// ---------------------------------------------------------------------------
// the offline read session
// ---------------------------------------------------------------------------

/// Tuning for a [`SnapshotReader`] session.
#[derive(Clone, Copy, Debug)]
pub struct SnapshotReaderOptions {
    /// Byte budget of the session's private decoded-chunk cache
    /// ([`DEFAULT_CHUNK_CACHE_BYTES`] by default). Size it to the working
    /// set of the zoom sequence the session serves; `0` disables caching
    /// (useful in tests that must observe on-disk bytes).
    pub cache_bytes: u64,
}

impl Default for SnapshotReaderOptions {
    fn default() -> SnapshotReaderOptions {
        SnapshotReaderOptions {
            cache_bytes: DEFAULT_CHUNK_CACHE_BYTES,
        }
    }
}

/// A long-lived, epoch-pinned read session over one snapshot — the
/// documented hot-path read API (see the [`crate::window`] module docs
/// for the open → query* → drop lifecycle and the consistency contract).
///
/// The session owns a private descriptor on the file (so it survives — and
/// stays consistent across — `&mut` use of the opener's handle), the
/// parsed topology and [`LodIndex`], a byte-budgeted chunk cache, and an
/// [`EpochPin`] on the opener's free-space manager. All queries are `&self`
/// and may run concurrently from many threads.
pub struct SnapshotReader {
    /// Session-private handle: parsed from the last *committed* footer at
    /// open, never refreshed — the snapshot-isolation the epoch pin keeps
    /// byte-valid.
    file: H5File,
    pin: EpochPin,
    t: f64,
    /// Domain box from `/common` (absent on files without it; only the
    /// pyramid level selection needs it).
    domain: Option<BBox>,
    /// Packed UID per snapshot row.
    uids: Vec<u64>,
    /// Bounding box per snapshot row.
    bboxes: Vec<BBox>,
    /// Child *rows* per snapshot row (empty = leaf).
    children: Vec<Vec<u64>>,
    ds_cur: Dataset,
    lod: Option<LodIndex>,
    /// Per-session counters ([`crate::metrics::names`]): index builds and
    /// bytes (paid once at open), queries, grids and payload served.
    pub metrics: Metrics,
}

impl SnapshotReader {
    /// Open a session on the snapshot at time `t` with default options.
    pub fn open(file: &H5File, t: f64) -> Result<SnapshotReader> {
        SnapshotReader::open_with(file, t, &SnapshotReaderOptions::default())
    }

    /// Open a session on the snapshot at time `t`: pin `file`'s current
    /// commit epoch, open a private descriptor on its path (landing on the
    /// last committed state) and parse the topology + LOD indexes once.
    pub fn open_with(
        file: &H5File,
        t: f64,
        opts: &SnapshotReaderOptions,
    ) -> Result<SnapshotReader> {
        // pin before the fresh open: a commit racing the open can only
        // move the opened state *past* the pinned epoch, so the pin is
        // conservative (it may park slightly more, never less)
        let pin = file.pin_epoch();
        let rf = H5File::open(&file.path)?;
        rf.set_chunk_cache_budget(opts.cache_bytes);
        let group = iokernel::ts_group(t);
        let ds_prop = rf.dataset(&group, "grid_property")?;
        let ds_sub = rf.dataset(&group, "subgrid_uid")?;
        let ds_bbox = rf.dataset(&group, "bounding_box")?;
        let ds_cur = rf.dataset(&group, "current_cell_data")?;
        let uids = rf.read_all_u64(&ds_prop)?;
        if uids.is_empty() {
            bail!("window: empty snapshot at t={t}");
        }
        // UID → row index (the offline analogue of the neighbourhood
        // server), resolved once into per-row child links
        let row_of: HashMap<u64, u64> = uids
            .iter()
            .enumerate()
            .map(|(r, &u)| (u, r as u64))
            .collect();
        let bbox_raw = rf.read_all_f64(&ds_bbox)?;
        let bboxes: Vec<BBox> = bbox_raw
            .chunks_exact(6)
            .map(|b| BBox {
                min: [b[0], b[1], b[2]],
                max: [b[3], b[4], b[5]],
            })
            .collect();
        let subs = rf.read_all_u64(&ds_sub)?;
        let children: Vec<Vec<u64>> = subs
            .chunks_exact(8)
            .map(|c| {
                c.iter()
                    .filter(|&&u| u != 0)
                    .filter_map(|u| row_of.get(u).copied())
                    .collect()
            })
            .collect();
        if bboxes.len() != uids.len() || children.len() != uids.len() {
            bail!("window: snapshot topology datasets disagree on row count");
        }
        let domain = iokernel::read_domain(&rf).ok();
        let lod = LodIndex::open(&rf, &group)?;
        let metrics = Metrics::new();
        metrics.add(names::READER_INDEX_BUILDS, 1);
        // everything read so far is index, paid once per session
        metrics.add(names::READER_INDEX_BYTES, rf.read_stats().read_bytes);
        Ok(SnapshotReader {
            file: rf,
            pin,
            t,
            domain,
            uids,
            bboxes,
            children,
            ds_cur,
            lod,
            metrics,
        })
    }

    /// Elapsed time of the snapshot this session serves.
    pub fn t(&self) -> f64 {
        self.t
    }

    /// Number of grids (rows) in the snapshot.
    pub fn n_grids(&self) -> usize {
        self.uids.len()
    }

    /// True when the snapshot stores a LOD pyramid.
    pub fn has_pyramid(&self) -> bool {
        self.lod.is_some()
    }

    /// The commit epoch this session pinned at open (diagnostics).
    pub fn pinned_epoch(&self) -> u64 {
        self.pin.epoch()
    }

    /// Physical-read accounting of the session's private handle: bytes
    /// actually read from disk and the chunk-cache hit/miss split.
    pub fn read_stats(&self) -> ReadStats {
        self.file.read_stats()
    }

    fn note_query(&self, grids: usize) {
        self.metrics.add(names::READER_QUERIES, 1);
        self.metrics.add(names::READER_GRIDS, grids as u64);
        self.metrics
            .add(names::READER_PAYLOAD_BYTES, grids as u64 * ROW_BYTES);
    }

    fn read_grid(&self, row: u64) -> Result<WindowGrid> {
        let data = codec::bytes_to_f32s(&self.file.read_rows(&self.ds_cur, row, 1)?);
        let uid = Uid(self.uids[row as usize]);
        Ok(WindowGrid {
            uid,
            depth: uid.loc().depth(),
            bbox: self.bboxes[row as usize],
            data,
        })
    }

    /// The classic LOD descent from the root (row 0) over the in-memory
    /// topology index — identical to `NeighbourhoodServer::select_window`
    /// but over snapshot rows; only the selected rows' cell data touches
    /// the file.
    fn classic(&self, window: &BBox, budget: usize) -> Result<Vec<WindowGrid>> {
        let mut current: Vec<u64> = if self.bboxes[0].intersects(window) {
            vec![0]
        } else {
            Vec::new()
        };
        loop {
            let mut next = Vec::with_capacity(current.len() * 4);
            let mut descended = false;
            for &row in &current {
                let kids = &self.children[row as usize];
                if kids.is_empty() {
                    next.push(row);
                } else {
                    let hits: Vec<u64> = kids
                        .iter()
                        .copied()
                        .filter(|&k| self.bboxes[k as usize].intersects(window))
                        .collect();
                    if hits.is_empty() {
                        next.push(row);
                    } else {
                        descended = true;
                        next.extend(hits);
                    }
                }
            }
            if !descended || next.len() > budget {
                break;
            }
            current = next;
        }
        current.into_iter().map(|row| self.read_grid(row)).collect()
    }

    /// Sliding-window query bounded by a grid-count `budget`: large
    /// windows come back coarse, small windows descend to the leaves.
    pub fn window(&self, window: &BBox, budget: usize) -> Result<Vec<WindowGrid>> {
        let grids = self.classic(window, budget)?;
        self.note_query(grids.len());
        Ok(grids)
    }

    /// Sliding-window query under a **byte budget**: serve `window` from
    /// the finest resolution whose cover fits `budget_bytes`, using the
    /// snapshot's LOD pyramid when it has one. Level 0 (full resolution)
    /// reads the tree's leaf grids; coarser levels read the pyramid
    /// datasets — a whole-domain overview costs one grid row, not the
    /// whole snapshot. The answer always holds at least one grid, even
    /// under a sub-grid budget. A pyramid-less snapshot falls back to the
    /// classic grid-count traversal with the budget converted to grids.
    pub fn budgeted(&self, window: &BBox, budget_bytes: u64) -> Result<LodWindow> {
        let row_bytes = ROW_BYTES;
        let Some(idx) = &self.lod else {
            let budget_grids = (budget_bytes / row_bytes).max(1) as usize;
            let grids = self.classic(window, budget_grids)?;
            self.note_query(grids.len());
            return Ok(LodWindow {
                bytes_read: grids.len() as u64 * row_bytes,
                grids,
                level: 0,
                from_pyramid: false,
            });
        };
        let domain = self.domain.ok_or_else(|| {
            anyhow!("window: snapshot stores a pyramid but /common carries no domain box")
        })?;
        let d_max = idx.max_level();
        // finest level whose whole-cover byte count fits the budget (the
        // count is an O(1) upper bound, so the chosen level never bursts
        // it); the root level is the floor — an answer is always
        // affordable
        let mut chosen = d_max;
        for l in 0..=d_max {
            if lod::intersect_count(&domain, d_max - l, window) * row_bytes <= budget_bytes {
                chosen = l;
                break;
            }
        }
        let out = if chosen == 0 {
            let grids = self.classic(window, usize::MAX)?;
            LodWindow {
                bytes_read: grids.len() as u64 * row_bytes,
                grids,
                level: 0,
                from_pyramid: false,
            }
        } else {
            self.read_pyramid_level(idx, &domain, chosen, window)?
        };
        self.note_query(out.grids.len());
        Ok(out)
    }

    /// Read the cover of `window` at pyramid level `l ≥ 1`. Coordinates an
    /// adaptive tree never stored resolve to their nearest stored ancestor
    /// (deduplicated), so the cover is complete at mixed depth.
    fn read_pyramid_level(
        &self,
        idx: &LodIndex,
        domain: &BBox,
        l: u32,
        window: &BBox,
    ) -> Result<LodWindow> {
        let row_bytes = ROW_BYTES;
        let d_max = idx.max_level();
        let depth = idx
            .level(l)
            .ok_or_else(|| anyhow!("window: no lod level {l}"))?
            .depth;
        let [ri, rj, rk] = lod::coord_range(domain, depth, window);
        let mut picked: BTreeSet<(u32, u64)> = BTreeSet::new();
        for i in ri.0..ri.1 {
            for j in rj.0..rj.1 {
                for k in rk.0..rk.1 {
                    let (mut lc, mut c) = (l, (i, j, k));
                    loop {
                        let lvl = idx.level(lc).unwrap();
                        let row = LocCode::from_coords(lvl.depth, c.0, c.1, c.2)
                            .and_then(|loc| lvl.row_of(loc));
                        if let Some(row) = row {
                            picked.insert((lc, row));
                            break;
                        }
                        if lc >= d_max {
                            bail!("window: lod pyramid misses an ancestor for ({i},{j},{k})");
                        }
                        lc += 1;
                        c = (c.0 / 2, c.1 / 2, c.2 / 2);
                    }
                }
            }
        }
        let mut grids = Vec::with_capacity(picked.len());
        let mut bytes_read = 0u64;
        for &(lc, row) in &picked {
            let lvl = idx.level(lc).unwrap();
            let data = lvl.read_row(&self.file, row)?;
            bytes_read += row_bytes;
            let loc = lvl.locs[row as usize];
            let (i, j, k) = loc.coords();
            grids.push(WindowGrid {
                uid: Uid::new(0, 0, loc),
                depth: loc.depth(),
                bbox: lod::grid_bbox(domain, loc.depth(), i, j, k),
                data,
            });
        }
        Ok(LodWindow {
            grids,
            level: l,
            bytes_read,
            from_pyramid: true,
        })
    }

    /// Progressive refinement: stream `window` coarse-to-fine — the root
    /// level first (immediate first paint), then each finer level while
    /// the *cumulative* bytes stay within `total_budget_bytes`. The last
    /// element is the finest affordable answer; the first is always
    /// emitted so the viewer never starves. Falls back to a single
    /// budgeted answer on pyramid-less snapshots.
    pub fn progressive(
        &self,
        window: &BBox,
        total_budget_bytes: u64,
    ) -> Result<Vec<LodWindow>> {
        let row_bytes = ROW_BYTES;
        let Some(idx) = &self.lod else {
            return Ok(vec![self.budgeted(window, total_budget_bytes)?]);
        };
        let domain = self.domain.ok_or_else(|| {
            anyhow!("window: snapshot stores a pyramid but /common carries no domain box")
        })?;
        let d_max = idx.max_level();
        let mut out: Vec<LodWindow> = Vec::new();
        let mut spent = 0u64;
        let mut total_grids = 0usize;
        for l in (0..=d_max).rev() {
            let cost = lod::intersect_count(&domain, d_max - l, window) * row_bytes;
            if !out.is_empty() && spent + cost > total_budget_bytes {
                break;
            }
            let step = if l == 0 {
                let grids = self.classic(window, usize::MAX)?;
                LodWindow {
                    bytes_read: grids.len() as u64 * row_bytes,
                    grids,
                    level: 0,
                    from_pyramid: false,
                }
            } else {
                self.read_pyramid_level(idx, &domain, l, window)?
            };
            spent += step.bytes_read;
            total_grids += step.grids.len();
            out.push(step);
        }
        self.note_query(total_grids);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// deprecated per-call shims over a throwaway session
// ---------------------------------------------------------------------------

/// Offline sliding-window query against the snapshot at time `t`.
///
/// Deprecated shim over a throwaway [`SnapshotReader`]: every call
/// re-opens the file and re-parses the topology index. It answers from the
/// last *committed* state of `file`, exactly like a fresh open — which
/// also means `file.path` must still exist on disk (a session opens its
/// own descriptor; the passed handle's is not reused).
#[deprecated(
    note = "open a `SnapshotReader` session — the free functions re-parse the snapshot index on every call"
)]
pub fn offline_window(
    file: &H5File,
    t: f64,
    window: &BBox,
    budget: usize,
) -> Result<Vec<WindowGrid>> {
    SnapshotReader::open(file, t)?.window(window, budget)
}

/// Byte-budgeted offline window query (see [`SnapshotReader::budgeted`]).
///
/// Deprecated shim over a throwaway [`SnapshotReader`]: every call rebuilds
/// the `LodIndex` (re-reading every `level_<ℓ>_locs` dataset) — the exact
/// hot-path cost the session amortises to once.
#[deprecated(
    note = "open a `SnapshotReader` session — the free functions rebuild the LodIndex on every call"
)]
pub fn offline_window_budgeted(
    file: &H5File,
    t: f64,
    window: &BBox,
    budget_bytes: u64,
) -> Result<LodWindow> {
    SnapshotReader::open(file, t)?.budgeted(window, budget_bytes)
}

/// Progressive coarse-to-fine offline window query (see
/// [`SnapshotReader::progressive`]).
///
/// Deprecated shim over a throwaway [`SnapshotReader`].
#[deprecated(
    note = "open a `SnapshotReader` session — the free functions rebuild the LodIndex on every call"
)]
pub fn offline_window_progressive(
    file: &H5File,
    t: f64,
    window: &BBox,
    total_budget_bytes: u64,
) -> Result<Vec<LodWindow>> {
    SnapshotReader::open(file, t)?.progressive(window, total_budget_bytes)
}

// ---------------------------------------------------------------------------
// online window: collector process + client sessions
// ---------------------------------------------------------------------------

const REQ_MAGIC: u32 = 0x5357_494E; // "SWIN"
/// Budget-aware request: bbox + byte budget, answered at the finest
/// level-of-detail whose cover fits (the online twin of the pyramid —
/// interior d-grids hold the restricted averages the bottom-up step
/// maintains).
const LOD_REQ_MAGIC: u32 = 0x5357_4C44; // "SWLD"
/// Wire length of one grid record: uid, depth, bbox, cell data.
const REC_LEN: usize = 8 + 4 + 48 + ROW_ELEMS * 4;

/// Handle to a running collector thread.
///
/// Each accepted connection is served by its own thread running a
/// **session loop**: any number of `SWIN` / `SWLD` requests over one
/// socket until the client hangs up — the online counterpart of the
/// offline [`SnapshotReader`] session. Old one-shot clients are simply
/// sessions of length one, so the wire protocols are unchanged.
pub struct Collector {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    sessions: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Collector {
    /// Spawn the collector on an ephemeral localhost port, serving
    /// sliding-window query sessions against the shared simulation state.
    pub fn spawn(sim: Arc<RwLock<Simulation>>) -> Result<Collector> {
        let listener = TcpListener::bind("127.0.0.1:0").context("collector bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let sessions: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let (stop2, sessions2) = (stop.clone(), sessions.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let sim = sim.clone();
                        let stop = stop2.clone();
                        let h = std::thread::spawn(move || {
                            let _ = serve_session(stream, &sim, &stop);
                        });
                        // reap finished sessions so a long-lived collector
                        // tracks concurrent connections, not every
                        // connection it ever accepted
                        let mut sessions = sessions2.lock().unwrap();
                        let mut live = Vec::with_capacity(sessions.len() + 1);
                        for s in sessions.drain(..) {
                            if s.is_finished() {
                                let _ = s.join();
                            } else {
                                live.push(s);
                            }
                        }
                        live.push(h);
                        *sessions = live;
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Collector {
            addr,
            stop,
            handle: Some(handle),
            sessions,
        })
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let sessions = std::mem::take(&mut *self.sessions.lock().unwrap());
        for h in sessions {
            let _ = h.join();
        }
    }
}

/// Read exactly `buf.len()` bytes, riding out the session socket's read
/// timeout so the thread can observe `stop`. With `eof_ok`, a clean EOF
/// before the first byte returns `Ok(false)` (end of session); EOF
/// mid-record is always an error.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok: bool,
) -> Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            bail!("collector: shutting down");
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 && eof_ok => return Ok(false),
            Ok(0) => bail!("collector: connection closed mid-request"),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// One server-side session (steps (2)–(5) of the Fig 3 query path, looped):
/// serve any mix of fixed-count and byte-budgeted requests over one
/// connection until the client hangs up.
fn serve_session(
    mut stream: TcpStream,
    sim: &Arc<RwLock<Simulation>>,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // short read timeout so an idle session notices a collector shutdown;
    // a write timeout so a stalled client (never draining its response)
    // cannot park this thread in write_all forever — Collector::drop joins
    // every session thread, so an unbounded write would hang the host
    stream.set_read_timeout(Some(std::time::Duration::from_millis(25)))?;
    stream.set_write_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut magic = [0u8; 4];
    loop {
        if !read_full(&mut stream, &mut magic, stop, true)? {
            return Ok(()); // clean end of session
        }
        let mut bbox_buf = [0u8; 48];
        read_full(&mut stream, &mut bbox_buf, stop, false)?;
        let window = decode_bbox(&bbox_buf);
        let out = match u32::from_le_bytes(magic) {
            REQ_MAGIC => {
                let mut b = [0u8; 4];
                read_full(&mut stream, &mut b, stop, false)?;
                respond(sim, &window, u32::from_le_bytes(b) as usize, false)?
            }
            LOD_REQ_MAGIC => {
                let mut b = [0u8; 8];
                read_full(&mut stream, &mut b, stop, false)?;
                // byte budget → grid budget: the server-side level
                // selection then picks the finest depth whose cover fits
                let budget = (u64::from_le_bytes(b) / REC_LEN as u64).max(1) as usize;
                respond(sim, &window, budget, true)?
            }
            _ => bail!("collector: bad request magic"),
        };
        stream.write_all(&out)?;
    }
}

fn decode_bbox(buf: &[u8; 48]) -> BBox {
    let f = |i: usize| f64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().unwrap());
    BBox {
        min: [f(0), f(1), f(2)],
        max: [f(3), f(4), f(5)],
    }
}

/// The neighbourhood server selects the grids at the budget's level of
/// detail, the owning processes provide the data, the collector serialises
/// the response. `lod_header` prefixes the record stream with the finest
/// tree depth served (the budgeted protocol's level report).
fn respond(
    sim: &Arc<RwLock<Simulation>>,
    window: &BBox,
    budget: usize,
    lod_header: bool,
) -> Result<Vec<u8>> {
    let sim = sim.read().map_err(|_| anyhow!("collector: lock poisoned"))?;
    let sel = sim.nbs.select_window(window, budget);
    let mut out: Vec<u8> = Vec::with_capacity(8 + sel.len() * REC_LEN);
    if lod_header {
        let depth = sel
            .iter()
            .map(|&i| sim.nbs.tree.node(i).depth())
            .max()
            .unwrap_or(0);
        out.extend_from_slice(&depth.to_le_bytes());
    }
    out.extend_from_slice(&(sel.len() as u32).to_le_bytes());
    let mut interior = vec![0.0f32; DGRID_CELLS];
    for idx in sel {
        let node = sim.nbs.tree.node(idx);
        out.extend_from_slice(&node.uid().0.to_le_bytes());
        out.extend_from_slice(&node.depth().to_le_bytes());
        for v in node.bbox.min.iter().chain(node.bbox.max.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in 0..NVAR {
            sim.grids[idx as usize]
                .cur
                .extract_interior(v, &mut interior);
            for x in &interior {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(out)
}

/// Read `n`-prefixed grid records off the wire (client side).
fn read_grid_records(stream: &mut TcpStream) -> Result<Vec<WindowGrid>> {
    let mut n_buf = [0u8; 4];
    stream.read_exact(&mut n_buf)?;
    let n = u32::from_le_bytes(n_buf) as usize;
    let mut grids = Vec::with_capacity(n);
    let mut rec = vec![0u8; REC_LEN];
    for _ in 0..n {
        stream.read_exact(&mut rec)?;
        let uid = Uid(u64::from_le_bytes(rec[0..8].try_into().unwrap()));
        let depth = u32::from_le_bytes(rec[8..12].try_into().unwrap());
        let f = |i: usize| f64::from_le_bytes(rec[12 + i * 8..20 + i * 8].try_into().unwrap());
        let bbox = BBox {
            min: [f(0), f(1), f(2)],
            max: [f(3), f(4), f(5)],
        };
        let data = codec::bytes_to_f32s(&rec[60..]);
        grids.push(WindowGrid {
            uid,
            depth,
            bbox,
            data,
        });
    }
    Ok(grids)
}

/// Answer of a byte-budgeted online query.
#[derive(Debug)]
pub struct OnlineLodWindow {
    pub grids: Vec<WindowGrid>,
    /// Finest tree depth the collector served.
    pub depth: u32,
    /// Payload bytes received (≤ the requested budget, modulo the
    /// one-grid floor).
    pub bytes: u64,
}

/// Client side of one online session: a persistent connection to the
/// [`Collector`] over which any number of fixed-count and byte-budgeted
/// queries can be issued — the wire twin of the offline
/// [`SnapshotReader`]. Dropping the client ends the server-side session.
pub struct WindowClient {
    stream: TcpStream,
}

impl WindowClient {
    /// Connect one session to a running collector.
    pub fn connect(addr: SocketAddr) -> Result<WindowClient> {
        let stream = TcpStream::connect(addr).context("window client connect")?;
        stream.set_nodelay(true).ok();
        Ok(WindowClient { stream })
    }

    /// Fixed-grid-count sliding-window query (`SWIN`).
    pub fn window(&mut self, window: &BBox, budget: u32) -> Result<Vec<WindowGrid>> {
        let mut req = Vec::with_capacity(56);
        req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        for v in window.min.iter().chain(window.max.iter()) {
            req.extend_from_slice(&v.to_le_bytes());
        }
        req.extend_from_slice(&budget.to_le_bytes());
        self.stream.write_all(&req)?;
        read_grid_records(&mut self.stream)
    }

    /// Byte-budgeted query (`SWLD`): the collector picks the finest level
    /// of detail whose cover fits `budget_bytes` and reports the depth it
    /// served.
    pub fn budgeted(&mut self, window: &BBox, budget_bytes: u64) -> Result<OnlineLodWindow> {
        let mut req = Vec::with_capacity(60);
        req.extend_from_slice(&LOD_REQ_MAGIC.to_le_bytes());
        for v in window.min.iter().chain(window.max.iter()) {
            req.extend_from_slice(&v.to_le_bytes());
        }
        req.extend_from_slice(&budget_bytes.to_le_bytes());
        self.stream.write_all(&req)?;
        let mut d = [0u8; 4];
        self.stream.read_exact(&mut d)?;
        let depth = u32::from_le_bytes(d);
        let grids = read_grid_records(&mut self.stream)?;
        let bytes = (grids.len() * REC_LEN) as u64;
        Ok(OnlineLodWindow {
            grids,
            depth,
            bytes,
        })
    }
}

/// Front-end client: one sliding-window query over TCP.
///
/// Deprecated shim: connects a throwaway [`WindowClient`] session per
/// query.
#[deprecated(note = "connect a `WindowClient` session — per-query connections pay a TCP handshake per request")]
pub fn query(addr: SocketAddr, window: &BBox, budget: u32) -> Result<Vec<WindowGrid>> {
    WindowClient::connect(addr)?.window(window, budget)
}

/// Front-end client: one **byte-budgeted** sliding-window query.
///
/// Deprecated shim: connects a throwaway [`WindowClient`] session per
/// query.
#[deprecated(note = "connect a `WindowClient` session — per-query connections pay a TCP handshake per request")]
pub fn query_budgeted(
    addr: SocketAddr,
    window: &BBox,
    budget_bytes: u64,
) -> Result<OnlineLodWindow> {
    WindowClient::connect(addr)?.budgeted(window, budget_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::pario::ParallelIo;
    use crate::physics::bc::DomainBc;
    use crate::physics::Params;
    use crate::tree::SpaceTree;
    use crate::var;

    fn sim(depth: u32) -> Simulation {
        let tree = SpaceTree::full(BBox::unit(), depth);
        let mut s = Simulation::new(
            tree,
            3,
            DomainBc::all_walls(),
            Params::isothermal(0.01, 1.0 / 32.0, 0.01),
        );
        // paint P with the arena index so grids are distinguishable
        for (i, g) in s.grids.iter_mut().enumerate() {
            let f = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &f);
        }
        s
    }

    #[test]
    fn session_window_full_domain_coarse() {
        let p = std::env::temp_dir().join(format!("win_off_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.5).unwrap();
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        assert_eq!(reader.n_grids(), 73);
        // budget 1 → root only (coarsest LOD)
        let w = reader.window(&BBox::unit(), 1).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].depth, 0);
        assert_eq!(w[0].data.len(), ROW_ELEMS);
        // budget 8 → depth 1
        let w = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|g| g.depth == 1));
        // large budget → all 64 leaves
        let w = reader.window(&BBox::unit(), 1000).unwrap();
        assert_eq!(w.len(), 64);
        // the session counted its queries and built the index exactly once
        assert_eq!(reader.metrics.counter(names::READER_QUERIES), 3);
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        assert_eq!(reader.metrics.counter(names::READER_GRIDS), 73);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn session_window_zoom_returns_correct_data() {
        let p = std::env::temp_dir().join(format!("win_zoom_{}.h5", std::process::id()));
        let s = sim(1);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0).unwrap();
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let w = reader.window(&corner, 64).unwrap();
        assert_eq!(w.len(), 1, "one leaf covers the corner window");
        // its pressure payload equals the painted arena index
        let idx = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.01; 3]))
            .unwrap();
        let pslice = &w[0].data[var::P * DGRID_CELLS..(var::P + 1) * DGRID_CELLS];
        assert!(pslice.iter().all(|&x| x == idx as f32));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn session_window_identical_on_compressed_and_raw_snapshots() {
        let p = std::env::temp_dir().join(format!("win_comp_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let comp = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            0.0,
            &iokernel::SnapshotOptions::default(),
        )
        .unwrap();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            1.0,
            &iokernel::SnapshotOptions::uncompressed(),
        )
        .unwrap();
        assert!(comp.io.stored_bytes < comp.io.bytes);
        // every zoom level returns identical grids + payloads on both
        let ra = SnapshotReader::open(&f, 0.0).unwrap();
        let rb = SnapshotReader::open(&f, 1.0).unwrap();
        for budget in [1usize, 8, 1000] {
            let a = ra.window(&BBox::unit(), budget).unwrap();
            let b = rb.window(&BBox::unit(), budget).unwrap();
            assert_eq!(a.len(), b.len(), "budget {budget}");
            for (ga, gb) in a.iter().zip(&b) {
                assert_eq!(ga.uid.0, gb.uid.0);
                assert_eq!(ga.data, gb.data);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    /// Cell-data bytes of one grid row.
    const RB: u64 = ROW_BYTES;

    fn snapshot_file(name: &str, s: &Simulation, t: f64) -> H5File {
        let p = std::env::temp_dir().join(format!("win_{name}_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, t).unwrap();
        f
    }

    #[test]
    fn budgeted_window_serves_pyramid_levels() {
        let s = sim(2);
        let f = snapshot_file("lod_levels", &s, 0.5);
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        assert!(reader.has_pyramid());
        // generous budget → full resolution, same grids as the classic path
        let full = reader.budgeted(&BBox::unit(), u64::MAX).unwrap();
        assert_eq!(full.level, 0);
        assert_eq!(full.grids.len(), 64);
        assert_eq!(full.bytes_read, 64 * RB);
        // an 8-grid budget → pyramid level 1 (the 8 depth-1 folds)
        let mid = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert_eq!(mid.level, 1);
        assert!(mid.from_pyramid);
        assert_eq!(mid.grids.len(), 8);
        assert!(mid.grids.iter().all(|g| g.depth == 1));
        assert_eq!(mid.bytes_read, 8 * RB);
        // the served values are exact folds of the painted leaves: octant 0
        // of a level-1 grid holds its first child's (constant) pressure
        let g1 = &mid.grids[0];
        let child = s.nbs.tree.lookup(g1.uid.loc().child(0)).unwrap();
        assert_eq!(g1.data[var::P * DGRID_CELLS], child as f32);
        // a one-grid budget → the root overview, 1/64 of the full bytes
        let root = reader.budgeted(&BBox::unit(), RB).unwrap();
        assert_eq!(root.level, 2);
        assert_eq!(root.grids.len(), 1);
        assert_eq!(root.grids[0].depth, 0);
        assert_eq!(root.bytes_read, RB);
        // one session, three queries, one index build
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        assert_eq!(reader.metrics.counter(names::READER_QUERIES), 3);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn budgeted_zoom_descends_levels_at_fixed_budget() {
        let s = sim(2);
        let f = snapshot_file("lod_zoom", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let budget = 4 * RB;
        let whole = reader.budgeted(&BBox::unit(), budget).unwrap();
        let octant = reader
            .budgeted(
                &BBox {
                    min: [0.0; 3],
                    max: [0.5; 3],
                },
                budget,
            )
            .unwrap();
        let corner = reader
            .budgeted(
                &BBox {
                    min: [0.0; 3],
                    max: [0.25; 3],
                },
                budget,
            )
            .unwrap();
        // shrinking the window at a fixed byte budget lands on finer levels
        assert_eq!(whole.level, 2);
        assert_eq!(octant.level, 1);
        assert_eq!(corner.level, 0);
        for w in [&whole, &octant, &corner] {
            assert!(w.bytes_read <= budget, "{} > {budget}", w.bytes_read);
            assert!(!w.grids.is_empty());
        }
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn progressive_refinement_streams_coarse_to_fine() {
        let s = sim(2);
        let f = snapshot_file("lod_prog", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        // budget for the whole cascade: 1 + 8 + 64 grids
        let steps = reader.progressive(&BBox::unit(), 73 * RB).unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(
            steps.iter().map(|s| s.level).collect::<Vec<_>>(),
            vec![2, 1, 0]
        );
        assert_eq!(steps[0].grids.len(), 1);
        assert_eq!(steps[2].grids.len(), 64);
        let total: u64 = steps.iter().map(|s| s.bytes_read).sum();
        assert!(total <= 73 * RB);
        // a sub-grid budget still paints the coarsest answer
        let tiny = reader.progressive(&BBox::unit(), 1).unwrap();
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny[0].level, 2);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn pyramid_less_snapshot_falls_back_unchanged() {
        let s = sim(2);
        let p = std::env::temp_dir().join(format!("win_nolod_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let opts = iokernel::SnapshotOptions {
            lod: false,
            ..iokernel::SnapshotOptions::default()
        };
        iokernel::write_snapshot_with(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0, &opts)
            .unwrap();
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        assert!(!reader.has_pyramid());
        // the classic API answers exactly as before the pyramid existed
        let classic = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(classic.len(), 8);
        // and the budgeted API degrades to the grid-count traversal
        let w = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert!(!w.from_pyramid);
        assert_eq!(w.level, 0);
        assert_eq!(w.grids.len(), 8);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn repeated_session_queries_serve_from_the_chunk_cache() {
        // the ROADMAP hot-path item this API closes: repeats through one
        // session rebuild no index and re-read no bytes — everything is
        // already resident
        let s = sim(2);
        let f = snapshot_file("lod_amort", &s, 0.0);
        let reader = SnapshotReader::open(&f, 0.0).unwrap();
        let roi = BBox {
            min: [0.0; 3],
            max: [0.5; 3],
        };
        reader.budgeted(&roi, 8 * RB).unwrap();
        let after_first = reader.read_stats().read_bytes;
        for _ in 0..3 {
            reader.budgeted(&roi, 8 * RB).unwrap();
        }
        let rs = reader.read_stats();
        assert_eq!(
            rs.read_bytes, after_first,
            "repeat queries re-read bytes: {rs:?}"
        );
        assert!(rs.cache_hits > 0, "{rs:?}");
        assert_eq!(reader.metrics.counter(names::READER_INDEX_BUILDS), 1);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_answer_like_sessions() {
        // the free functions must stay byte-for-byte compatible while they
        // exist — each call is a throwaway session
        let s = sim(2);
        let f = snapshot_file("shims", &s, 0.5);
        let reader = SnapshotReader::open(&f, 0.5).unwrap();
        let a = offline_window(&f, 0.5, &BBox::unit(), 8).unwrap();
        let b = reader.window(&BBox::unit(), 8).unwrap();
        assert_eq!(a.len(), b.len());
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.uid.0, gb.uid.0);
            assert_eq!(ga.data, gb.data);
        }
        let wa = offline_window_budgeted(&f, 0.5, &BBox::unit(), 8 * RB).unwrap();
        let wb = reader.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert_eq!(wa.level, wb.level);
        assert_eq!(wa.grids.len(), wb.grids.len());
        let pa = offline_window_progressive(&f, 0.5, &BBox::unit(), 73 * RB).unwrap();
        let pb = reader.progressive(&BBox::unit(), 73 * RB).unwrap();
        assert_eq!(pa.len(), pb.len());
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn online_session_serves_mixed_protocols_on_one_connection() {
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let rec = REC_LEN as u64;
        // one connection, a whole zoom sequence across both protocols
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let coarse = client.budgeted(&BBox::unit(), rec).unwrap();
        assert_eq!(coarse.grids.len(), 1);
        assert_eq!(coarse.depth, 0);
        assert!(coarse.bytes <= rec);
        let mid = client.budgeted(&BBox::unit(), 8 * rec).unwrap();
        assert_eq!(mid.grids.len(), 8);
        assert_eq!(mid.depth, 1);
        assert!(mid.bytes <= 8 * rec);
        // zooming at the same budget reaches the leaves
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let zoom = client.budgeted(&corner, 8 * rec).unwrap();
        assert_eq!(zoom.depth, 2);
        // the fixed-count protocol works on the same socket
        let legacy = client.window(&BBox::unit(), 8).unwrap();
        assert_eq!(legacy.len(), 8);
    }

    #[test]
    fn online_collector_roundtrip() {
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        // full-domain query at budget 8 → the 8 depth-1 grids
        let grids = client.window(&BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
        assert!(grids.iter().all(|g| g.depth == 1));
        assert!(grids.iter().all(|g| g.data.len() == ROW_ELEMS));
        // zoomed query descends deeper
        let corner = BBox {
            min: [0.0; 3],
            max: [0.1; 3],
        };
        let zoom = client.window(&corner, 8).unwrap();
        assert!(zoom.iter().any(|g| g.depth == 2), "{zoom:?} depths");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_online_shims_still_answer() {
        // one-shot clients are sessions of length one: the wire protocol
        // did not change underneath them
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let grids = query(collector.addr, &BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
        let lod = query_budgeted(collector.addr, &BBox::unit(), REC_LEN as u64).unwrap();
        assert_eq!(lod.grids.len(), 1);
        assert_eq!(lod.depth, 0);
    }

    #[test]
    fn online_window_sees_live_updates() {
        let s = sim(1);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let before = client.window(&BBox::unit(), 1).unwrap();
        // mutate the root grid's pressure
        {
            let mut sim = shared.write().unwrap();
            let f = vec![777.0f32; DGRID_CELLS];
            sim.grids[0].cur.set_interior(var::P, &f);
        }
        // the same session serves the new state
        let after = client.window(&BBox::unit(), 1).unwrap();
        let pr = |w: &[WindowGrid]| w[0].data[var::P * DGRID_CELLS];
        assert_ne!(pr(&before), pr(&after));
        assert_eq!(pr(&after), 777.0);
    }

    #[test]
    fn online_and_offline_agree() {
        let p = std::env::temp_dir().join(format!("win_agree_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 1.5).unwrap();
        let reader = SnapshotReader::open(&f, 1.5).unwrap();
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let mut client = WindowClient::connect(collector.addr).unwrap();
        let win = BBox {
            min: [0.4, 0.4, 0.4],
            max: [0.6, 0.6, 0.6],
        };
        let online = client.window(&win, 16).unwrap();
        let offline = reader.window(&win, 16).unwrap();
        assert_eq!(online.len(), offline.len());
        let key = |g: &WindowGrid| g.uid.loc().0;
        let mut on: Vec<_> = online.iter().map(key).collect();
        let mut off: Vec<_> = offline.iter().map(key).collect();
        on.sort_unstable();
        off.sort_unstable();
        assert_eq!(on, off);
        std::fs::remove_file(&p).ok();
    }
}
